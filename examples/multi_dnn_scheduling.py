"""Multi-DNN scheduling (paper §6): run a self-driving-style fleet of models
whose total memory exceeds the budget.

    PYTHONPATH=src python examples/multi_dnn_scheduling.py

Allocates the budget across models with Eq. 1 (performance-score calibrated),
partitions each with the lookup table, executes all of them swapped, and then
adapts when the budget shrinks at runtime (Fig. 18).
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.bench_coefficients import profile_delay_model
from benchmarks.common import build_vision, vision_infos
from repro.core.partition import PartitionPlanner
from repro.core.runtime import SwappedSequential
from repro.core.scheduler import MultiDNNScheduler, ScheduledModel
from repro.models import vision

BATCH = 4
FLEET = [("yolo", "object detection"), ("fcn", "scene segmentation"),
         ("vgg", "sign classification"), ("resnet", "car recognition")]


def main() -> None:
    print("profiling device coefficients (one-off)...")
    dm = profile_delay_model()

    scheduled, built = [], []
    for i, (kind, task) in enumerate(FLEET):
        name, layers, params, hw = build_vision(kind, seed=i)
        infos = vision_infos(layers, params, hw, BATCH)
        scheduled.append(ScheduledModel(f"{kind}:{task}",
                                        PartitionPlanner(infos, dm)))
        built.append((kind, layers, params, hw))

    total = sum(float(np.sum(m.planner.sizes)) for m in scheduled)
    available = total * 0.6
    print(f"\nfleet demands {total/1e6:.1f} MB, budget {available/1e6:.1f} MB "
          f"({total/available:.2f}x beyond)")

    sched = MultiDNNScheduler(scheduled, available)
    for row in sched.summary():
        print(f"  {row['model']:28s} budget={row['budget_mb']:6.1f} MB "
              f"blocks={row['n_blocks']} "
              f"pred={row['predicted_latency_s']*1e3:6.1f} ms")

    print("\nexecuting the fleet, swapped:")
    for (kind, layers, params, hw), m in zip(built, scheduled):
        x = jax.random.normal(jax.random.key(0), (BATCH, hw, hw, 3))
        units = [(f"{kind}{i:02d}", p) for i, p in enumerate(params)]
        with tempfile.TemporaryDirectory() as d:
            sw = SwappedSequential(
                units, lambda i, p, xx, _l=layers: vision.apply_layer(_l[i], p, xx),
                d, mode="snet")
            sw.set_plan(m.plan.points)
            sw.forward(x)                       # warm
            sw.engine.stats.__init__()
            _, st = sw.forward(x)
            sw.close()
        print(f"  {m.name:28s} latency={st['latency_s']*1e3:6.1f} ms "
              f"peak={st['peak_resident_mb']:6.1f} MB "
              f"(budget {m.budget/1e6:.1f} MB)")

    print("\nruntime dynamics: budget drops toward the fleet floor "
          "(paper Fig. 18)...")
    floors = sum(m.planner.min_feasible_budget() for m in scheduled)
    dt = sched.adapt(max(available * 0.65, floors * 1.05))
    print(f"adaptation finished in {dt*1e3:.0f} ms; new plans:")
    for row in sched.summary():
        print(f"  {row['model']:28s} budget={row['budget_mb']:6.1f} MB "
              f"blocks={row['n_blocks']} "
              f"pred={row['predicted_latency_s']*1e3:6.1f} ms")

    print("\nbudget below the physical floor is rejected loudly:")
    try:
        sched.adapt(floors * 0.5)
    except ValueError as e:
        print(f"  ValueError: {e}")


if __name__ == "__main__":
    main()
