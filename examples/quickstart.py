"""Quickstart: run a model beyond its memory budget with SwapNet.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced qwen2.5 model, executes it (a) directly in memory and
(b) swapped through a budget ~3x smaller than the model, and shows that the
outputs are identical (lossless) while peak resident memory stays within
budget (the paper's headline result).
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.cost_model import DelayModel
from repro.core.runtime import SwappedModel
from repro.models.transformer import Model


def main() -> None:
    # reduced family config, deepened to 8 layers so a 3x-too-small budget
    # still satisfies the m=2 physical floor (two adjacent blocks resident)
    cfg = dataclasses.replace(get_arch("qwen2.5-3b").reduced(),
                              dtype="float32", n_layers=8)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    total_mb = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params)) / 1e6
    print(f"model: {cfg.name}, {total_mb:.1f} MB of parameters")

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)),
                                   jnp.int32)}

    # (a) direct inference — everything resident
    ref, _ = jax.jit(model.prefill)(params, batch)

    # (b) SwapNet: blocks swapped through a budget ~1/3 of the model size
    budget = int(total_mb / 3 * 1e6)
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet")
        plan = sm.partition(budget=budget, dm=DelayModel(),
                            batch=2, seq=64)
        logits, stats = sm.forward(batch)
        sm.close()

    match = np.allclose(np.asarray(logits), np.asarray(ref), rtol=1e-4, atol=1e-4)
    print(f"budget: {budget/1e6:.1f} MB -> {plan.n_blocks} blocks "
          f"{[b for b in plan.blocks()]}")
    print(f"peak resident:   {stats['peak_resident_mb']:.1f} MB "
          f"(model is {total_mb:.1f} MB — "
          f"{total_mb/stats['peak_resident_mb']:.2f}x beyond budget)")
    print(f"outputs match direct inference: {match}")
    print(f"swapped latency: {stats['latency_s']*1e3:.1f} ms")
    assert match, "SwapNet must be lossless"


if __name__ == "__main__":
    main()
