"""End-to-end driver: train a ~100M-parameter model for a few hundred steps
on the synthetic pipeline and checkpoint through the SwapNet flat store.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

This is a thin wrapper over the real launcher (src/repro/launch/train.py);
it exists so the example is a single file you can read top to bottom.
"""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main() -> None:
    steps = "300"
    if "--steps" in sys.argv:
        steps = sys.argv[sys.argv.index("--steps") + 1]
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "qwen2.5-3b", "--reduce", "100m",
         "--steps", steps, "--batch", "4", "--seq", "256",
         "--ckpt", os.path.join(ROOT, "results", "ckpt_100m")],
        env=env, cwd=ROOT, check=True)


if __name__ == "__main__":
    main()
