"""LLM-on-edge serving (paper §10): batched generation, plus weight-swapped
inference of a transformer whose parameters exceed the memory budget —
the forward pass streams layer blocks with the m=2 pipeline.

    PYTHONPATH=src python examples/llm_edge_serve.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.cost_model import DelayModel
from repro.core.runtime import SwappedModel
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    cfg = dataclasses.replace(get_arch("gemma2-9b").reduced(),
                              dtype="float32", n_layers=8)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    # 1) batched serving with KV cache (full weights resident)
    engine = ServingEngine(model, params, max_len=96)
    reqs = [Request(i, list(map(int, rng.integers(0, cfg.vocab_size, 24))),
                    max_new_tokens=12) for i in range(4)]
    stats = engine.generate(reqs)
    print(f"batched serving: {stats['decode_steps']} decode steps, "
          f"{stats['tok_per_s']:.1f} tok/s (cold, includes compile)")
    print(f"  first request generated: {reqs[0].output}")

    # 2) the same model's prefill under a 3x-too-small weight budget
    total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))
    budget = total // 3
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 24)),
                                   jnp.int32)}
    ref, _ = jax.jit(model.prefill)(params, batch)
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet")
        plan = sm.partition(budget=budget, dm=DelayModel(), batch=4, seq=24)
        logits, st = sm.forward(batch)
        sm.close()
    ok = np.allclose(np.asarray(logits), np.asarray(ref), rtol=1e-4, atol=1e-4)
    print(f"weight-swapped prefill: {plan.n_blocks} blocks, "
          f"peak {st['peak_resident_mb']:.1f} MB vs model {total/1e6:.1f} MB, "
          f"lossless={ok}")
    assert ok


if __name__ == "__main__":
    main()
