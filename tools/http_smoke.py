#!/usr/bin/env python
"""End-to-end smoke of the HTTP control plane (CI's serving gate).

Boots ``python -m repro.launch.serve --profile <p> --http`` as a real
subprocess, then exercises the full remote lifecycle a fleet driver uses:

  1. parse the ``[serve-http] listening on http://...`` line;
  2. ``GET /healthz``            -> status ok, every model up;
  3. ``POST /v1/submit``         -> rid; poll ``GET /v1/requests/<rid>``
     until ``done``; latency must be the scheduler's own (> 0);
  4. ``POST /v1/submit`` + immediate cancel -> ``cancelled`` status
     (either on the cancel reply or, if an executor won the race, the
     request simply completes — both are legal);
  5. ``GET /metrics``            -> Prometheus text: required families
     present, completed-request count consistent with what we submitted;
  6. ``POST /v1/shutdown``       -> process exits 0 within the deadline.

Stdlib only (urllib), same as the control plane itself. Exit code 0 =
healthy; any assertion prints a diagnostic and exits 1.

Usage::

    PYTHONPATH=src python tools/http_smoke.py [--profile edge-tpu]
"""
from __future__ import annotations

import argparse
import json
import re
import subprocess
import sys
import time
import urllib.request

REQUIRED_FAMILIES = (
    "swapnet_ledger_occupancy",
    "swapnet_cache_hit_rate",
    "swapnet_requests_completed_total",
    "swapnet_request_latency_seconds",
    "swapnet_model_up",
    "swapnet_http_requests_total",
)


def call(base: str, path: str, body=None, timeout: float = 30.0):
    req = urllib.request.Request(
        base + path,
        data=(json.dumps(body).encode() if body is not None else None),
        headers={"Content-Type": "application/json"},
        method="POST" if body is not None else "GET")
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        raw = resp.read()
        ctype = resp.headers.get("Content-Type", "")
        return raw.decode() if "text/plain" in ctype else json.loads(raw)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="edge-tpu")
    ap.add_argument("--boot-timeout", type=float, default=600.0,
                    help="seconds to wait for the listening line (model "
                         "build + jit warmup happen before bind)")
    args = ap.parse_args()

    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve",
         "--profile", args.profile, "--http", "--http-port", "0"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    base = None
    deadline = time.monotonic() + args.boot_timeout
    lines = []
    try:
        while time.monotonic() < deadline:
            line = proc.stdout.readline()
            if not line and proc.poll() is not None:
                break
            lines.append(line)
            m = re.search(r"listening on (http://[\w.:]+)", line)
            if m:
                base = m.group(1)
                break
        assert base, f"no listening line within {args.boot_timeout}s:\n" \
                     + "".join(lines[-20:])
        print(f"[http-smoke] serving at {base}")

        health = call(base, "/healthz")
        assert health["status"] == "ok", health
        assert health["models"] and all(health["models"].values()), health
        model = sorted(health["models"])[0]
        print(f"[http-smoke] healthz ok, models: {sorted(health['models'])}")

        sub = call(base, "/v1/submit",
                   {"model": model, "requests": 2, "prompt_len": 16,
                    "seed": 7, "priority": 8.0})
        rid = sub["rid"]
        poll_deadline = time.monotonic() + 300
        while time.monotonic() < poll_deadline:
            status = call(base, f"/v1/requests/{rid}")
            if status["status"] != "pending":
                break
            time.sleep(0.05)
        assert status["status"] == "done", status
        assert status["latency_s"] > 0, status
        assert status["logits_shape"][0] == 2, status
        print(f"[http-smoke] rid {rid} done in {status['latency_s']*1e3:.1f} "
              f"ms (scheduler's own latency), "
              f"logits_shape={status['logits_shape']}")

        sub2 = call(base, "/v1/submit",
                    {"model": model, "requests": 1, "prompt_len": 8})
        cancel = call(base, f"/v1/requests/{sub2['rid']}/cancel", {})
        status2 = call(base, f"/v1/requests/{sub2['rid']}")
        if cancel["cancelled"]:
            assert status2["status"] == "cancelled", status2
            print(f"[http-smoke] rid {sub2['rid']} cancelled cleanly")
        else:       # executor won the race: it must then complete normally
            while status2["status"] == "pending" \
                    and time.monotonic() < poll_deadline:
                time.sleep(0.05)
                status2 = call(base, f"/v1/requests/{sub2['rid']}")
            assert status2["status"] == "done", status2
            print(f"[http-smoke] rid {sub2['rid']} raced cancel, completed")

        text = call(base, "/metrics")
        missing = [f for f in REQUIRED_FAMILIES if f"\n{f}" not in text
                   and not text.startswith(f)]
        assert not missing, f"missing metric families: {missing}"
        done_total = sum(
            float(m.group(1)) for m in re.finditer(
                r'^swapnet_requests_completed_total\{[^}]*\} ([\d.e+-]+)$',
                text, re.M))
        assert done_total >= 1, text
        print(f"[http-smoke] /metrics ok ({len(text.splitlines())} lines, "
              f"{done_total:g} completed requests)")

        call(base, "/v1/shutdown", {})
        proc.wait(timeout=120)
        assert proc.returncode == 0, \
            f"server exited {proc.returncode}:\n{proc.stdout.read()}"
        print("[http-smoke] clean shutdown — PASS")
        return 0
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()


if __name__ == "__main__":
    sys.exit(main())
