#!/usr/bin/env python
"""Fail when the prose drifts from the code it describes.

Checks, over README.md and docs/*.md:

  * every repo file path referenced in backticks or markdown links exists
    (``src/repro/core/runtime.py``, ``docs/BENCHMARKS.md``, ...), including
    dotted module spellings (``repro.launch.serve`` -> src/repro/launch/
    serve.py) and ``path.py: member`` / ``module.attr`` suffixes;
  * every ``--flag`` the docs mention is actually defined by some
    ``add_argument`` call in src/, benchmarks/, or tools/;
  * every backend key in ``STORE_BACKENDS`` is mentioned in README.md and
    docs/ARCHITECTURE.md (a new backend must be documented; a renamed one
    fails the path/flag checks on the stale side);
  * the serving-config surface stays honest both ways: every deployment
    profile in ``repro.config.PROFILES`` is documented in README.md AND
    docs/ARCHITECTURE.md, every ``profile:<name>`` / ``SWAPNET_*`` token
    the docs mention exists in code, every documented dotted config key
    (``runtime.budget_mb`` style) is a real ``ServeConfig`` field, and the
    HTTP endpoint tables match ``repro.serving.control_plane.ENDPOINTS``
    exactly (both directions: undocumented endpoint = drift, documented
    ghost endpoint = drift).

Docs rot silently: a rename refactor updates every import but no grep hits
the prose. This runs in CI next to the test suite so the rename PR is the
one that fixes its own docs. Heuristic by design — only tokens that LOOK
like repo paths or flags are validated; plain prose is never parsed.

One non-docs hygiene check rides along: every ``results/*.json`` path that
``benchmarks/check_regression.py`` or ``.github/workflows/ci.yml``
references must be git-TRACKED. ``.gitignore`` ignores results scratch
patterns, so a new baseline/fixture file that matches one (or a rename
that forgets ``git add``) would otherwise sit untracked forever while CI
quietly gates against a stale committed copy.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
CODE_DIRS = ["src", "benchmarks", "tools", "tests"]

# prefixes a backticked token must start with to be treated as a repo path
PATH_PREFIXES = ("src/", "benchmarks/", "tests/", "docs/", "results/",
                 "tools/", ".github/", "repro.", "benchmarks.")
# flags owned by external tools the docs may legitimately mention
EXTERNAL_FLAGS = {"--smoke-test"}  # (none currently; keep the hook)


def backtick_tokens(text: str) -> list[str]:
    # inline code spans + fenced code blocks, then link targets
    toks = re.findall(r"`([^`\n]+)`", text)
    for block in re.findall(r"```[a-z]*\n(.*?)```", text, re.S):
        toks.extend(block.split())
    toks.extend(re.findall(r"\]\(([^)#\s]+)\)", text))
    return toks


def defined_flags() -> set[str]:
    flags: set[str] = set()
    for d in CODE_DIRS:
        for py in (ROOT / d).rglob("*.py"):
            try:
                tree = ast.parse(py.read_text())
            except SyntaxError:
                continue
            for node in ast.walk(tree):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "add_argument"):
                    for a in node.args:
                        if (isinstance(a, ast.Constant)
                                and isinstance(a.value, str)
                                and a.value.startswith("--")):
                            flags.add(a.value)
    return flags


def resolve_path(tok: str) -> bool:
    """True if ``tok`` names something real in the repo."""
    tok = tok.strip().rstrip(".,;:")
    if tok.startswith(("repro.", "benchmarks.")):  # python -m spelling
        mod = tok.split()[0]
        rel = mod.replace(".", "/")
        base = "src/" if mod.startswith("repro.") else ""
        return ((ROOT / f"{base}{rel}.py").exists()
                or (ROOT / base / rel).is_dir())
    candidates = [tok, tok.split(":")[0].strip()]
    # `store/base.py` style (relative to a dir named in the section) and
    # `kernels/swap_linear.vmem_bytes` style (module.attr) both reduce to:
    # strip a trailing .member if the base resolves
    if "." in tok.rsplit("/", 1)[-1]:
        stem = tok[:tok.rfind(".")]
        candidates += [stem, stem + ".py"]
    for c in candidates:
        c = c.strip().rstrip(".,;:")
        if not c:
            continue
        if (ROOT / c).exists():
            return True
        # paths quoted relative to src/repro/ inside module-map sections
        if (ROOT / "src" / "repro" / c).exists():
            return True
    return False


def looks_like_path(tok: str) -> bool:
    if " " in tok and not tok.startswith(("repro.", "benchmarks.")):
        return False
    if any(ch in tok for ch in "{}*|\\()<>="):
        return False
    return tok.startswith(PATH_PREFIXES) or (
        "/" in tok and tok.rsplit("/", 1)[-1].count(".") >= 1
        and not tok.startswith(("http", "0.", "1.")))


def check_serving_config(readme: str, arch: str) -> list[str]:
    """Profiles, config keys, env vars, and HTTP endpoints: docs <-> code,
    both directions."""
    from repro.config import ENV_PREFIX, config_fields, profile_names
    from repro.serving.control_plane import ENDPOINTS
    errors: list[str] = []
    fields = config_fields()
    known_profiles = set(profile_names())
    env_map = {ENV_PREFIX + path.replace(".", "_").upper()
               for path in fields}
    env_map.add(ENV_PREFIX + "PROFILE")

    for name, text in [("README.md", readme), ("docs/ARCHITECTURE.md", arch)]:
        # every shipped profile must be documented...
        for prof in sorted(known_profiles):
            if not re.search(rf"`{re.escape(prof)}`", text):
                errors.append(f"{name}: deployment profile `{prof}` "
                              f"(repro.config.PROFILES) is undocumented")
        toks = backtick_tokens(text)
        for tok in toks:
            tok = tok.strip().rstrip(".,;:")
            # ...and every documented profile/env/config token must exist
            m = re.match(r"^profile:([\w-]+)$", tok)
            if m and m.group(1) not in known_profiles:
                errors.append(f"{name}: unknown profile `{m.group(1)}`")
            for var in re.findall(rf"\b({ENV_PREFIX}[A-Z0-9_]+)\b", tok):
                if "<" in tok:          # template spellings like
                    continue            # SWAPNET_<SECTION>_<KEY>
                if var not in env_map:
                    errors.append(f"{name}: env var `{var}` is not a "
                                  f"ServeConfig field")
            m = re.match(r"^(workload|runtime|scheduler|http)\.(\w+)$", tok)
            if m and m.group(2) != "py" and tok not in fields:
                # (module-map lines like `runtime.py` are paths, not keys)
                errors.append(f"{name}: config key `{tok}` is not a "
                              f"ServeConfig field")

    # endpoint tables: exact two-way match against the code's contract
    code_eps = {(meth, path) for meth, path in ENDPOINTS}
    for name, text in [("README.md", readme), ("docs/ARCHITECTURE.md", arch)]:
        doc_eps = set()
        for meth, path in re.findall(
                r"(GET|POST)\W+`(/[\w/<>.-]*)`", text):
            doc_eps.add((meth, path))
        for meth, path in re.findall(            # README prose spelling:
                r"`(GET|POST) (/[\w/<>.-]*)`", text):   # `GET /healthz`
            doc_eps.add((meth, path))
        if not doc_eps:
            errors.append(f"{name}: no HTTP endpoint reference found "
                          f"(expected the control-plane endpoints)")
            continue
        for ep in sorted(code_eps - doc_eps):
            errors.append(f"{name}: endpoint {ep[0]} {ep[1]} "
                          f"(control_plane.ENDPOINTS) is undocumented")
        for ep in sorted(doc_eps - code_eps):
            errors.append(f"{name}: documents endpoint {ep[0]} {ep[1]} "
                          f"which the control plane does not serve")
    return errors


def check_tracked_results() -> list[str]:
    """Every results/*.json path referenced by the regression gate or the
    CI workflow must be tracked in git. Skips silently when git (or the
    .git dir) is unavailable — a source tarball can still run the docs
    checks."""
    import subprocess
    refs: set[str] = set()
    for src in (ROOT / "benchmarks" / "check_regression.py",
                ROOT / ".github" / "workflows" / "ci.yml"):
        if src.exists():
            refs.update(re.findall(r"results/[\w.-]+\.json",
                                   src.read_text()))
    # files CI (re)generates fresh on every run are artifacts, not
    # fixtures — only the committed baseline inputs must be tracked,
    # and those are exactly the paths the gate READS as its baseline
    # plus any fixture the reporting tests pin (all BENCH_*.json today)
    try:
        out = subprocess.run(
            ["git", "ls-files", "--", "results/"], cwd=ROOT,
            capture_output=True, text=True, timeout=30, check=True).stdout
    except (OSError, subprocess.SubprocessError):
        return []
    tracked = set(out.split())
    return [f"results hygiene: `{p}` is referenced by the CI gate but not "
            f"git-tracked (matched a .gitignore scratch pattern, or "
            f"`git add` was forgotten)"
            for p in sorted(refs) if p not in tracked]


def main() -> int:
    flags = defined_flags()
    errors: list[str] = []
    readme = (ROOT / "README.md").read_text()
    arch = (ROOT / "docs" / "ARCHITECTURE.md").read_text()

    for doc in DOC_FILES:
        text = doc.read_text()
        rel = doc.relative_to(ROOT)
        for tok in backtick_tokens(text):
            tok = tok.strip()
            if looks_like_path(tok) and not resolve_path(tok):
                errors.append(f"{rel}: stale path reference `{tok}`")
            for flag in re.findall(r"(?<![\w-])(--[a-z][a-z0-9-]+)", tok):
                if flag not in flags and flag not in EXTERNAL_FLAGS:
                    errors.append(f"{rel}: flag `{flag}` is not defined by "
                                  f"any add_argument in {CODE_DIRS}")

    sys.path.insert(0, str(ROOT / "src"))
    from repro.store import STORE_BACKENDS
    for backend in STORE_BACKENDS:
        for name, text in [("README.md", readme),
                           ("docs/ARCHITECTURE.md", arch)]:
            if not re.search(rf"`{backend}`", text):
                errors.append(f"{name}: store backend `{backend}` "
                              f"(STORE_BACKENDS) is undocumented")

    errors += check_serving_config(readme, arch)
    errors += check_tracked_results()

    if errors:
        print(f"docs drift: {len(errors)} problem(s)")
        for e in sorted(set(errors)):
            print(f"  {e}")
        return 1
    n = sum(len(backtick_tokens(d.read_text())) for d in DOC_FILES)
    print(f"docs drift: OK ({len(DOC_FILES)} docs, {n} code tokens, "
          f"{len(flags)} known flags, {len(STORE_BACKENDS)} backends)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
