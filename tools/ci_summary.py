#!/usr/bin/env python
"""Render the CI job summary (markdown) from report.xml + results/*.json.

Extracted from the inline heredoc in .github/workflows/ci.yml so the
renderers are unit-testable (tests/test_reporting.py) against the
COMMITTED results fixtures — a bench JSON schema shift now fails a test
instead of silently blanking a section of the job summary.

Usage (the workflow appends stdout to $GITHUB_STEP_SUMMARY)::

    python tools/ci_summary.py >> "$GITHUB_STEP_SUMMARY"

Exit status: 0 when the junit verdict is OK (passes >= $BASELINE_PASSED
and zero failures/errors), 1 on a regression — the workflow step inherits
it, so the summary step doubles as the pass-count gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import xml.etree.ElementTree as ET
from typing import Dict, List, Optional, Tuple


# ------------------------------------------------------------------- junit
def junit_counts(path: str) -> Dict[str, int]:
    """passed/failed/errors/skipped totals from a junit XML report.
    The XML is the machine-readable truth (regexing the console log breaks
    on pytest wording/plugin changes). Missing file -> all zeros."""
    passed = failed = errors = skipped = 0
    if os.path.exists(path):
        root = ET.parse(path).getroot()
        for s in root.iter("testsuite"):
            tests = int(s.get("tests", 0))
            failed += int(s.get("failures", 0))
            errors += int(s.get("errors", 0))
            skipped += int(s.get("skipped", 0))
            passed += (tests - int(s.get("failures", 0))
                       - int(s.get("errors", 0)) - int(s.get("skipped", 0)))
    return {"passed": passed, "failed": failed, "errors": errors,
            "skipped": skipped}


def render_junit(counts: Dict[str, int],
                 baseline: int) -> Tuple[List[str], bool]:
    """The headline line + the OK/REGRESSION verdict."""
    bad = counts["failed"] + counts["errors"]
    ok = counts["passed"] >= baseline and bad == 0
    verdict = "OK" if ok else "REGRESSION"
    return [f"### tier-1: {counts['passed']} passed, "
            f"{counts['failed']} failed, {counts['errors']} errors, "
            f"{counts['skipped']} skipped "
            f"(baseline {baseline} passed) — **{verdict}**"], ok


# ---------------------------------------------------------- bench renderers
def render_swap_store(r: dict, chaos_seed: str = "?") -> List[str]:
    """BENCH_swap_store.json: the fused/mmap m=2 points, the chaos arm,
    and the calibrated mixed-precision arm."""
    lines = []
    for backend in ("fused", "mmap"):
        p = r["backends"][backend]["m2"]
        lines.append(f"- swap-store {backend} m2: "
                     f"latency {p['latency_ms']:.1f} ms, "
                     f"overlap_eff {p['overlap_efficiency']:.3f}, "
                     f"swapped {p['bytes_swapped'] / 1e6:.1f} MB "
                     f"({r['workload']})")
    ch = r.get("chaos")
    if ch:
        f = ch["faulty"]
        lines.append(f"- chaos faulty(mmap, p={ch['p']}) seed "
                     f"{ch['seed']}: {sum(f['injected'].values())} "
                     f"faults injected over {f['reads']} reads, "
                     f"{f['retries']} retries, "
                     f"wrong_outputs {f['wrong_outputs']}, "
                     f"p99 {f['p99_ms']:.1f} ms "
                     f"({f['p99_inflation_vs_mmap']:.2f}x mmap); "
                     f"randomized pytest seed {chaos_seed}")
    lines.extend(render_mixed_precision(r.get("mixed_precision")))
    return lines


def render_mixed_precision(mp: Optional[dict]) -> List[str]:
    """The mixed_precision section: plan shape + the three-arm separation
    the regression gate enforces (compare_mixed)."""
    if not mp:
        return []
    hist = mp["plan"]["histogram"]
    lines = [f"- mixed-precision plan @ fidelity {mp['fidelity_target']:g}: "
             f"units fp={hist['fp']} int8={hist['int8']} "
             f"int4={hist['int4']}, "
             f"predicted_err {mp['plan']['predicted_err']:.4f}, "
             f"stored {mp['plan']['stored_mb']:.1f} MB"]
    for arm in ("int8", "int4", "mixed"):
        a = mp[arm]
        lines.append(f"  - {arm}: {a['layers_per_block']:.2f} layers/block, "
                     f"swapped {a['bytes_swapped'] / 1e6:.1f} MB, "
                     f"rel_err {a['rel_err']:.4f} "
                     f"(meets target: {a['meets_target']})")
    return lines


def render_decode(r: dict) -> List[str]:
    lines = []
    for arm, a in sorted(r["arms"].items()):
        lines.append(f"- decode {arm} (max_batch={a['max_batch']}): "
                     f"{a['tok_per_s']:.1f} tok/s "
                     f"(decode-only {a['decode_tok_per_s']:.1f}), "
                     f"occupancy {a['mean_occupancy']:.2f}, "
                     f"kv pages peak {a['kv_pages_peak']}/"
                     f"{a['kv_pool_pages']}, "
                     f"peak {a['peak_resident_mb']:.1f} MB "
                     f"(budget ok: {a['budget_ok']})")
    lines.append(f"- continuous-batching speedup b8/b1: "
                 f"{r['speedup_b8_over_b1']:.2f}x overall, "
                 f"{r['decode_speedup_b8_over_b1']:.2f}x decode-only")
    return lines


def render_multi_tenant(r: dict) -> List[str]:
    lines = []
    for arm, a in r["arms"].items():
        cls = a["classes"]
        lines.append(f"- multi-tenant {arm} (K={a['executors']}): "
                     f"hi p50/p99 {cls['hi']['p50_ms']:.0f}/"
                     f"{cls['hi']['p99_ms']:.0f} ms, "
                     f"lo p50/p99 {cls['lo']['p50_ms']:.0f}/"
                     f"{cls['lo']['p99_ms']:.0f} ms, "
                     f"preemptions {a['preemptions']}, "
                     f"peak {a['peak_resident_mb']:.1f} MB "
                     f"(budget ok: {a['budget_ok']})")
    lines.append(f"- hi-class p99 speedup vs serialized: "
                 f"{r['hi_p99_speedup']:.2f}x")
    par = r.get("http_parity")
    if par:
        http_arm = r["arms"]["scheduled_http"]
        lines.append(f"- http arm parity vs in-process: "
                     f"ok={par['ok']} (tolerance {par['tolerance']}x), "
                     f"poll overhead "
                     f"{http_arm['mean_poll_overhead_ms']:.1f} ms")
    dh = r.get("decode_heavy")
    if dh:
        cls = dh["classes"]
        lines.append(f"- decode-heavy mix: "
                     f"hi p50/p99 {cls['hi']['p50_ms']:.0f}/"
                     f"{cls['hi']['p99_ms']:.0f} ms, "
                     f"gen_lo p50/p99 {cls['gen_lo']['p50_ms']:.0f}/"
                     f"{cls['gen_lo']['p99_ms']:.0f} ms, "
                     f"decode-step preemptions {dh['preemptions']}, "
                     f"peak {dh['peak_resident_mb']:.1f} MB "
                     f"(budget ok: {dh['budget_ok']}, "
                     f"kv pool clean: {dh['kv_pool_clean']})")
    return lines


def render_fleet(r: dict) -> List[str]:
    arr = r["arrival"]
    sc = r["scrape"]
    return [f"- fleet over HTTP (profile {r['profile']}, "
            f"{r['budget_mb']:g} MB): model arrival "
            f"{arr['arch']} registered in "
            f"{arr['register_ms']:.0f} ms, cold first request "
            f"{arr['cold_over_warm']:.2f}x warm; scrape "
            f"{sc['samples']} samples / {sc['families']} families, "
            f"peak {r['peak_resident_mb']:.1f} MB "
            f"(budget ok: {r['budget_ok']}, "
            f"ledger clean: {r['ledger_clean']})"]


# ---------------------------------------------------------------- assembly
RENDERERS = (
    ("BENCH_swap_store.json", render_swap_store),
    ("BENCH_decode.json", render_decode),
    ("BENCH_multi_tenant.json", render_multi_tenant),
    ("BENCH_fleet.json", render_fleet),
)


def render_summary(results_dir: str = "results",
                   report_xml: str = "report.xml",
                   baseline: int = 0,
                   chaos_seed: str = "?") -> Tuple[str, bool]:
    """The whole job summary. Missing bench files are skipped (their CI
    step failed before writing — the junit verdict already covers it)."""
    lines, ok = render_junit(junit_counts(report_xml), baseline)
    for fname, fn in RENDERERS:
        path = os.path.join(results_dir, fname)
        if not os.path.exists(path):
            continue
        with open(path) as fh:
            r = json.load(fh)
        lines.extend(fn(r, chaos_seed) if fn is render_swap_store
                     else fn(r))
    return "\n".join(lines) + "\n", ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--results-dir", default="results")
    ap.add_argument("--report-xml", default="report.xml")
    ap.add_argument("--baseline", type=int,
                    default=int(os.environ.get("BASELINE_PASSED", "0")),
                    help="minimum tier-1 pass count "
                         "(default: $BASELINE_PASSED)")
    args = ap.parse_args(argv)
    text, ok = render_summary(
        results_dir=args.results_dir, report_xml=args.report_xml,
        baseline=args.baseline,
        chaos_seed=os.environ.get("chaos_seed", "?"))
    sys.stdout.write(text)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
