"""Decode-throughput point: continuous batching over the paged KV cache
(`serving/batch_engine.py`) vs serial per-sequence decode, both through the
SAME swapped weight pipeline.

Per-sequence decode pays the model's full swap-in cost PER TOKEN PER
SEQUENCE; a batched decode step streams the weight blocks once and
amortizes them over every active sequence
(:meth:`~repro.core.runtime.SwappedModel.decode_step_paged`). The two arms
serve the IDENTICAL request set:

  * ``b1`` — ``max_batch=1``: the engine degenerates to one-sequence-at-a-
    time decode (the pre-batching serving behaviour);
  * ``b8`` — ``max_batch=8``: all requests co-resident, one weight stream
    per step.

Reported per arm: tokens/s (overall and decode-only), mean batch occupancy,
KV page-pool peak, and the shared-ledger peak vs the budget (weights + KV
pages under ONE `MemoryLedger` — ``budget_ok`` must hold in both arms).
Headline: ``speedup_b8_over_b1`` (the batching win; the CI gate holds it
above 2x) and ``decode_speedup_b8_over_b1`` (the decode-phase-only ratio,
closer to the ideal B x).

Standalone CLI for the CI smoke point::

    python -m benchmarks.bench_decode
    # -> results/BENCH_decode.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile

import jax
import numpy as np

from benchmarks.common import RESULTS_DIR, emit
from repro.configs import ARCHS
from repro.core.cost_model import DelayModel
from repro.core.runtime import SwappedModel
from repro.models.transformer import Model
from repro.serving.batch_engine import BatchDecodeEngine
from repro.serving.engine import Request
from repro.serving.paged_kv import PagedKVCache, page_bytes_for

ARCH = "qwen2.5-3b"
MB = 1024 * 1024
BUDGET = 12 * MB           # ONE ledger budget for weight blocks + KV pages
PAGE_TOKENS = 4


def _build():
    cfg = dataclasses.replace(ARCHS[ARCH].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, n: int, prompt_len: int, max_new: int):
    rng = np.random.default_rng(0)
    return [Request(i, list(map(int, rng.integers(0, cfg.vocab_size,
                                                  prompt_len))),
                    max_new_tokens=max_new)
            for i in range(n)]


def _run_arm(cfg, model, params, reqs, *, max_batch: int,
             page_tokens: int) -> dict:
    """One decode arm over a fresh swapped model + page pool. The pool is
    sized for the whole request set so neither arm preempts — the point is
    the batching amortization, not page pressure."""
    max_ctx = max(len(r.prompt) + r.max_new_tokens for r in reqs)
    pages_per_seq = -(-max_ctx // page_tokens)
    kv_bytes = len(reqs) * pages_per_seq * page_bytes_for(cfg, page_tokens)
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet", budget=BUDGET)
        sm.partition(budget=BUDGET - kv_bytes, dm=DelayModel(),
                     batch=2, seq=16)
        kv = PagedKVCache.for_budget(cfg, sm.engine.ledger, kv_bytes,
                                     page_tokens=page_tokens)
        be = BatchDecodeEngine(sm, kv, max_batch=max_batch)
        for r in reqs:
            be.submit(r)
        be.run_all()
        st = be.stats()
        peak = sm.engine.ledger.peak
        sm.close()
    admissions = len(reqs) + int(st["preemptions"])
    decode_tokens = st["tokens_emitted"] - admissions
    return {
        "max_batch": max_batch,
        "tokens_emitted": int(st["tokens_emitted"]),
        "decode_steps": int(st["decode_steps"]),
        "preemptions": int(st["preemptions"]),
        "mean_occupancy": st["mean_occupancy"],
        "tok_per_s": st["tok_per_s"],
        "decode_tok_per_s": decode_tokens / max(st["decode_s"], 1e-9),
        "prefill_s": st["prefill_s"],
        "decode_s": st["decode_s"],
        "kv_pages_peak": int(st["kv_pages_peak"]),
        "kv_pool_pages": kv.max_pages,
        "kv_page_bytes": kv.page_bytes,
        "kv_bytes": kv_bytes,
        "peak_resident_mb": peak / 1e6,
        "budget_ok": bool(peak <= BUDGET),
        "outputs_digest": sum(t for r in reqs for t in r.output) % (1 << 31),
    }


def run(n_req: int, prompt_len: int, max_new: int,
        page_tokens: int) -> dict:
    cfg, model, params = _build()
    # warm the jit caches at BOTH batch shapes first (the prefill trace and
    # the B=1 / B=n decode traces), so neither measured arm carries the
    # other's compile cost — without this the first arm eats all shared
    # compilation and the speedup is compile skew, not batching
    for mb in (1, n_req):
        _run_arm(cfg, model, params, _requests(cfg, n_req, prompt_len, 2),
                 max_batch=mb, page_tokens=page_tokens)
    arms = {}
    for label, mb in (("b1", 1), ("b8", 8)):
        reqs = _requests(cfg, n_req, prompt_len, max_new)
        arms[label] = _run_arm(cfg, model, params, reqs,
                               max_batch=mb, page_tokens=page_tokens)
    # batching must be invisible in the outputs: both arms decode the same
    # requests greedily, so the emitted token streams are identical
    assert arms["b1"]["outputs_digest"] == arms["b8"]["outputs_digest"], \
        "b1 and b8 arms emitted different tokens"
    b1, b8 = arms["b1"], arms["b8"]
    return {
        "arch": ARCH,
        "budget_mb": BUDGET / 1e6,
        "page_tokens": page_tokens,
        "requests": {"n": n_req, "prompt_len": prompt_len,
                     "max_new": max_new},
        "arms": arms,
        "speedup_b8_over_b1": (b8["tok_per_s"] / b1["tok_per_s"]
                               if b1["tok_per_s"] else 0.0),
        "decode_speedup_b8_over_b1": (
            b8["decode_tok_per_s"] / b1["decode_tok_per_s"]
            if b1["decode_tok_per_s"] else 0.0),
    }


def write_report(report: dict, path: str = None) -> str:
    path = path or os.path.join(RESULTS_DIR, "BENCH_decode.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-tokens", type=int, default=PAGE_TOKENS)
    args = ap.parse_args()

    report = run(args.requests, args.prompt_len, args.max_new,
                 args.page_tokens)
    for label, a in report["arms"].items():
        emit(f"decode.{label}", a["decode_s"] * 1e6 / max(a["decode_steps"],
                                                          1),
             f"tok_per_s={a['tok_per_s']:.2f};"
             f"decode_tok_per_s={a['decode_tok_per_s']:.2f};"
             f"occupancy={a['mean_occupancy']:.2f};"
             f"kv_pages_peak={a['kv_pages_peak']};"
             f"peak_mb={a['peak_resident_mb']:.1f};"
             f"budget_ok={a['budget_ok']}")
    emit("decode.speedup", 0.0,
         f"b8/b1={report['speedup_b8_over_b1']:.2f}x;"
         f"decode_only={report['decode_speedup_b8_over_b1']:.2f}x")
    path = write_report(report)
    print(f"# decode point -> {path}", flush=True)


if __name__ == "__main__":
    main()
