"""§Roofline: three-term roofline per (arch x shape) from the compiled
dry-run artifacts (results/dryrun/*.json), single-pod mesh.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

Hardware: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
cost_analysis() and the parsed HLO are per-partition (per device) under SPMD,
so no further division by chip count is needed.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from benchmarks.common import RESULTS_DIR, emit
from repro.configs import ARCHS, SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256


def model_flops(arch: str, shape: str) -> float:
    """MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N_active*D inference."""
    cfg = ARCHS[arch]
    sh = SHAPES[shape]
    n_act = cfg.n_active_params()
    tokens = sh.global_batch * (1 if sh.mode == "decode" else sh.seq_len)
    mult = 6.0 if sh.mode == "train" else 2.0
    return mult * n_act * tokens


def load_rows(mesh: str = "16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun",
                                              f"*__{mesh}.json"))):
        with open(path) as fh:
            r = json.load(fh)
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "error": r.get("error", "?")})
            continue
        ca = r["cost_analysis"]
        hlo_flops = ca.get("flops", 0.0)
        analytic = r.get("flops_analytic_per_dev", 0.0)
        if not analytic:
            from repro.configs.flops import analytic_flops_per_device
            analytic = analytic_flops_per_device(
                ARCHS[r["arch"]], SHAPES[r["shape"]], CHIPS)
        # train lowerings keep the layer scan rolled (cost analysis counts the
        # body once) -> use the config-derived analytic FLOPs; inference
        # lowerings are fully unrolled -> HLO numbers are trustworthy.
        flops = analytic if r.get("mode") == "train" else hlo_flops
        bytes_acc = ca.get("bytes accessed", 0.0)
        coll = sum(v["bytes"] for v in r["collectives"].values())
        t_c = flops / PEAK_FLOPS
        t_m = bytes_acc / HBM_BW
        t_n = coll / LINK_BW
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(r["arch"], r["shape"])
        useful = mf / max(flops * CHIPS, 1e-30)
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_compute": t_c, "t_memory": t_m, "t_collective": t_n,
            "dominant": dom, "model_flops_ratio": useful,
            "flops_per_dev": flops, "bytes_per_dev": bytes_acc,
            "coll_bytes_per_dev": coll,
            "mem": r.get("memory_analysis", {}),
        })
    return rows


def run() -> None:
    rows = load_rows()
    if not rows:
        emit("roofline.missing", 0.0, "run repro.launch.dryrun --all first")
        return
    out_csv = os.path.join(RESULTS_DIR, "roofline.csv")
    with open(out_csv, "w") as fh:
        fh.write("arch,shape,t_compute_s,t_memory_s,t_collective_s,"
                 "dominant,model_flops_ratio\n")
        for r in rows:
            if "error" in r:
                continue
            fh.write(f"{r['arch']},{r['shape']},{r['t_compute']:.6g},"
                     f"{r['t_memory']:.6g},{r['t_collective']:.6g},"
                     f"{r['dominant']},{r['model_flops_ratio']:.4f}\n")
    for r in rows:
        if "error" in r:
            emit(f"roofline.{r['arch']}.{r['shape']}", 0.0,
                 f"ERROR={r['error'][:60]}")
            continue
        step_s = max(r["t_compute"], r["t_memory"], r["t_collective"])
        emit(f"roofline.{r['arch']}.{r['shape']}", step_s * 1e6,
             f"dom={r['dominant']};tc={r['t_compute']:.4g};"
             f"tm={r['t_memory']:.4g};tn={r['t_collective']:.4g};"
             f"useful={r['model_flops_ratio']:.3f}")
