"""Shared benchmark utilities."""
from __future__ import annotations

import os
import sys
import time
from typing import Callable, List

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    """One CSV row per benchmark quantity: name,us_per_call,derived."""
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def cosine_fidelity(a, b) -> float:
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    n = min(a.size, b.size)       # pruned model may have same-size head output
    a, b = a[:n], b[:n]
    return float(a @ b / max(np.linalg.norm(a) * np.linalg.norm(b), 1e-30))


def build_mlp(n: int = 12, dim: int = 1280, seed: int = 3):
    """Uniform fc stack: the matmul-dominated swap workload (the paper's
    LLM-outlook proxy). Every weight is a 2-D ``w`` — fused-routable — so
    the quantized-resident path engages for the WHOLE model, unlike the
    conv nets whose 4-D kernels all take the host-dequant path."""
    from repro.models import vision
    layers = [vision.Layer("fc", dim, dim) for _ in range(n)]
    params = vision.init_convnet(layers, jax.random.key(seed))
    return layers, params


def mlp_infos(params, dim: int, batch: int):
    """LayerInfo rows for a :func:`build_mlp` stack."""
    from repro.core.cost_model import LayerInfo
    return [LayerInfo(f"mlp{i:02d}",
                      sum(np.asarray(x).nbytes for x in jax.tree.leaves(p)),
                      len(jax.tree.leaves(p)), 2.0 * batch * dim * dim)
            for i, p in enumerate(params)]


def scenario_models():
    """The paper's three application scenarios (scaled, DESIGN.md §8)."""
    from repro.models import vision
    return {
        "self_driving": [("yolo", True), ("fcn", True),
                         ("vgg", False), ("resnet", False)],
        "rsu": [("yolo", True), ("yolo", True), ("resnet", False),
                ("resnet", False), ("vgg", False)],
        "uav": [("yolo", True), ("resnet", False)],
    }


def build_vision(kind: str, seed: int = 0):
    from repro.models import vision
    name, layers, hw = vision.MODELS[kind]()
    params = vision.init_convnet(layers, jax.random.key(seed))
    return name, layers, params, hw


def vision_infos(layers, params, hw: int, batch: int):
    """LayerInfo rows for a conv net."""
    from repro.core.cost_model import LayerInfo
    from repro.models.vision import layer_flops_conv, trace_hw
    hws = trace_hw(layers, hw)
    rows = []
    for i, (l, p) in enumerate(zip(layers, params)):
        size = sum(np.asarray(x).nbytes for x in jax.tree.leaves(p))
        depth = max(len(jax.tree.leaves(p)), 1)
        rows.append(LayerInfo(f"{l.kind}{i:02d}", int(size), depth,
                              layer_flops_conv(l, hws[i], batch)))
    return rows
