"""Benchmark harness: one module per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import (bench_ablation, bench_adaptation, bench_blocks,
                        bench_coefficients, bench_overhead,
                        bench_partition_table, bench_roofline,
                        bench_scenarios)
from benchmarks.common import ROWS, RESULTS_DIR

MODULES = [
    ("fig9_coefficients", bench_coefficients),
    ("table3_partition_table", bench_partition_table),
    ("fig11_13_scenarios", bench_scenarios),
    ("fig15_ablation", bench_ablation),
    ("fig16_blocks", bench_blocks),
    ("fig18_adaptation", bench_adaptation),
    ("fig19a_overhead", bench_overhead),
    ("roofline", bench_roofline),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived", flush=True)
    failed = []
    for name, mod in MODULES:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name}: done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failed.append(name)
            print(f"# {name}: FAILED", flush=True)
            traceback.print_exc()
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "bench.csv"), "w") as fh:
        fh.write("name,us_per_call,derived\n")
        fh.write("\n".join(ROWS) + "\n")
    if failed:
        raise SystemExit(f"failed benches: {failed}")


if __name__ == "__main__":
    main()
