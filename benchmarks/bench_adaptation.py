"""Fig. 18: runtime adaptation of model partitioning to budget dynamics."""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.common import build_vision, emit, vision_infos
from benchmarks.bench_coefficients import profile_delay_model
from repro.core.partition import PartitionPlanner
from repro.core.runtime import SwappedSequential
from repro.models import vision

BATCH = 4


def run() -> None:
    dm = profile_delay_model()
    kind = "resnet"
    _, layers, params, hw = build_vision(kind)
    x = jax.random.normal(jax.random.key(5), (BATCH, hw, hw, 3))
    units = [(f"{kind}{i:02d}", p) for i, p in enumerate(params)]
    infos = vision_infos(layers, params, hw, BATCH)
    total = float(sum(i.size for i in infos))
    planner = PartitionPlanner(infos, dm)
    # the paper precomputes "several partition strategy lookup tables before
    # execution"; adaptation then only re-selects rows
    planner.prewarm([total * f for f in (0.8, 0.55, 0.4)])

    with tempfile.TemporaryDirectory() as d:
        sw = SwappedSequential(
            units, lambda i, p, xx: vision.apply_layer(layers[i], p, xx),
            d, mode="snet")
        # workload dynamics: budget shrinks twice (paper: 136 MB -> smaller)
        for step, frac in enumerate((0.8, 0.55, 0.4)):
            t0 = time.perf_counter()
            plan, _ = planner.best_partition(total * frac)
            adapt_ms = (time.perf_counter() - t0) * 1e3
            sw.set_plan(plan.points)
            sw.forward(x)
            sw.engine.stats.__init__()
            _, st = sw.forward(x)
            emit(f"fig18.budget_{int(frac*100)}pct", st["latency_s"] * 1e6,
                 f"adapt_ms={adapt_ms:.1f};blocks={plan.n_blocks};"
                 f"mem_mb={st['peak_resident_mb']:.2f}")
        sw.close()
