"""Figs. 11-13: the three application scenarios — memory, latency and output
fidelity per model for DInf / DCha / TPrg / SNet.

Accuracy proxy: the paper retrains models per task; here "fidelity" is cosine
similarity of each method's logits against DInf on the same inputs. SwapNet is
bit-lossless (fidelity 1.0); TPrg is structurally pruned (fidelity < 1 —
mirrors the paper's 5.0-6.7% accuracy drop); DCha is exact.
"""
from __future__ import annotations

import tempfile
from typing import Dict

import jax
import numpy as np

from benchmarks.common import (build_vision, cosine_fidelity, emit,
                               scenario_models, timeit, vision_infos)
from benchmarks.bench_coefficients import profile_delay_model
from repro.core.budget import ModelDemand, allocate_budgets
from repro.core.partition import PartitionPlanner
from repro.core.runtime import SwappedSequential
from repro.models import vision

BATCH = 4
BUDGET_FRAC = 0.72      # paper self-driving: 843 MB budget / 1161 MB demand


def _bench_model(kind: str, gpu: bool, budget: float, dm, seed: int) -> Dict:
    name, layers, params, hw = build_vision(kind, seed)
    x = jax.random.normal(jax.random.key(seed + 99), (BATCH, hw, hw, 3))
    total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))

    apply_full = jax.jit(lambda p, xx: vision.apply_convnet(layers, p, xx))
    ref = apply_full(params, x)
    t_dinf = timeit(apply_full, params, x)
    # DInf resident: weights + page-cache copy (+ dispatch copy on GPU models)
    m_dinf = total * (3 if gpu else 2)

    groups = 4
    apply_cha = jax.jit(lambda p, xx: vision.apply_convnet_channel_split(
        layers, p, xx, groups))
    out_cha = apply_cha(params, x)
    t_cha = timeit(apply_cha, params, x)
    m_cha = total * (3 if gpu else 2) / groups * 2 + total / groups

    keep = min(1.0, budget / (total * 2.2))
    pl, pp = vision.prune_convnet(layers, params, keep_frac=max(0.25, keep))
    pruned_total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(pp))
    apply_tp = jax.jit(lambda p, xx: vision.apply_convnet(pl, p, xx))
    out_tp = apply_tp(pp, x)
    t_tp = timeit(apply_tp, pp, x)
    m_tp = pruned_total * (3 if gpu else 2)

    units = [(f"{kind}{i:02d}", p) for i, p in enumerate(params)]
    infos = vision_infos(layers, params, hw, BATCH)
    # the store-backend axis: SNet (mmap, the paper's system) plus the
    # rawio and quant tiers on the SAME partition problem — per-backend
    # swap-in bytes and latency for the Figs. 11-13 workloads
    swapped = {}
    floor = PartitionPlanner(infos, dm).min_feasible_budget() * 1.05
    results = {
        "model": kind, "size_mb": total / 1e6,
        "DInf": (m_dinf, t_dinf, 1.0),
        "DCha": (m_cha, t_cha, cosine_fidelity(ref, out_cha)),
        "TPrg": (m_tp, t_tp, cosine_fidelity(ref, out_tp)),
    }
    for meth, backend in (("SNet", "mmap"), ("SNet_rawio", "rawio"),
                          ("SNet_quant", "quant")):
        with tempfile.TemporaryDirectory() as d:
            sw = SwappedSequential(
                units, lambda i, p, xx: vision.apply_layer(layers[i], p, xx),
                d, gpu_dispatch=gpu, store_backend=backend)
            # rawio holds 2x logical bytes resident (page-cache + staging
            # copies; 3x with the GPU dispatch copy): plan accordingly,
            # floor-lifted to the largest-layer physical minimum
            mult = (3 if gpu else 2) if backend == "rawio" else 1
            sw.partition_with(infos, max(budget / mult, floor), dm)
            out_sn, _ = sw.forward(x)         # warm (jit compiles)
            sw.engine.stats.__init__()
            out_sn, st = sw.forward(x)
            n_blocks = sw.plan.n_blocks
            sw.close()
        m_sn = st["peak_resident_mb"] * 1e6
        results[meth] = (m_sn, st["latency_s"], cosine_fidelity(ref, out_sn))
        swapped[meth] = st["bytes_swapped"]
        if meth == "SNet":
            results["n_blocks"] = n_blocks
            results["overlap_eff"] = st["overlap_efficiency"]
    results["swapped_bytes"] = swapped
    return results


def run() -> None:
    dm = profile_delay_model()
    for scen, models in scenario_models().items():
        demands = []
        built = []
        for i, (kind, gpu) in enumerate(models):
            _, layers, params, hw = build_vision(kind, seed=i)
            total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))
            flops = sum(r.flops for r in vision_infos(layers, params, hw, BATCH))
            demands.append(ModelDemand(f"{kind}{i}", total, dm.t_ex(flops)))
            built.append((kind, gpu))
        available = sum(d.memory for d in demands) * BUDGET_FRAC
        budgets = allocate_budgets(demands, available)
        # Eq. 1 is share-based; highly unbalanced models (vgg's dominant fc —
        # the paper bumps VGG's budget for exactly this, §8.2 fn. 2) get
        # floor-lifted to their largest-layer physical minimum.
        floors = []
        for i, (kind, gpu) in enumerate(models):
            _, layers, params, hw = build_vision(kind, seed=i)
            pl = PartitionPlanner(vision_infos(layers, params, hw, BATCH), dm)
            floors.append(pl.min_feasible_budget())
        budgets = [max(b, f * 1.05) for b, f in zip(budgets, floors)]

        for i, ((kind, gpu), b) in enumerate(zip(built, budgets)):
            r = _bench_model(kind, gpu, b, dm, seed=i)
            dinf_m, dinf_t, _ = r["DInf"]
            for meth in ("DInf", "DCha", "TPrg", "SNet", "SNet_rawio",
                         "SNet_quant"):
                m, t, fid = r[meth]
                extra = ""
                if meth == "SNet":
                    # (no cache is configured in the scenario arm — hit rate
                    # would be a misleading constant 0, so it is not emitted;
                    # bench_overhead's pipeline rows cover the cache)
                    extra = f";overlap_eff={r['overlap_eff']:.3f}"
                if meth.startswith("SNet"):
                    extra += f";swapped_mb={r['swapped_bytes'][meth]/1e6:.1f}"
                emit(f"fig11_13.{scen}.{kind}{i}.{meth}",
                     t * 1e6,
                     f"mem_mb={m/1e6:.1f};fidelity={fid:.4f};"
                     f"mem_vs_dinf={100*(1-m/dinf_m):.1f}%;"
                     f"lat_vs_dinf={100*(t/dinf_t-1):+.1f}%;"
                     f"blocks={r['n_blocks']}{extra}")
