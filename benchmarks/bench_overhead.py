"""Fig. 19a: SwapNet's own memory overhead — skeletons, intermediate
activations, partition lookup tables — plus the pipelined-runtime section:
overlap efficiency (fraction of t_in hidden behind t_ex) and block-cache
hit rate at prefetch depths m = 1, 2, 3."""
from __future__ import annotations

import sys
import tempfile

import jax
import numpy as np

from benchmarks.common import build_vision, emit, vision_infos
from benchmarks.bench_coefficients import profile_delay_model
from repro.core.partition import PartitionPlanner
from repro.core.runtime import SwappedSequential
from repro.core.swap_engine import BlockCache, LayerStore, MemoryLedger
from repro.models import vision

BATCH = 4


def run_pipeline() -> None:
    """Overlap + cache metrics of the depth-m prefetch pipeline on the resnet
    workload (uniform layer sizes — the pipeline-friendly case): m=1 is the
    serial floor (overlap 0 by construction), m=2 is the paper's double
    buffer, m=3 shows what deeper prefetch buys. A second request on the same
    engine reports the hot-block cache hit rate."""
    dm = profile_delay_model()
    _, layers, params, hw = build_vision("resnet")
    units = [(f"resnet{i:02d}", p) for i, p in enumerate(params)]
    infos = vision_infos(layers, params, hw, BATCH)
    total = float(sum(r.size for r in infos))
    largest = float(max(r.size for r in infos))
    # tight enough to force several blocks, roomy enough for an m=3 plan
    budget = max(total * 0.4, 3.6 * largest)
    x = jax.random.normal(jax.random.key(7), (BATCH, hw, hw, 3))

    for m in (1, 2, 3):
        with tempfile.TemporaryDirectory() as d:
            ledger = MemoryLedger(int(budget))
            cache = BlockCache(int(budget * 0.25), ledger)
            sw = SwappedSequential(
                units, lambda i, p, xx: vision.apply_layer(layers[i], p, xx),
                d, mode="snet", prefetch_depth=m, ledger=ledger, cache=cache)
            # the cache reserve comes off the top; blocks get the rest
            sw.partition_with(infos, budget - cache.capacity, dm)
            sw.forward(x)                    # warm (jit compiles)
            cache.clear()                    # drop warm-pass cache entries
            sw.engine.stats.__init__()
            _, st1 = sw.forward(x)           # genuinely cold: all misses
            sw.engine.stats.__init__()
            _, st2 = sw.forward(x)           # repeat request: cache hits
            n_blocks = sw.plan.n_blocks
            sw.close()
        emit(f"pipeline.m{m}", st1["latency_s"] * 1e6,
             f"blocks={n_blocks};overlap_eff={st1['overlap_efficiency']:.3f};"
             f"cache_hit_rate={st2['cache_hit_rate']:.3f};"
             f"peak_mb={st2['peak_resident_mb']:.1f};"
             f"budget_mb={budget/1e6:.1f}")


def run() -> None:
    dm = profile_delay_model()
    for kind in ("vgg", "resnet", "yolo", "fcn"):
        _, layers, params, hw = build_vision(kind)
        units = [(f"{kind}{i:02d}", p) for i, p in enumerate(params)]
        with tempfile.TemporaryDirectory() as d:
            store = LayerStore.build(units, d)
            skel_mb = store.meta_bytes() / 1e6
        infos = vision_infos(layers, params, hw, BATCH)
        planner = PartitionPlanner(infos, dm)
        table = planner.lookup_table(3, budget=float("inf"), delta=0.0)
        table_mb = sys.getsizeof(table) / 1e6 + sum(
            sys.getsizeof(r) for r in table) / 1e6
        # largest inter-layer activation (temporal feature storage)
        hws = vision.trace_hw(layers, hw)
        act_mb = max(BATCH * h * h * max(l.cout, 1) * 4
                     for l, h in zip(layers, hws)) / 1e6
        total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params)) / 1e6
        emit(f"fig19a.{kind}", 0.0,
             f"skeleton_mb={skel_mb:.4f};activations_mb={act_mb:.2f};"
             f"table_mb={table_mb:.3f};model_mb={total:.1f};"
             f"overhead_pct={100*(skel_mb+act_mb+table_mb)/total:.1f}%")
    run_pipeline()
