"""Fig. 19a: SwapNet's own memory overhead — skeletons, intermediate
activations, partition lookup tables — plus the pipelined-runtime section:
overlap efficiency (fraction of t_in hidden behind t_ex), block-cache hit
rate, swap-in time and ACTUAL storage->host bytes per store backend
(mmap / rawio / quant) at prefetch depths m = 1, 2, 3.

Standalone CLI for the CI smoke matrix::

    python -m benchmarks.bench_overhead --smoke
    # -> results/BENCH_swap_store.json  (per-backend swap-in ms / bytes /
    #    overlap efficiency: the perf-trajectory data point)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import jax
import numpy as np

from benchmarks.common import RESULTS_DIR, build_vision, emit, vision_infos
from benchmarks.bench_coefficients import profile_delay_model
from repro.core.cost_model import DelayModel
from repro.core.partition import PartitionPlanner
from repro.core.runtime import SwappedSequential
from repro.core.swap_engine import (BlockCache, LayerStore, MemoryLedger,
                                    size_aware_policy)
from repro.models import vision

BATCH = 4
STORE_BACKENDS = ("mmap", "rawio", "quant")


def _pipeline_point(backend: str, m: int, dm, units, infos, layers,
                    budget: float, x) -> dict:
    """One (backend, m) cell: cold + repeat swapped forward passes."""
    with tempfile.TemporaryDirectory() as d:
        ledger = MemoryLedger(int(budget))
        cache = BlockCache(int(budget * 0.25), ledger)
        sw = SwappedSequential(
            units, lambda i, p, xx: vision.apply_layer(layers[i], p, xx),
            d, prefetch_depth=m, ledger=ledger, cache=cache,
            store_backend=backend)
        # admission from the store's per-unit resident costs (ROADMAP (d))
        cache.set_policy(size_aware_policy(
            {n: sw.store.resident_nbytes(n) for n in sw.store.order},
            cache.capacity))
        # the cache reserve comes off the top; blocks get the rest. rawio
        # holds 2x the logical bytes resident per unit (page-cache + staging
        # copy — the w/o-uni-add arm's whole point), so its blocks must be
        # planned against half the physical budget.
        plan_budget = (budget - cache.capacity) / (2 if backend == "rawio"
                                                   else 1)
        sw.partition_with(infos, plan_budget, dm)
        sw.forward(x)                    # warm (jit compiles)
        cache.clear()                    # drop warm-pass cache entries
        sw.engine.stats.__init__()
        _, st1 = sw.forward(x)           # genuinely cold: all misses
        sw.engine.stats.__init__()
        _, st2 = sw.forward(x)           # repeat request: cache hits
        point = {
            "n_blocks": sw.plan.n_blocks,
            "swap_in_ms": sum(st1["t_in"]) * 1e3,
            "latency_ms": st1["latency_s"] * 1e3,
            "bytes_swapped": st1["bytes_swapped"],
            "bytes_logical": st1["bytes_logical"],
            "overlap_efficiency": st1["overlap_efficiency"],
            "cache_hit_rate": st2["cache_hit_rate"],
            "peak_resident_mb": st2["peak_resident_mb"],
        }
        sw.close()
    return point


def _store_matrix(dm, budget_frac: float = 0.4) -> dict:
    """The backend x m matrix on the resnet workload (uniform layer sizes —
    the pipeline-friendly case): m=1 is the serial floor, m=2 the paper's
    double buffer, m=3 deeper prefetch. A repeat request on the same engine
    reports the hot-block cache hit rate."""
    _, layers, params, hw = build_vision("resnet")
    units = [(f"resnet{i:02d}", p) for i, p in enumerate(params)]
    infos = vision_infos(layers, params, hw, BATCH)
    total = float(sum(r.size for r in infos))
    largest = float(max(r.size for r in infos))
    # tight enough to force several blocks, roomy enough for an m=3 plan
    budget = max(total * budget_frac, 3.6 * largest)
    x = jax.random.normal(jax.random.key(7), (BATCH, hw, hw, 3))

    matrix = {"workload": "resnet", "batch": BATCH,
              "budget_mb": budget / 1e6, "model_mb": total / 1e6,
              "backends": {}}
    for backend in STORE_BACKENDS:
        rows = {}
        for m in (1, 2, 3):
            rows[f"m{m}"] = _pipeline_point(backend, m, dm, units, infos,
                                            layers, budget, x)
        matrix["backends"][backend] = rows
    mmap_bytes = matrix["backends"]["mmap"]["m2"]["bytes_swapped"]
    for backend in STORE_BACKENDS:
        b = matrix["backends"][backend]["m2"]["bytes_swapped"]
        matrix["backends"][backend]["bytes_vs_mmap"] = \
            b / mmap_bytes if mmap_bytes else 1.0
    return matrix


def write_store_report(matrix: dict,
                       path: str = None) -> str:
    path = path or os.path.join(RESULTS_DIR, "BENCH_swap_store.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(matrix, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def run_pipeline(dm=None) -> None:
    dm = dm or profile_delay_model()
    matrix = _store_matrix(dm)
    for backend, rows in matrix["backends"].items():
        for m in (1, 2, 3):
            p = rows[f"m{m}"]
            emit(f"pipeline.{backend}.m{m}", p["latency_ms"] * 1e3,
                 f"blocks={p['n_blocks']};"
                 f"swap_in_ms={p['swap_in_ms']:.1f};"
                 f"swapped_mb={p['bytes_swapped']/1e6:.1f};"
                 f"overlap_eff={p['overlap_efficiency']:.3f};"
                 f"cache_hit_rate={p['cache_hit_rate']:.3f};"
                 f"peak_mb={p['peak_resident_mb']:.1f};"
                 f"budget_mb={matrix['budget_mb']:.1f}")
    path = write_store_report(matrix)
    print(f"# swap-store matrix -> {path}", flush=True)


def run() -> None:
    dm = profile_delay_model()
    for kind in ("vgg", "resnet", "yolo", "fcn"):
        _, layers, params, hw = build_vision(kind)
        units = [(f"{kind}{i:02d}", p) for i, p in enumerate(params)]
        with tempfile.TemporaryDirectory() as d:
            store = LayerStore.build(units, d)
            skel_mb = store.meta_bytes() / 1e6
        infos = vision_infos(layers, params, hw, BATCH)
        planner = PartitionPlanner(infos, dm)
        table = planner.lookup_table(3, budget=float("inf"), delta=0.0)
        table_mb = sys.getsizeof(table) / 1e6 + sum(
            sys.getsizeof(r) for r in table) / 1e6
        # largest inter-layer activation (temporal feature storage)
        hws = vision.trace_hw(layers, hw)
        act_mb = max(BATCH * h * h * max(l.cout, 1) * 4
                     for l, h in zip(layers, hws)) / 1e6
        total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params)) / 1e6
        emit(f"fig19a.{kind}", 0.0,
             f"skeleton_mb={skel_mb:.4f};activations_mb={act_mb:.2f};"
             f"table_mb={table_mb:.3f};model_mb={total:.1f};"
             f"overhead_pct={100*(skel_mb+act_mb+table_mb)/total:.1f}%")
    run_pipeline(dm)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="skip device-coefficient profiling (use the default "
                         "DelayModel) and only run the store matrix — the "
                         "cheap CI data point")
    args = ap.parse_args()
    if args.smoke:
        run_pipeline(dm=DelayModel())
    else:
        run()


if __name__ == "__main__":
    main()
