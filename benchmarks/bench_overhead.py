"""Fig. 19a: SwapNet's own memory overhead — skeletons, intermediate
activations, partition lookup tables."""
from __future__ import annotations

import sys
import tempfile

import jax
import numpy as np

from benchmarks.common import build_vision, emit, vision_infos
from benchmarks.bench_coefficients import profile_delay_model
from repro.core.partition import PartitionPlanner
from repro.core.swap_engine import LayerStore
from repro.models import vision

BATCH = 4


def run() -> None:
    dm = profile_delay_model()
    for kind in ("vgg", "resnet", "yolo", "fcn"):
        _, layers, params, hw = build_vision(kind)
        units = [(f"{kind}{i:02d}", p) for i, p in enumerate(params)]
        with tempfile.TemporaryDirectory() as d:
            store = LayerStore.build(units, d)
            skel_mb = store.meta_bytes() / 1e6
        infos = vision_infos(layers, params, hw, BATCH)
        planner = PartitionPlanner(infos, dm)
        table = planner.lookup_table(3, budget=float("inf"), delta=0.0)
        table_mb = sys.getsizeof(table) / 1e6 + sum(
            sys.getsizeof(r) for r in table) / 1e6
        # largest inter-layer activation (temporal feature storage)
        hws = vision.trace_hw(layers, hw)
        act_mb = max(BATCH * h * h * max(l.cout, 1) * 4
                     for l, h in zip(layers, hws)) / 1e6
        total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params)) / 1e6
        emit(f"fig19a.{kind}", 0.0,
             f"skeleton_mb={skel_mb:.4f};activations_mb={act_mb:.2f};"
             f"table_mb={table_mb:.3f};model_mb={total:.1f};"
             f"overhead_pct={100*(skel_mb+act_mb+table_mb)/total:.1f}%")
