"""Fig. 19a: SwapNet's own memory overhead — skeletons, intermediate
activations, partition lookup tables — plus the pipelined-runtime section:
overlap efficiency (fraction of t_in hidden behind t_ex), block-cache hit
rate, swap-in time and ACTUAL storage->host bytes per store backend
(mmap / rawio / quant / fused / directio — fused is the quant store in
quantized-RESIDENT int4 mode: no eager dequant, matmul weights stream
through the fused dequant-matmul kernel; directio is the O_DIRECT
aligned-arena store) at prefetch depths m = 1, 2, 3,
and the per-kernel ``fused_kernel`` micro-matrix: end-to-end swap-in +
compute ms, VMEM working set, and HBM->VMEM weight-stream bytes of
swap_linear vs swap_linear_q at equal tile shapes.

Standalone CLI for the CI smoke matrix::

    python -m benchmarks.bench_overhead --smoke
    # -> results/BENCH_swap_store.json  (per-backend swap-in ms / bytes /
    #    overlap efficiency + the fused-kernel point: the perf-trajectory
    #    data point)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import (RESULTS_DIR, build_mlp, build_vision, emit,
                               mlp_infos, vision_infos)
from benchmarks.bench_coefficients import profile_delay_model
from repro.core.cost_model import DelayModel
from repro.core.partition import PartitionPlanner
from repro.core.runtime import SwappedSequential, kernel_vmem_working_set
from repro.core.swap_engine import (BlockCache, LayerStore, MemoryLedger,
                                    size_aware_policy)
from repro.models import vision

BATCH = 4
# the pipeline matrix workload: a uniform fc stack (see _store_matrix)
MLP_LAYERS, MLP_DIM, MLP_BATCH = 12, 1280, 64
STORE_BACKENDS = ("mmap", "rawio", "quant", "fused", "directio")
# fused = quant store, bits=4, eager=False (QuantizedTensor-resident units)
_BACKEND_OPTS = {"fused": dict(store_backend="quant", precision="int4",
                               fused=True)}


def _evict_page_cache(store) -> None:
    """Make the next cold pass COLD: drop the unit files' page-cache pages
    so swap-in measures storage I/O, not a warm-cache memcpy — without this
    every backend's 'cold' numbers flatter whoever leans on the page cache
    (mmap) and penalize whoever bypasses it (directio). fsync first: dirty
    pages are not evictable. Best-effort (tmpfs ignores the advice)."""
    for name in store.order:
        try:
            fd = os.open(store._path(name), os.O_RDONLY)
        except OSError:
            continue
        try:
            os.fsync(fd)
            os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
        except (OSError, AttributeError):
            pass
        finally:
            os.close(fd)


def _pipeline_point(backend: str, m: int, dm, units, infos, layers,
                    budget: float, x) -> dict:
    """One (backend, m) cell: cold + repeat swapped forward passes."""
    with tempfile.TemporaryDirectory() as d:
        ledger = MemoryLedger(int(budget))
        cache = BlockCache(int(budget * 0.25), ledger)
        opts = _BACKEND_OPTS.get(backend, {"store_backend": backend})
        sw = SwappedSequential(
            units, lambda i, p, xx: vision.apply_layer(layers[i], p, xx),
            d, prefetch_depth=m, ledger=ledger, cache=cache, **opts)
        # admission from the store's per-unit resident costs (ROADMAP (d))
        cache.set_policy(size_aware_policy(
            {n: sw.store.resident_nbytes(n) for n in sw.store.order},
            cache.capacity))
        # the cache reserve comes off the top; blocks get the rest. rawio
        # holds 2x the logical bytes resident per unit (page-cache + staging
        # copy — the w/o-uni-add arm's whole point), so its blocks must be
        # planned against half the physical budget.
        plan_budget = (budget - cache.capacity) / (2 if backend == "rawio"
                                                   else 1)
        # plan each backend with ITS OWN measured per-byte swap cost —
        # mmap-profiled alpha under-costs the quantized channel ~3x and
        # the block-count search then stops at a shallow plan whose cold
        # first block caps the achievable overlap (docs/BENCHMARKS.md)
        sw.partition_with(infos, plan_budget, dm.calibrated(sw.store))
        sw.forward(x)                    # warm (jit compiles)
        # min-of-3 cold passes: this matrix is regression-gated, so shed
        # the CPU scheduler noise instead of averaging it in (bytes are
        # identical across passes — only the clock varies)
        st1 = None
        for _ in range(3):
            cache.clear()                # drop prior-pass cache entries
            _evict_page_cache(sw.store)  # ...and the OS page-cache copies
            sw.engine.stats.__init__()
            _, st = sw.forward(x)        # genuinely cold: all misses
            if st1 is None or st["latency_s"] < st1["latency_s"]:
                st1 = st
        sw.engine.stats.__init__()
        _, st2 = sw.forward(x)           # repeat request: cache hits
        point = {
            "n_blocks": sw.plan.n_blocks,
            "swap_in_ms": sum(st1["t_in"]) * 1e3,
            "latency_ms": st1["latency_s"] * 1e3,
            "bytes_swapped": st1["bytes_swapped"],
            "bytes_logical": st1["bytes_logical"],
            "bytes_resident_quantized": st1["bytes_resident_quantized"],
            "vmem_working_set": st1["vmem_working_set"],
            "precision": st1["precision"],
            "overlap_efficiency": st1["overlap_efficiency"],
            "cache_hit_rate": st2["cache_hit_rate"],
            "peak_resident_mb": st2["peak_resident_mb"],
        }
        sw.close()
    return point


def _fused_kernel_matrix(M: int = 256, K: int = 1024, N: int = 512) -> dict:
    """The per-kernel acceptance point (ISSUE 3): at EQUAL tile shapes,
    swap_linear_q's weight stream moves >= 2x (int8) / >= 3.5x (int4) fewer
    HBM->VMEM bytes than the fp swap_linear stream, with the VMEM working
    set and end-to-end (store swap-in + matmul) wall clock alongside.

    The stream/VMEM figures are the analytic per-grid numbers
    (kernels.swap_linear.weight_stream_bytes / vmem_bytes); the ms figures
    are measured through the auto-dispatch ops wrappers (real kernels on
    TPU, reference path on CPU CI).
    """
    from repro.kernels.swap_linear import weight_stream_bytes
    from repro.models.layers import linear
    from repro.store import build_store

    rng = np.random.default_rng(11)
    w = rng.standard_normal((K, N)).astype(np.float32) * K ** -0.5
    x = jax.numpy.asarray(rng.standard_normal((M, K)).astype(np.float32))
    fp_bits = 32                                  # f32 weight stream
    arms = {"fp": dict(backend="mmap", opts={}, w_bits=fp_bits),
            "int8": dict(backend="quant", opts=dict(bits=8, eager=False),
                         w_bits=8),
            "int4": dict(backend="quant", opts=dict(bits=4, eager=False),
                         w_bits=4)}
    out = {"shape": {"M": M, "K": K, "N": N}}
    for name, arm in arms.items():
        with tempfile.TemporaryDirectory() as d:
            store = build_store([("w", {"w": w})], d, backend=arm["backend"],
                                **arm["opts"])
            t0 = time.perf_counter()
            r = store.read_unit("w")
            leaf = r.params["w"]
            jax.block_until_ready(jax.tree.leaves(leaf))
            t1 = time.perf_counter()
            y = linear(x, leaf)                   # routes by representation
            jax.block_until_ready(y)
            t2 = time.perf_counter()
            y = linear(x, leaf)                   # warm (post-compile)
            jax.block_until_ready(y)
            t3 = time.perf_counter()
        out[name] = {
            "swap_in_ms": (t1 - t0) * 1e3,
            "compute_ms": (t3 - t2) * 1e3,
            "swap_in_plus_compute_ms": (t1 - t0 + t3 - t2) * 1e3,
            "io_bytes": r.io_bytes,
            "vmem_bytes": kernel_vmem_working_set(
                "fp" if name == "fp" else name, "float32"),
            "weight_stream_bytes": weight_stream_bytes(
                M, K, N, w_bits=arm["w_bits"]),
        }
    fp_stream = out["fp"]["weight_stream_bytes"]
    for name in ("int8", "int4"):
        out[name]["stream_ratio_vs_fp"] = fp_stream / out[name][
            "weight_stream_bytes"]
    return out


def _chaos_arm(dm, p: float = 0.01, passes: int = 25,
               budget_frac: float = 0.4) -> dict:
    """The ``faulty(mmap, p=0.01)`` arm (ISSUE 8): the same MLP workload
    served through the fault injector vs clean mmap, over repeated warm
    passes. The claims this section gates (check_regression): injected
    faults cost bounded p99 inflation and ZERO wrong outputs — every
    fault is absorbed by the loader's retry ladder, never served.

    ``CHAOS_SEED`` (env) picks the injection schedule; CI's chaos job logs
    its randomized pick so a failing schedule is reproducible."""
    layers, params = build_mlp(MLP_LAYERS, MLP_DIM)
    units = [(f"mlp{i:02d}", pu) for i, pu in enumerate(params)]
    infos = mlp_infos(params, MLP_DIM, MLP_BATCH)
    total = float(sum(r.size for r in infos))
    largest = float(max(r.size for r in infos))
    budget = max(total * budget_frac, 3.6 * largest)
    x = jax.random.normal(jax.random.key(7), (MLP_BATCH, MLP_DIM))
    seed = int(os.environ.get("CHAOS_SEED", "0"))

    def run(**opts):
        with tempfile.TemporaryDirectory() as d:
            ledger = MemoryLedger(int(budget))
            cache = BlockCache(int(budget * 0.25), ledger)
            sw = SwappedSequential(
                units, lambda i, pp, xx: vision.apply_layer(layers[i], pp, xx),
                d, prefetch_depth=2, ledger=ledger, cache=cache, **opts)
            sw.partition_with(infos, budget - cache.capacity,
                              dm.calibrated(sw.store))
            # absorb unlucky back-to-back injections cheaply: the arm
            # measures steady-state retry cost, not budget exhaustion
            sw.engine.read_retries = 4
            sw.engine.retry_backoff_s = 0.002
            sw.forward(x)                         # warm (jit compiles)
            lats, outs = [], []
            faults, retries = {}, 0
            for _ in range(passes):
                sw.engine.stats.__init__()
                y, st = sw.forward(x)
                lats.append(st["latency_s"] * 1e3)
                outs.append(np.asarray(y))
                retries += st["retries"]
                for k, v in st["faults"].items():
                    faults[k] = faults.get(k, 0) + v
            injected = dict(getattr(sw.store, "injected", {}))
            reads = getattr(sw.store, "reads", 0)
            sw.close()
        return lats, outs, faults, retries, injected, reads

    ref_lats, ref_outs, _, _, _, _ = run(store_backend="mmap")
    lats, outs, faults, retries, injected, reads = run(
        store_backend="faulty",
        store_options=dict(inner="mmap", p=p, seed=seed, latency_s=0.005))
    wrong = sum(not np.array_equal(o, ref_outs[0]) for o in outs)
    ref_p99 = float(np.percentile(ref_lats, 99))
    p99 = float(np.percentile(lats, 99))
    return {
        "workload": f"mlp{MLP_LAYERS}x{MLP_DIM}", "p": p, "seed": seed,
        "passes": passes,
        "mmap": {"p50_ms": float(np.percentile(ref_lats, 50)),
                 "p99_ms": ref_p99},
        "faulty": {"p50_ms": float(np.percentile(lats, 50)),
                   "p99_ms": p99,
                   "p99_inflation_vs_mmap": p99 / max(ref_p99, 1e-9),
                   "wrong_outputs": int(wrong),
                   "faults": faults, "retries": retries,
                   "injected": injected, "reads": reads},
    }


# the bench's committed fidelity target (max rel-L2 at the model output):
# chosen so uniform int8 meets it, uniform int4 VIOLATES it, and the
# calibrated mixed plan lands in between — the regression-gated separation
# (docs/BENCHMARKS.md, check_regression.compare_mixed)
MIXED_FIDELITY = 3.5e-2


def _mixed_precision_arm(budget_frac: float = 0.4) -> dict:
    """The calibrated mixed-precision arm (ISSUE 10): profile the MLP
    stack's per-unit quantization sensitivity through the swapped runtime
    itself (repro/calibrate/), solve the knapsack at ``MIXED_FIDELITY``,
    then run uniform-int8 / uniform-int4 / mixed quantized-RESIDENT arms
    and report what the plan buys: layers packed per block, bytes swapped,
    and measured output error vs the f32 mmap reference.

    The gated claims: mixed packs strictly more layers per block than
    uniform int8, its bytes_swapped sit strictly between the two uniform
    points, it MEETS the fidelity target, and uniform int4 does not.

    Unlike the pipeline matrix, this arm is a CONTROLLED packing
    experiment, so it plans every arm with one fixed, documented
    DelayModel (below) instead of device-profiled or store-measured
    coefficients — block counts and packing density are regression-gated
    and must be bit-reproducible across machines. Bytes and output error
    are exact either way."""
    plan_dm = DelayModel(alpha=0.8e-9)
    from repro.calibrate import (assign_precisions, profile_sequential,
                                 quantize_unit_params)
    from repro.core.cost_model import packing_density

    layers, params = build_mlp(MLP_LAYERS, MLP_DIM)
    # a pure-Gaussian stack has HOMOGENEOUS sensitivity — every unit costs
    # the same error per bit, so there is nothing for a per-unit policy to
    # exploit. Real nets are heterogeneous; make that explicit and
    # reproducible here by snapping EVEN layers' weights onto their own
    # int4 grid (their int4 round-trip is then exact — the
    # quantization-robust units) while ODD layers keep Gaussian weights
    # (int4-fragile, int8-fine). The calibration pass has to FIND this
    # split — it is not told which is which.
    params = [quantize_unit_params(p, bits=4) if i % 2 == 0 else p
              for i, p in enumerate(params)]
    units = [(f"mlp{i:02d}", p) for i, p in enumerate(params)]
    infos = mlp_infos(params, MLP_DIM, MLP_BATCH)
    total = float(sum(r.size for r in infos))
    largest = float(max(r.size for r in infos))
    budget = max(total * budget_frac, 3.6 * largest)
    x = jax.random.normal(jax.random.key(7), (MLP_BATCH, MLP_DIM))

    def build(opts):
        d = tempfile.TemporaryDirectory()
        ledger = MemoryLedger(int(budget))
        cache = BlockCache(int(budget * 0.25), ledger)
        sw = SwappedSequential(
            units, lambda i, p, xx: vision.apply_layer(layers[i], p, xx),
            d.name, prefetch_depth=2, ledger=ledger, cache=cache, **opts)
        sw.partition_with(infos, budget - cache.capacity, plan_dm)
        return d, sw

    # f32 reference output + the sensitivity profile, both through the
    # same swapped stack the arms run on (forward_partial-equivalent:
    # block-by-block under the budget)
    d, ref = build({"store_backend": "mmap"})
    y_ref = np.asarray(ref.forward(x)[0])
    profile = profile_sequential(ref, x, method="output")
    ref.close()
    d.cleanup()
    plan = assign_precisions(profile, MIXED_FIDELITY)

    arms = {
        "int8": dict(store_backend="quant", precision="int8", fused=True),
        "int4": dict(store_backend="quant", precision="int4", fused=True),
        "mixed": dict(store_backend="quant", precision="mixed", fused=True,
                      store_options={"plan": plan}),
    }
    out = {
        "workload": f"mlp{MLP_LAYERS}x{MLP_DIM}",
        "fidelity_target": MIXED_FIDELITY,
        "plan": {"histogram": plan.histogram(),
                 "predicted_err": plan.predicted_err,
                 "stored_mb": plan.stored_bytes / 1e6},
    }
    for name, opts in arms.items():
        d, sw = build(opts)
        sw.forward(x)                    # warm (jit compiles)
        sw.engine.cache.clear()
        sw.engine.stats.__init__()       # cold, deterministic bytes
        y, st = sw.forward(x)
        y = np.asarray(y)
        err = float(np.linalg.norm(y - y_ref)
                    / max(np.linalg.norm(y_ref), 1e-30))
        out[name] = {
            "n_blocks": sw.plan.n_blocks,
            "layers_per_block": packing_density(sw.plan),
            "bytes_swapped": st["bytes_swapped"],
            "bytes_by_precision": st["bytes_by_precision"],
            "rel_err": err,
            "meets_target": bool(err <= MIXED_FIDELITY),
        }
        sw.close()
        d.cleanup()
    return out


def _store_matrix(dm, budget_frac: float = 0.4) -> dict:
    """The backend x m matrix on a uniform 12 x 1280^2 fc stack — the
    matmul-dominated workload the swap path targets (the paper's LLM
    outlook: weight matrices dominate both bytes and FLOPs). Every weight
    is fused-routable, so the quantized-resident backends engage their
    actual mechanism instead of the conv fallback (docs/BENCHMARKS.md).
    m=1 is the serial floor, m=2 the paper's double buffer, m=3 deeper
    prefetch. A repeat request on the same engine reports the hot-block
    cache hit rate."""
    layers, params = build_mlp(MLP_LAYERS, MLP_DIM)
    units = [(f"mlp{i:02d}", p) for i, p in enumerate(params)]
    infos = mlp_infos(params, MLP_DIM, MLP_BATCH)
    total = float(sum(r.size for r in infos))
    largest = float(max(r.size for r in infos))
    # tight enough to force several blocks, roomy enough for an m=3 plan
    budget = max(total * budget_frac, 3.6 * largest)
    x = jax.random.normal(jax.random.key(7), (MLP_BATCH, MLP_DIM))

    matrix = {"workload": f"mlp{MLP_LAYERS}x{MLP_DIM}", "batch": MLP_BATCH,
              "budget_mb": budget / 1e6, "model_mb": total / 1e6,
              "backends": {}}
    for backend in STORE_BACKENDS:
        rows = {}
        for m in (1, 2, 3):
            rows[f"m{m}"] = _pipeline_point(backend, m, dm, units, infos,
                                            layers, budget, x)
        matrix["backends"][backend] = rows
    mmap_bytes = matrix["backends"]["mmap"]["m2"]["bytes_swapped"]
    for backend in STORE_BACKENDS:
        b = matrix["backends"][backend]["m2"]["bytes_swapped"]
        matrix["backends"][backend]["bytes_vs_mmap"] = \
            b / mmap_bytes if mmap_bytes else 1.0
    matrix["fused_kernel"] = _fused_kernel_matrix()
    matrix["chaos"] = _chaos_arm(dm)
    matrix["mixed_precision"] = _mixed_precision_arm()
    return matrix


def write_store_report(matrix: dict,
                       path: str = None) -> str:
    path = path or os.path.join(RESULTS_DIR, "BENCH_swap_store.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(matrix, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def run_pipeline(dm=None) -> None:
    dm = dm or profile_delay_model()
    matrix = _store_matrix(dm)
    for backend, rows in matrix["backends"].items():
        for m in (1, 2, 3):
            p = rows[f"m{m}"]
            emit(f"pipeline.{backend}.m{m}", p["latency_ms"] * 1e3,
                 f"blocks={p['n_blocks']};"
                 f"swap_in_ms={p['swap_in_ms']:.1f};"
                 f"swapped_mb={p['bytes_swapped']/1e6:.1f};"
                 f"overlap_eff={p['overlap_efficiency']:.3f};"
                 f"cache_hit_rate={p['cache_hit_rate']:.3f};"
                 f"peak_mb={p['peak_resident_mb']:.1f};"
                 f"budget_mb={matrix['budget_mb']:.1f}")
    fk = matrix["fused_kernel"]
    for prec in ("int8", "int4"):
        p = fk[prec]
        emit(f"fused_kernel.{prec}", p["swap_in_plus_compute_ms"] * 1e3,
             f"stream_ratio_vs_fp={p['stream_ratio_vs_fp']:.2f};"
             f"vmem_mb={p['vmem_bytes']/1e6:.2f};"
             f"io_mb={p['io_bytes']/1e6:.2f};"
             f"fp_vmem_mb={fk['fp']['vmem_bytes']/1e6:.2f}")
    ch = matrix["chaos"]
    f = ch["faulty"]
    emit("chaos.faulty_mmap", f["p99_ms"] * 1e3,
         f"p={ch['p']};seed={ch['seed']};"
         f"p99_inflation={f['p99_inflation_vs_mmap']:.2f};"
         f"wrong_outputs={f['wrong_outputs']};"
         f"injected={sum(f['injected'].values())};"
         f"retries={f['retries']};reads={f['reads']}")
    mp = matrix["mixed_precision"]
    for arm in ("int8", "int4", "mixed"):
        a = mp[arm]
        emit(f"mixed_precision.{arm}", 0.0,
             f"layers_per_block={a['layers_per_block']:.2f};"
             f"swapped_mb={a['bytes_swapped']/1e6:.1f};"
             f"rel_err={a['rel_err']:.4f};"
             f"meets_target={int(a['meets_target'])};"
             f"target={mp['fidelity_target']}")
    path = write_store_report(matrix)
    print(f"# swap-store matrix -> {path}", flush=True)


def run() -> None:
    dm = profile_delay_model()
    for kind in ("vgg", "resnet", "yolo", "fcn"):
        _, layers, params, hw = build_vision(kind)
        units = [(f"{kind}{i:02d}", p) for i, p in enumerate(params)]
        with tempfile.TemporaryDirectory() as d:
            store = LayerStore.build(units, d)
            skel_mb = store.meta_bytes() / 1e6
        infos = vision_infos(layers, params, hw, BATCH)
        planner = PartitionPlanner(infos, dm)
        table = planner.lookup_table(3, budget=float("inf"), delta=0.0)
        table_mb = sys.getsizeof(table) / 1e6 + sum(
            sys.getsizeof(r) for r in table) / 1e6
        # largest inter-layer activation (temporal feature storage)
        hws = vision.trace_hw(layers, hw)
        act_mb = max(BATCH * h * h * max(l.cout, 1) * 4
                     for l, h in zip(layers, hws)) / 1e6
        total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params)) / 1e6
        emit(f"fig19a.{kind}", 0.0,
             f"skeleton_mb={skel_mb:.4f};activations_mb={act_mb:.2f};"
             f"table_mb={table_mb:.3f};model_mb={total:.1f};"
             f"overhead_pct={100*(skel_mb+act_mb+table_mb)/total:.1f}%")
    run_pipeline(dm)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="skip device-coefficient profiling (use the default "
                         "DelayModel) and only run the store matrix — the "
                         "cheap CI data point")
    args = ap.parse_args()
    if args.smoke:
        run_pipeline(dm=DelayModel())
    else:
        run()


if __name__ == "__main__":
    main()
