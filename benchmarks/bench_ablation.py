"""Fig. 15: ablation — w/o-uni-add (copy_in), w/o-mod-ske (dummy_asm),
w/o-pat-sch (equal partition) vs the full SwapNet."""
from __future__ import annotations

import tempfile

import jax
import numpy as np

from benchmarks.common import build_vision, emit, vision_infos
from benchmarks.bench_coefficients import profile_delay_model
from repro.core.partition import BlockPlan
from repro.core.runtime import SwappedSequential
from repro.models import vision

BATCH = 4


def _run_mode(kind, mode, gpu, budget, dm, equal_partition=False):
    name, layers, params, hw = build_vision(kind)
    x = jax.random.normal(jax.random.key(7), (BATCH, hw, hw, 3))
    units = [(f"{kind}{i:02d}", p) for i, p in enumerate(params)]
    infos = vision_infos(layers, params, hw, BATCH)
    with tempfile.TemporaryDirectory() as d:
        sw = SwappedSequential(
            units, lambda i, p, xx: vision.apply_layer(layers[i], p, xx),
            d, mode=mode, gpu_dispatch=gpu)
        if equal_partition:
            sw.partition_with(infos, budget, dm)
            n = sw.plan.n_blocks
            L = len(units)
            pts = tuple(round(L * k / n) for k in range(1, n))
            sw.set_plan(pts)
        else:
            sw.partition_with(infos, budget, dm)
        sw.forward(x)
        sw.engine.stats.__init__()
        out, st = sw.forward(x)
        sw.close()
    return out, st


def run() -> None:
    dm = profile_delay_model()
    # vgg: the unbalanced structure (dominant fc) is where partition CHOICE
    # matters — on uniform models equal splits are near-optimal and the
    # w/o-pat-sch arm shows nothing (tried: yolo, delta -0.9%)
    kind, gpu = "vgg", True
    _, layers, params, hw = build_vision(kind)
    total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))
    budget = total * 0.9

    ref, full = _run_mode(kind, "snet", gpu, budget, dm)
    arms = {
        "w/o-uni-add": _run_mode(kind, "copy_in", gpu, budget, dm)[1],
        "w/o-mod-ske": _run_mode(kind, "dummy_asm", gpu, budget, dm)[1],
        "w/o-pat-sch": _run_mode(kind, "snet", gpu, budget, dm,
                                 equal_partition=True)[1],
    }
    emit("fig15.full_snet", full["latency_s"] * 1e6,
         f"mem_mb={full['peak_resident_mb']:.1f}")
    for name, st in arms.items():
        dlat = 100 * (st["latency_s"] / full["latency_s"] - 1)
        dmem = st["peak_resident_mb"] - full["peak_resident_mb"]
        emit(f"fig15.{name}", st["latency_s"] * 1e6,
             f"lat_increase={dlat:+.1f}%;mem_delta_mb={dmem:+.1f}")

    # Scheduling leverage depends on the swap-bandwidth:compute ratio. This
    # host's alpha (~3 us/MB warm) makes swap-in ~100x cheaper relative to
    # compute than the paper's Jetson, so w/o-pat-sch is ~null in wall time
    # here. Predict both partitions under a Jetson-like alpha (833 MB/s) with
    # the measured gamma to show the regime the paper operates in.
    import dataclasses as _dc
    from repro.core.cost_model import DelayModel
    from repro.core.partition import BlockPlan, PartitionPlanner, create_blocks, simulate_pipeline
    from benchmarks.common import vision_infos
    _, layers2, params2, hw2 = build_vision(kind)
    infos = vision_infos(layers2, params2, hw2, BATCH)
    dm_jetson = _dc.replace(dm, alpha=1.2e-9)
    pl = PartitionPlanner(infos, dm_jetson)
    plan, _ = pl.best_partition(budget * 1.1)
    L, n = len(infos), plan.n_blocks
    eq = BlockPlan(pl._equal_split(n), L)     # the paper's naive equal-memory arm
    def lat(p):
        s, d, f = create_blocks(p, pl.sizes, pl.depths, pl.flops)
        return simulate_pipeline(s, d, f, dm_jetson)
    t_best, t_eq = lat(plan), lat(eq)
    emit("fig15.w/o-pat-sch@jetson_alpha_predicted", t_eq * 1e6,
         f"lat_increase={100*(t_eq/t_best-1):+.1f}%;vs_best_us={t_best*1e6:.0f}")
