"""Fleet scenario scripted PURELY through the HTTP control plane.

Before the control plane, every fleet scenario was a bespoke
``launch/serve.py`` invocation: the tenant set, budgets, and workload were
frozen at process start, and "a model arrives mid-run" was not expressible
at all. This driver is the counter-example the refactor exists for — one
serving process, resolved from the ``edge-tpu`` deployment profile, driven
end-to-end over plain JSON/HTTP (serving/control_plane.py):

  1. **burst**    — round-robin priority bursts against the two resident
     tenants via ``POST /v1/submit``, latencies polled back from
     ``GET /v1/requests/<rid>`` (the scheduler's own arrival->completion
     ``latency_s``, so polling cadence never distorts the numbers);
  2. **arrival**  — ``POST /v1/models`` registers ``h2o-danube-3-4b`` on
     the live runtime (FusedInf-style: co-tenants keep serving, budgets
     re-planned), then the newcomer's FIRST request measures the cold
     start (jit compile + first swap-in) against its warmed steady state;
  3. **replan**   — ``POST /v1/replan`` with an urgency mix favouring the
     newcomer; the returned per-model block budgets are recorded;
  4. **scrape**   — ``GET /metrics`` (Prometheus text) must agree with
     what the driver observed: completed-request counts per priority
     class, ledger peak under budget, every expected family present;
  5. **shutdown** — ``POST /v1/shutdown`` drains the server; the ledger
     must come back clean.

Standalone CLI for the CI smoke point::

    python -m benchmarks.bench_fleet --smoke
    # -> results/BENCH_fleet.json
"""
from __future__ import annotations

import argparse
import json
import os
import re
import tempfile
import time
import urllib.request

import numpy as np

from benchmarks.common import RESULTS_DIR, emit
from repro.config import resolve_config
from repro.core.serving_scheduler import ServingScheduler
from repro.launch.serve import _build_runtime, _make_batches
from repro.serving.control_plane import ControlPlane
from repro.serving.metrics import MetricsRegistry

PROFILE = "edge-tpu"
ARRIVAL_ARCH = "h2o-danube-3-4b"
# families the scrape must serve for the scenario to count as observable
REQUIRED_FAMILIES = (
    "swapnet_ledger_budget_bytes", "swapnet_ledger_peak_bytes",
    "swapnet_cache_hit_rate", "swapnet_requests_completed_total",
    "swapnet_request_latency_seconds", "swapnet_model_up",
    "swapnet_http_requests_total",
)


def _http(base: str, path: str, body=None, timeout: float = 300.0):
    req = urllib.request.Request(
        base + path,
        data=(json.dumps(body).encode() if body is not None else None),
        headers={"Content-Type": "application/json"},
        method="POST" if body is not None else "GET")
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        raw = resp.read()
        ctype = resp.headers.get("Content-Type", "")
    return raw.decode() if ctype.startswith("text/") else json.loads(raw)


def _poll_done(base: str, rid: int, timeout_s: float = 600.0) -> dict:
    deadline = time.monotonic() + timeout_s
    while True:
        out = _http(base, f"/v1/requests/{rid}")
        if out["status"] != "pending":
            assert out["status"] == "done", out
            return out
        assert time.monotonic() < deadline, f"rid {rid} stuck pending"
        time.sleep(0.02)


def _prom_samples(text: str) -> dict:
    """Prometheus text -> {(name, sorted-label-tuple): value}."""
    out = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        m = re.match(r"^(\w+)(?:\{(.*)\})? (.+)$", line)
        assert m, f"unparseable metrics line: {line!r}"
        labels = tuple(sorted(
            tuple(kv.split("=", 1)) for kv in
            (m.group(2).replace('"', "").split(",") if m.group(2) else [])))
        out[(m.group(1), labels)] = float(m.group(3))
    return out


def _percentiles(lat_ms):
    return {"n": len(lat_ms),
            "p50_ms": float(np.percentile(lat_ms, 50)) if lat_ms else 0.0,
            "p99_ms": float(np.percentile(lat_ms, 99)) if lat_ms else 0.0}


def _burst(base: str, names, priorities, rounds: int, requests: int,
           prompt_len: int, seed0: int) -> dict:
    """Round-robin priority burst over ``names`` via /v1/submit; returns
    per-class scheduler latencies (ms) keyed ``hi``/``lo``."""
    hi = max(priorities)
    rids, label_of = [], {}
    for round_i in range(rounds):
        for j, name in enumerate(names):
            prio = priorities[(round_i * len(names) + j) % len(priorities)]
            resp = _http(base, "/v1/submit",
                         {"model": name, "requests": requests,
                          "prompt_len": prompt_len,
                          "seed": seed0 + round_i * len(names) + j,
                          "priority": prio})
            rids.append(resp["rid"])
            label_of[resp["rid"]] = "hi" if prio == hi else "lo"
    classes = {"hi": [], "lo": []}
    for rid in rids:
        out = _poll_done(base, rid)
        classes[label_of[rid]].append(out["latency_s"] * 1e3)
    return {"submitted": len(rids),
            "classes": {k: _percentiles(v) for k, v in classes.items()}}


def run(rounds: int, requests: int) -> dict:
    # the edge-tpu profile describes the device class; the fleet scenario
    # tightens the envelope via the CLI layer (defaults -> profile -> CLI,
    # the operator override path) so that with the third tenant aboard the
    # models' summed size EXCEEDS the usable pool — Eq. 1 short-circuits to
    # "give everyone its full size" when everything fits, and the replan
    # phase needs the contended regime where urgency actually moves budgets
    cfg = resolve_config(profile=PROFILE, env={},
                         cli={"workload": {"rounds": rounds,
                                           "requests": requests},
                              "runtime": {"budget_mb": 16.0}})
    priorities = [float(p) for p in cfg.workload.priorities]
    budget = int(cfg.runtime.budget_mb * 1e6)
    report = {"profile": PROFILE, "budget_mb": cfg.runtime.budget_mb,
              "executors": cfg.runtime.executors,
              "workload": {"rounds": rounds, "requests": requests,
                           "prompt_len": cfg.workload.prompt_len,
                           "priorities": priorities}}

    with tempfile.TemporaryDirectory() as d:
        names, rt, refs = _build_runtime(cfg, d)
        for name, batch in _make_batches(cfg, refs).items():
            rt.forward(name, batch)             # warm: jit compile per block
        sched = ServingScheduler.from_config(rt, cfg)
        metrics = MetricsRegistry(rt, sched)
        with ControlPlane(rt, sched, metrics, port=0,
                          plan_shape=(cfg.workload.requests,
                                      cfg.workload.prompt_len),
                          reduce=cfg.reduce, workdir=d) as cp:
            base = cp.url
            health = _http(base, "/healthz")
            assert health["status"] == "ok", health

            # -- phase 1: burst against the resident tenants --------------
            report["burst"] = _burst(base, names, priorities, rounds,
                                     requests, cfg.workload.prompt_len,
                                     seed0=0)

            # -- phase 2: runtime model arrival + cold start --------------
            t0 = time.perf_counter()
            added = _http(base, "/v1/models",
                          {"arch": ARRIVAL_ARCH, "reduce": cfg.reduce})
            arrival_ms = (time.perf_counter() - t0) * 1e3
            assert added["added"] == ARRIVAL_ARCH, added
            listing = _http(base, "/v1/models")["models"]
            assert set(listing) == set(names) | {ARRIVAL_ARCH}, listing
            assert all(m["up"] for m in listing.values()), listing

            def one_request(seed: int) -> float:
                rid = _http(base, "/v1/submit",
                            {"model": ARRIVAL_ARCH, "requests": requests,
                             "prompt_len": cfg.workload.prompt_len,
                             "seed": seed, "priority": max(priorities)})["rid"]
                return _poll_done(base, rid)["latency_s"] * 1e3

            cold_ms = one_request(seed=100)     # jit compile + first swap-in
            warm_ms = [one_request(seed=101 + i) for i in range(3)]
            report["arrival"] = {
                "arch": ARRIVAL_ARCH,
                "register_ms": arrival_ms,      # build + add_model + replan
                "n_blocks": added["n_blocks"],
                "cold_first_request_ms": cold_ms,
                "warm_request_ms": _percentiles(warm_ms),
                "cold_over_warm": cold_ms / max(np.median(warm_ms), 1e-9),
            }

            # -- phase 3: post-arrival burst over ALL tenants -------------
            report["burst_post_arrival"] = _burst(
                base, names + [ARRIVAL_ARCH], priorities, rounds, requests,
                cfg.workload.prompt_len, seed0=200)

            # -- phase 4: live replan favouring the newcomer --------------
            # urgency responsiveness, size-independent: the newcomer's
            # budget under a 4x-urgency mix must exceed its budget under a
            # uniform mix (needs the contended regime — see the envelope
            # override above — else Eq. 1 never consults urgency at all)
            uniform = _http(base, "/v1/replan",
                            {"urgencies": {n: 1.0
                                           for n in names + [ARRIVAL_ARCH]}})
            urgencies = {name: 1.0 for name in names}
            urgencies[ARRIVAL_ARCH] = 4.0
            favored = _http(base, "/v1/replan", {"urgencies": urgencies})
            report["replan"] = {"uniform": uniform, "favored": favored}
            assert (favored["budgets_mb"][ARRIVAL_ARCH]
                    > uniform["budgets_mb"][ARRIVAL_ARCH]), \
                f"urgency-weighted replan ignored the mix: " \
                f"{uniform} vs {favored}"

            # -- phase 5: /metrics must agree with what the driver saw ----
            text = _http(base, "/metrics")
            samples = _prom_samples(text)
            families = {name for name, _ in samples}
            missing = [f for f in REQUIRED_FAMILIES if f not in families]
            assert not missing, f"scrape missing families: {missing}"
            completed = sum(v for (name, _), v in samples.items()
                            if name == "swapnet_requests_completed_total")
            expected = (report["burst"]["submitted"] + 4
                        + report["burst_post_arrival"]["submitted"])
            assert completed == expected, (completed, expected)
            peak = samples[("swapnet_ledger_peak_bytes", ())]
            assert peak <= budget, f"scrape shows budget breach: {peak}"
            report["scrape"] = {
                "families": len(families),
                "samples": len(samples),
                "bytes": len(text.encode()),
                "completed_total": completed,
                "peak_resident_mb": peak / 1e6,
                "cache_hit_rate": samples[("swapnet_cache_hit_rate", ())],
            }

            # -- phase 6: graceful shutdown -------------------------------
            assert _http(base, "/v1/shutdown", {})["shutting_down"]
            assert cp.shutdown_requested.wait(timeout=5)
        sched.shutdown()
        st = rt.stats()
        rt.close()
        resident_after_close = float(rt.ledger.resident)

    report["peak_resident_mb"] = st["peak_resident_mb"]
    report["budget_ok"] = bool(st["peak_resident_mb"] * 1e6 <= budget)
    report["ledger_clean"] = resident_after_close == 0.0
    report["clean_shutdown"] = True
    assert report["budget_ok"], report
    assert report["ledger_clean"], st
    return report


def write_report(report: dict, path: str = None) -> str:
    path = path or os.path.join(RESULTS_DIR, "BENCH_fleet.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small workload: the cheap CI data point")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None,
                    help="prompts per submitted batch")
    args = ap.parse_args()
    rounds = args.rounds if args.rounds is not None else (
        2 if args.smoke else 4)
    requests = args.requests if args.requests is not None else 2

    report = run(rounds, requests)
    for phase in ("burst", "burst_post_arrival"):
        for cls in ("hi", "lo"):
            c = report[phase]["classes"][cls]
            emit(f"fleet.{phase}.{cls}", c["p99_ms"] * 1e3,
                 f"n={c['n']};p50_ms={c['p50_ms']:.1f};"
                 f"p99_ms={c['p99_ms']:.1f}")
    arr = report["arrival"]
    emit("fleet.arrival", arr["register_ms"] * 1e3,
         f"arch={arr['arch']};register_ms={arr['register_ms']:.0f};"
         f"cold_ms={arr['cold_first_request_ms']:.1f};"
         f"warm_p50_ms={arr['warm_request_ms']['p50_ms']:.1f};"
         f"cold_over_warm={arr['cold_over_warm']:.2f}x")
    sc = report["scrape"]
    emit("fleet.scrape", 0.0,
         f"families={sc['families']};samples={sc['samples']};"
         f"completed={sc['completed_total']:.0f};"
         f"peak_mb={sc['peak_resident_mb']:.1f};"
         f"hit_rate={sc['cache_hit_rate']:.3f};"
         f"budget_ok={report['budget_ok']};"
         f"ledger_clean={report['ledger_clean']}")
    path = write_report(report)
    print(f"# fleet point -> {path}", flush=True)


if __name__ == "__main__":
    main()
