"""CI perf-regression gate: diff a fresh ``bench_overhead --smoke`` output
(``results/BENCH_swap_store.json``) against the committed baseline
(``results/BENCH_baseline.json``).

Per {mmap, rawio, quant, fused, directio} x m{1,2,3} arm:

  * ``bytes_swapped`` / ``bytes_logical`` must match EXACTLY — swap-in
    byte counts are deterministic (store format x plan), so any drift is a
    real behaviour change (a quant packing regression, a planner change
    silently growing I/O), never noise;
  * ``swap_in_ms`` may drift up to ``--latency-tol`` (default +-20%) —
    wall clock is hardware-dependent, but a 2x regression must fail the
    job instead of sailing through as an uploaded artifact nobody reads.

A missing arm in the fresh output is itself a regression (the matrix
silently shrank). ``--update`` rewrites the baseline from the fresh file
(run it locally after an INTENTIONAL perf change and commit the result).

Exit status: 0 clean, 1 regression — wire it as a CI step after the bench.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List

from benchmarks.common import RESULTS_DIR

BYTE_KEYS = ("bytes_swapped", "bytes_logical")
LATENCY_KEYS = ("swap_in_ms",)
ARMS = ("m1", "m2", "m3")


def compare(baseline: Dict, fresh: Dict,
            latency_tol: float = 0.2) -> List[str]:
    """Regression messages (empty = clean). Latency may drift DOWN freely
    (a faster machine or a real win is not a regression); bytes may not
    move in either direction — fewer bytes than the baseline promised
    means the baseline is stale and must be consciously re-recorded."""
    violations = []
    for backend, rows in sorted(baseline["backends"].items()):
        fresh_rows = fresh.get("backends", {}).get(backend)
        if fresh_rows is None:
            violations.append(f"{backend}: arm missing from fresh results")
            continue
        for m in ARMS:
            base, new = rows.get(m), fresh_rows.get(m)
            if base is None:
                continue
            if new is None:
                violations.append(f"{backend}.{m}: missing from fresh results")
                continue
            for k in BYTE_KEYS:
                if new.get(k) != base.get(k):
                    violations.append(
                        f"{backend}.{m}.{k}: {base.get(k)} -> {new.get(k)} "
                        f"(bytes must match exactly)")
            for k in LATENCY_KEYS:
                b, n = base.get(k), new.get(k)
                if b is None or n is None:
                    continue
                if n > b * (1.0 + latency_tol):
                    violations.append(
                        f"{backend}.{m}.{k}: {b:.2f} -> {n:.2f} ms "
                        f"(+{(n / b - 1.0) * 100:.0f}% > "
                        f"+{latency_tol * 100:.0f}% tolerance)")
    return violations


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline",
                    default=os.path.join(RESULTS_DIR, "BENCH_baseline.json"))
    ap.add_argument("--fresh",
                    default=os.path.join(RESULTS_DIR, "BENCH_swap_store.json"))
    ap.add_argument("--latency-tol", type=float,
                    default=float(os.environ.get("BENCH_LATENCY_TOL", "0.2")),
                    help="allowed fractional swap-in latency growth "
                         "(0.2 = +20%%; env BENCH_LATENCY_TOL overrides "
                         "the default)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh results "
                         "(after an intentional perf change; commit it)")
    args = ap.parse_args()

    if args.update:
        shutil.copyfile(args.fresh, args.baseline)
        print(f"baseline updated from {args.fresh} -> {args.baseline}")
        return

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    violations = compare(baseline, fresh, args.latency_tol)
    if violations:
        print(f"PERF REGRESSION vs {args.baseline} "
              f"(latency tol +{args.latency_tol * 100:.0f}%):")
        for v in violations:
            print(f"  {v}")
        sys.exit(1)
    n_arms = sum(len(r) for r in baseline["backends"].values())
    print(f"perf gate clean: {len(baseline['backends'])} backends, "
          f"{n_arms} arms within +{args.latency_tol * 100:.0f}% latency / "
          f"exact bytes of {args.baseline}")


if __name__ == "__main__":
    main()
