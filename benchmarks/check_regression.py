"""CI perf-regression gate: diff a fresh ``bench_overhead --smoke`` output
(``results/BENCH_swap_store.json``) against the committed baseline
(``results/BENCH_baseline.json``).

Per {mmap, rawio, quant, fused, directio} x m{1,2,3} arm:

  * ``bytes_swapped`` / ``bytes_logical`` must match EXACTLY — swap-in
    byte counts are deterministic (store format x plan), so any drift is a
    real behaviour change (a quant packing regression, a planner change
    silently growing I/O), never noise;
  * ``swap_in_ms`` may drift up to ``--latency-tol`` (default +-20%) —
    wall clock is hardware-dependent, but a 2x regression must fail the
    job instead of sailing through as an uploaded artifact nobody reads.

The baseline also carries a ``decode`` section (``bench_decode`` output,
the continuous-batching point). Per {b1, b8} arm:

  * ``tokens_emitted`` / ``decode_steps`` must match EXACTLY — greedy
    decode over fixed requests is deterministic, so any drift means the
    engine's admission/retirement schedule changed;
  * ``tok_per_s`` may drift DOWN up to ``--latency-tol`` (throughput is
    wall-clock; up is always fine);
  * the fresh ``speedup_b8_over_b1`` must stay above ``DECODE_SPEEDUP_MIN``
    — batching that no longer amortizes the weight stream is the one
    regression this subsystem exists to prevent.

A missing arm in the fresh output is itself a regression (the matrix
silently shrank). ``--update`` MERGES the fresh section(s) into the
baseline — each fresh file refreshes only the section it produces, so
re-recording the swap-store matrix does not silently drop the decode
point (run it locally after an INTENTIONAL perf change and commit).

Exit status: 0 clean, 1 regression — wire it as a CI step after the bench.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

from benchmarks.common import RESULTS_DIR

BYTE_KEYS = ("bytes_swapped", "bytes_logical")
LATENCY_KEYS = ("swap_in_ms",)
ARMS = ("m1", "m2", "m3")
DECODE_ARMS = ("b1", "b8")
DECODE_EXACT_KEYS = ("tokens_emitted", "decode_steps")
DECODE_RATE_KEYS = ("tok_per_s",)
DECODE_SPEEDUP_MIN = 2.0
# chaos arm (faulty(mmap, p=0.01), bench_overhead): gated ABSOLUTELY on the
# fresh run — the seed may be randomized (CHAOS_SEED), so there is no
# baseline to diff against, only the invariants the arm exists to prove.
# The tail bound is deliberately loose: the p99 of a small warm-pass sample
# is max-dominated CPU-scheduler noise; what it must catch is a retry
# ladder gone quadratic or a fault served as latency instead of retried —
# both blow past any small multiple.
CHAOS_P99_INFLATION_MAX = 5.0


def compare(baseline: Dict, fresh: Dict,
            latency_tol: float = 0.2) -> List[str]:
    """Regression messages (empty = clean). Latency may drift DOWN freely
    (a faster machine or a real win is not a regression); bytes may not
    move in either direction — fewer bytes than the baseline promised
    means the baseline is stale and must be consciously re-recorded."""
    violations = []
    for backend, rows in sorted(baseline["backends"].items()):
        fresh_rows = fresh.get("backends", {}).get(backend)
        if fresh_rows is None:
            violations.append(f"{backend}: arm missing from fresh results")
            continue
        for m in ARMS:
            base, new = rows.get(m), fresh_rows.get(m)
            if base is None:
                continue
            if new is None:
                violations.append(f"{backend}.{m}: missing from fresh results")
                continue
            for k in BYTE_KEYS:
                if new.get(k) != base.get(k):
                    violations.append(
                        f"{backend}.{m}.{k}: {base.get(k)} -> {new.get(k)} "
                        f"(bytes must match exactly)")
            for k in LATENCY_KEYS:
                b, n = base.get(k), new.get(k)
                if b is None or n is None:
                    continue
                if n > b * (1.0 + latency_tol):
                    violations.append(
                        f"{backend}.{m}.{k}: {b:.2f} -> {n:.2f} ms "
                        f"(+{(n / b - 1.0) * 100:.0f}% > "
                        f"+{latency_tol * 100:.0f}% tolerance)")
    violations += compare_decode(baseline.get("decode"), fresh.get("decode"),
                                 latency_tol)
    violations += compare_chaos(fresh.get("chaos"))
    return violations


def compare_chaos(new: Dict | None) -> List[str]:
    """Fault-injection invariants (absolute, no baseline): retries make a
    p=0.01 fault schedule invisible in the OUTPUTS (zero wrong results
    served) and bounded in the TAIL (p99 within a small multiple of clean
    mmap). A missing section once the baseline era includes it would be
    caught as a suite regression, not here."""
    if new is None:
        return []
    violations = []
    f = new["faulty"]
    if f.get("wrong_outputs", 0) != 0:
        violations.append(
            f"chaos.faulty.wrong_outputs: {f['wrong_outputs']} of "
            f"{new['passes']} passes served WRONG bits under seed "
            f"{new['seed']} (must be 0: faults are retried, never served)")
    infl = f.get("p99_inflation_vs_mmap", 0.0)
    if infl > CHAOS_P99_INFLATION_MAX:
        violations.append(
            f"chaos.faulty.p99_inflation_vs_mmap: {infl:.2f}x > "
            f"{CHAOS_P99_INFLATION_MAX:.1f}x bound (p={new['p']}, "
            f"seed {new['seed']}: retry/backoff cost is no longer bounded)")
    return violations


def compare_decode(base: Dict | None, new: Dict | None,
                   latency_tol: float = 0.2) -> List[str]:
    """Decode-point regressions. Token/step counts are deterministic and
    must match exactly; throughput may only drift DOWN within tolerance;
    the b8/b1 speedup is gated ABSOLUTELY (the fresh run must demonstrate
    batching still amortizes, whatever the baseline recorded)."""
    if base is None:
        return []
    if new is None:
        return ["decode: section missing from fresh results"]
    violations = []
    for arm in DECODE_ARMS:
        b, n = base["arms"].get(arm), new.get("arms", {}).get(arm)
        if b is None:
            continue
        if n is None:
            violations.append(f"decode.{arm}: missing from fresh results")
            continue
        for k in DECODE_EXACT_KEYS:
            if n.get(k) != b.get(k):
                violations.append(
                    f"decode.{arm}.{k}: {b.get(k)} -> {n.get(k)} "
                    f"(deterministic counts must match exactly)")
        for k in DECODE_RATE_KEYS:
            bv, nv = b.get(k), n.get(k)
            if bv is None or nv is None:
                continue
            if nv < bv * (1.0 - latency_tol):
                violations.append(
                    f"decode.{arm}.{k}: {bv:.2f} -> {nv:.2f} tok/s "
                    f"({(1.0 - nv / bv) * 100:.0f}% drop > "
                    f"{latency_tol * 100:.0f}% tolerance)")
        if not n.get("budget_ok", True):
            violations.append(
                f"decode.{arm}: ledger peak exceeded the budget "
                f"({n.get('peak_resident_mb')} MB)")
    sp = new.get("speedup_b8_over_b1", 0.0)
    if sp < DECODE_SPEEDUP_MIN:
        violations.append(
            f"decode.speedup_b8_over_b1: {sp:.2f}x < "
            f"{DECODE_SPEEDUP_MIN:.1f}x floor (batching no longer "
            f"amortizes the weight stream)")
    return violations


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline",
                    default=os.path.join(RESULTS_DIR, "BENCH_baseline.json"))
    ap.add_argument("--fresh",
                    default=os.path.join(RESULTS_DIR, "BENCH_swap_store.json"))
    ap.add_argument("--fresh-decode",
                    default=os.path.join(RESULTS_DIR, "BENCH_decode.json"),
                    help="bench_decode output attached as the fresh "
                         "'decode' section (skipped when absent)")
    ap.add_argument("--latency-tol", type=float,
                    default=float(os.environ.get("BENCH_LATENCY_TOL", "0.2")),
                    help="allowed fractional swap-in latency growth "
                         "(0.2 = +20%%; env BENCH_LATENCY_TOL overrides "
                         "the default)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh results "
                         "(after an intentional perf change; commit it)")
    args = ap.parse_args()

    if args.update:
        with open(args.fresh) as fh:
            merged = json.load(fh)
        if os.path.exists(args.baseline):      # sections the fresh files
            with open(args.baseline) as fh:    # do not produce survive
                old = json.load(fh)
            for k, v in old.items():
                merged.setdefault(k, v)
        if os.path.exists(args.fresh_decode):
            with open(args.fresh_decode) as fh:
                merged["decode"] = json.load(fh)
        with open(args.baseline, "w") as fh:
            json.dump(merged, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"baseline merged from {args.fresh}"
              + (f" + {args.fresh_decode}"
                 if os.path.exists(args.fresh_decode) else "")
              + f" -> {args.baseline}")
        return

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    if os.path.exists(args.fresh_decode):
        with open(args.fresh_decode) as fh:
            fresh["decode"] = json.load(fh)
    violations = compare(baseline, fresh, args.latency_tol)
    if violations:
        print(f"PERF REGRESSION vs {args.baseline} "
              f"(latency tol +{args.latency_tol * 100:.0f}%):")
        for v in violations:
            print(f"  {v}")
        sys.exit(1)
    n_arms = sum(len(r) for r in baseline["backends"].values())
    decode_note = ""
    if "decode" in baseline and "decode" in fresh:
        decode_note = (f"; decode b8/b1="
                       f"{fresh['decode']['speedup_b8_over_b1']:.2f}x "
                       f"(floor {DECODE_SPEEDUP_MIN:.1f}x)")
    print(f"perf gate clean: {len(baseline['backends'])} backends, "
          f"{n_arms} arms within +{args.latency_tol * 100:.0f}% latency / "
          f"exact bytes of {args.baseline}{decode_note}")


if __name__ == "__main__":
    main()
