"""CI perf-regression gate: diff a fresh ``bench_overhead --smoke`` output
(``results/BENCH_swap_store.json``) against the committed baseline
(``results/BENCH_baseline.json``).

Per {mmap, rawio, quant, fused, directio} x m{1,2,3} arm:

  * ``bytes_swapped`` / ``bytes_logical`` must match EXACTLY — swap-in
    byte counts are deterministic (store format x plan), so any drift is a
    real behaviour change (a quant packing regression, a planner change
    silently growing I/O), never noise;
  * ``swap_in_ms`` may drift up to ``--latency-tol`` (default +-20%) —
    wall clock is hardware-dependent, but a 2x regression must fail the
    job instead of sailing through as an uploaded artifact nobody reads.

The baseline also carries a ``decode`` section (``bench_decode`` output,
the continuous-batching point). Per {b1, b8} arm:

  * ``tokens_emitted`` / ``decode_steps`` must match EXACTLY — greedy
    decode over fixed requests is deterministic, so any drift means the
    engine's admission/retirement schedule changed;
  * ``tok_per_s`` may drift DOWN up to ``--latency-tol`` (throughput is
    wall-clock; up is always fine);
  * the fresh ``speedup_b8_over_b1`` must stay above ``DECODE_SPEEDUP_MIN``
    — batching that no longer amortizes the weight stream is the one
    regression this subsystem exists to prevent.

Three further sections close the coverage gap (every CI bench arm is now
gated, not just uploaded):

  * ``mixed_precision`` (inside the swap-store file) — absolute,
    deterministic invariants: the calibrated plan meets the committed
    fidelity target where uniform int4 violates it, packs strictly more
    layers per block than uniform int8, and swaps strictly fewer bytes
    than int8 / more than int4;
  * ``multi_tenant`` (``bench_multi_tenant --smoke``) — scheduled-arm
    hi-class p99 vs baseline at a widened tolerance, the
    ``hi_p99_speedup`` floor, per-arm ``budget_ok``, and the decode-heavy
    mix's ``kv_pool_clean``;
  * ``fleet`` (``bench_fleet --smoke``) — ``cold_over_warm`` ceiling plus
    the ``ledger_clean`` / ``budget_ok`` / ``clean_shutdown`` verdicts.

A missing arm in the fresh output is itself a regression (the matrix
silently shrank). ``--update`` MERGES the fresh section(s) into the
baseline — each fresh file refreshes only the section it produces, so
re-recording the swap-store matrix does not silently drop the decode,
multi-tenant, or fleet points (run it locally after an INTENTIONAL perf
change and commit).

Exit status: 0 clean, 1 regression — wire it as a CI step after the bench.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

from benchmarks.common import RESULTS_DIR

BYTE_KEYS = ("bytes_swapped", "bytes_logical")
LATENCY_KEYS = ("swap_in_ms",)
ARMS = ("m1", "m2", "m3")
DECODE_ARMS = ("b1", "b8")
DECODE_EXACT_KEYS = ("tokens_emitted", "decode_steps")
DECODE_RATE_KEYS = ("tok_per_s",)
DECODE_SPEEDUP_MIN = 2.0
# chaos arm (faulty(mmap, p=0.01), bench_overhead): gated ABSOLUTELY on the
# fresh run — the seed may be randomized (CHAOS_SEED), so there is no
# baseline to diff against, only the invariants the arm exists to prove.
# The tail bound is deliberately loose: the p99 of a small warm-pass sample
# is max-dominated CPU-scheduler noise; what it must catch is a retry
# ladder gone quadratic or a fault served as latency instead of retried —
# both blow past any small multiple.
CHAOS_P99_INFLATION_MAX = 5.0
# multi-tenant section (bench_multi_tenant --smoke): the scheduler must
# keep beating the serialized arm on hi-class tail latency by at least
# this factor — the subsystem's reason to exist. The hi p99 itself diffs
# against the baseline at a WIDER tolerance than swap_in_ms: a small-n
# p99 is max-dominated scheduler noise.
MULTI_TENANT_HI_SPEEDUP_MIN = 1.1
MULTI_TENANT_P99_TOL_FACTOR = 2.0
# fleet section (bench_fleet --smoke): a runtime-registered model's cold
# first request must stay within this multiple of its warm p50 — a
# blown-out ratio means registration stopped pre-paging / replanning.
FLEET_COLD_OVER_WARM_MAX = 5.0
# mixed_precision section (bench_overhead, the calibrated arm): gated
# ABSOLUTELY on the fresh run — the separation it must demonstrate is
# deterministic (fixed plan DelayModel + exact bytes), so any flip is a
# calibration/policy/store behaviour change, never noise.


def compare(baseline: Dict, fresh: Dict,
            latency_tol: float = 0.2) -> List[str]:
    """Regression messages (empty = clean). Latency may drift DOWN freely
    (a faster machine or a real win is not a regression); bytes may not
    move in either direction — fewer bytes than the baseline promised
    means the baseline is stale and must be consciously re-recorded."""
    violations = []
    for backend, rows in sorted(baseline["backends"].items()):
        fresh_rows = fresh.get("backends", {}).get(backend)
        if fresh_rows is None:
            violations.append(f"{backend}: arm missing from fresh results")
            continue
        for m in ARMS:
            base, new = rows.get(m), fresh_rows.get(m)
            if base is None:
                continue
            if new is None:
                violations.append(f"{backend}.{m}: missing from fresh results")
                continue
            for k in BYTE_KEYS:
                if new.get(k) != base.get(k):
                    violations.append(
                        f"{backend}.{m}.{k}: {base.get(k)} -> {new.get(k)} "
                        f"(bytes must match exactly)")
            for k in LATENCY_KEYS:
                b, n = base.get(k), new.get(k)
                if b is None or n is None:
                    continue
                if n > b * (1.0 + latency_tol):
                    violations.append(
                        f"{backend}.{m}.{k}: {b:.2f} -> {n:.2f} ms "
                        f"(+{(n / b - 1.0) * 100:.0f}% > "
                        f"+{latency_tol * 100:.0f}% tolerance)")
    violations += compare_decode(baseline.get("decode"), fresh.get("decode"),
                                 latency_tol)
    violations += compare_chaos(fresh.get("chaos"))
    violations += compare_mixed(baseline.get("mixed_precision"),
                                fresh.get("mixed_precision"))
    violations += compare_multi_tenant(baseline.get("multi_tenant"),
                                       fresh.get("multi_tenant"),
                                       latency_tol)
    violations += compare_fleet(baseline.get("fleet"), fresh.get("fleet"))
    return violations


def compare_mixed(base: Dict | None, new: Dict | None) -> List[str]:
    """Mixed-precision invariants (absolute on the fresh run): the
    calibrated plan must MEET the committed fidelity target where uniform
    int4 VIOLATES it, pack strictly more layers per block than uniform
    int8, and land its swap traffic strictly between the two uniform
    points. All four quantities are deterministic — bytes come from the
    store format x plan and packing from a fixed-coefficient planner — so
    the section needs no baseline diff, only the section's presence once
    the baseline era includes it."""
    if new is None:
        return ["mixed_precision: section missing from fresh results"] \
            if base is not None else []
    violations = []
    tgt = new["fidelity_target"]
    if not new["mixed"]["meets_target"]:
        violations.append(
            f"mixed_precision.mixed.rel_err: {new['mixed']['rel_err']:.4f} "
            f"> {tgt:g} target (the calibrated plan no longer meets its "
            f"own fidelity target)")
    if new["int4"]["meets_target"]:
        violations.append(
            f"mixed_precision.int4.rel_err: {new['int4']['rel_err']:.4f} "
            f"<= {tgt:g} target (uniform int4 meets the target — the arm "
            f"no longer demonstrates a separation; tighten the target)")
    lpb_mixed = new["mixed"]["layers_per_block"]
    lpb_int8 = new["int8"]["layers_per_block"]
    if not lpb_mixed > lpb_int8:
        violations.append(
            f"mixed_precision.layers_per_block: mixed {lpb_mixed:.2f} !> "
            f"int8 {lpb_int8:.2f} (the plan stopped buying packing "
            f"density)")
    b4, bm, b8 = (new[a]["bytes_swapped"] for a in ("int4", "mixed", "int8"))
    if not b4 < bm < b8:
        violations.append(
            f"mixed_precision.bytes_swapped: int4 {b4} / mixed {bm} / "
            f"int8 {b8} — mixed must sit strictly between the uniform "
            f"points")
    return violations


def compare_multi_tenant(base: Dict | None, new: Dict | None,
                         latency_tol: float = 0.2) -> List[str]:
    """Multi-tenant serving regressions: the hi-class p99 of the scheduled
    arm diffs against the baseline (at a widened tolerance — small-n p99),
    the hi_p99_speedup floor and every arm's ledger verdict are absolute
    on the fresh run, and the decode-heavy mix must return its KV pool
    clean."""
    if base is None:
        return []
    if new is None:
        return ["multi_tenant: section missing from fresh results"]
    violations = []
    tol = latency_tol * MULTI_TENANT_P99_TOL_FACTOR
    b = base["arms"].get("scheduled", {}).get(
        "classes", {}).get("hi", {}).get("p99_ms")
    n = new.get("arms", {}).get("scheduled", {}).get(
        "classes", {}).get("hi", {}).get("p99_ms")
    if b is not None:
        if n is None:
            violations.append("multi_tenant.scheduled.hi.p99_ms: missing "
                              "from fresh results")
        elif n > b * (1.0 + tol):
            violations.append(
                f"multi_tenant.scheduled.hi.p99_ms: {b:.0f} -> {n:.0f} ms "
                f"(+{(n / b - 1.0) * 100:.0f}% > +{tol * 100:.0f}% "
                f"tolerance)")
    sp = new.get("hi_p99_speedup", 0.0)
    if sp < MULTI_TENANT_HI_SPEEDUP_MIN:
        violations.append(
            f"multi_tenant.hi_p99_speedup: {sp:.2f}x < "
            f"{MULTI_TENANT_HI_SPEEDUP_MIN:.1f}x floor (the scheduler no "
            f"longer protects the hi class from the serialized tail)")
    for arm, a in sorted(new.get("arms", {}).items()):
        if not a.get("budget_ok", True):
            violations.append(
                f"multi_tenant.{arm}: ledger peak exceeded the budget "
                f"({a.get('peak_resident_mb')} MB)")
    dh = new.get("decode_heavy")
    if dh is not None:
        if not dh.get("budget_ok", True):
            violations.append(
                f"multi_tenant.decode_heavy: ledger peak exceeded the "
                f"budget ({dh.get('peak_resident_mb')} MB)")
        if not dh.get("kv_pool_clean", True):
            violations.append(
                "multi_tenant.decode_heavy.kv_pool_clean: false (KV pages "
                "leaked across the decode mix)")
    return violations


def compare_fleet(base: Dict | None, new: Dict | None) -> List[str]:
    """Fleet-over-HTTP invariants (absolute on the fresh run): runtime
    model arrival must stay usably warm (cold/warm ratio ceiling) and the
    run must hand back a clean ledger, in-budget peak, and a clean
    shutdown."""
    if base is None:
        return []
    if new is None:
        return ["fleet: section missing from fresh results"]
    violations = []
    ratio = new.get("arrival", {}).get("cold_over_warm", 0.0)
    if ratio > FLEET_COLD_OVER_WARM_MAX:
        violations.append(
            f"fleet.arrival.cold_over_warm: {ratio:.2f}x > "
            f"{FLEET_COLD_OVER_WARM_MAX:.1f}x ceiling (runtime "
            f"registration stopped pre-warming the new model)")
    for key in ("ledger_clean", "budget_ok", "clean_shutdown"):
        if not new.get(key, True):
            violations.append(f"fleet.{key}: false")
    return violations


def compare_chaos(new: Dict | None) -> List[str]:
    """Fault-injection invariants (absolute, no baseline): retries make a
    p=0.01 fault schedule invisible in the OUTPUTS (zero wrong results
    served) and bounded in the TAIL (p99 within a small multiple of clean
    mmap). A missing section once the baseline era includes it would be
    caught as a suite regression, not here."""
    if new is None:
        return []
    violations = []
    f = new["faulty"]
    if f.get("wrong_outputs", 0) != 0:
        violations.append(
            f"chaos.faulty.wrong_outputs: {f['wrong_outputs']} of "
            f"{new['passes']} passes served WRONG bits under seed "
            f"{new['seed']} (must be 0: faults are retried, never served)")
    infl = f.get("p99_inflation_vs_mmap", 0.0)
    if infl > CHAOS_P99_INFLATION_MAX:
        violations.append(
            f"chaos.faulty.p99_inflation_vs_mmap: {infl:.2f}x > "
            f"{CHAOS_P99_INFLATION_MAX:.1f}x bound (p={new['p']}, "
            f"seed {new['seed']}: retry/backoff cost is no longer bounded)")
    return violations


def compare_decode(base: Dict | None, new: Dict | None,
                   latency_tol: float = 0.2) -> List[str]:
    """Decode-point regressions. Token/step counts are deterministic and
    must match exactly; throughput may only drift DOWN within tolerance;
    the b8/b1 speedup is gated ABSOLUTELY (the fresh run must demonstrate
    batching still amortizes, whatever the baseline recorded)."""
    if base is None:
        return []
    if new is None:
        return ["decode: section missing from fresh results"]
    violations = []
    for arm in DECODE_ARMS:
        b, n = base["arms"].get(arm), new.get("arms", {}).get(arm)
        if b is None:
            continue
        if n is None:
            violations.append(f"decode.{arm}: missing from fresh results")
            continue
        for k in DECODE_EXACT_KEYS:
            if n.get(k) != b.get(k):
                violations.append(
                    f"decode.{arm}.{k}: {b.get(k)} -> {n.get(k)} "
                    f"(deterministic counts must match exactly)")
        for k in DECODE_RATE_KEYS:
            bv, nv = b.get(k), n.get(k)
            if bv is None or nv is None:
                continue
            if nv < bv * (1.0 - latency_tol):
                violations.append(
                    f"decode.{arm}.{k}: {bv:.2f} -> {nv:.2f} tok/s "
                    f"({(1.0 - nv / bv) * 100:.0f}% drop > "
                    f"{latency_tol * 100:.0f}% tolerance)")
        if not n.get("budget_ok", True):
            violations.append(
                f"decode.{arm}: ledger peak exceeded the budget "
                f"({n.get('peak_resident_mb')} MB)")
    sp = new.get("speedup_b8_over_b1", 0.0)
    if sp < DECODE_SPEEDUP_MIN:
        violations.append(
            f"decode.speedup_b8_over_b1: {sp:.2f}x < "
            f"{DECODE_SPEEDUP_MIN:.1f}x floor (batching no longer "
            f"amortizes the weight stream)")
    return violations


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline",
                    default=os.path.join(RESULTS_DIR, "BENCH_baseline.json"))
    ap.add_argument("--fresh",
                    default=os.path.join(RESULTS_DIR, "BENCH_swap_store.json"))
    ap.add_argument("--fresh-decode",
                    default=os.path.join(RESULTS_DIR, "BENCH_decode.json"),
                    help="bench_decode output attached as the fresh "
                         "'decode' section (skipped when absent)")
    ap.add_argument("--fresh-multi-tenant",
                    default=os.path.join(RESULTS_DIR,
                                         "BENCH_multi_tenant.json"),
                    help="bench_multi_tenant output attached as the fresh "
                         "'multi_tenant' section (skipped when absent)")
    ap.add_argument("--fresh-fleet",
                    default=os.path.join(RESULTS_DIR, "BENCH_fleet.json"),
                    help="bench_fleet output attached as the fresh "
                         "'fleet' section (skipped when absent)")
    ap.add_argument("--latency-tol", type=float,
                    default=float(os.environ.get("BENCH_LATENCY_TOL", "0.2")),
                    help="allowed fractional swap-in latency growth "
                         "(0.2 = +20%%; env BENCH_LATENCY_TOL overrides "
                         "the default)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh results "
                         "(after an intentional perf change; commit it)")
    args = ap.parse_args()

    section_files = (("decode", args.fresh_decode),
                     ("multi_tenant", args.fresh_multi_tenant),
                     ("fleet", args.fresh_fleet))
    if args.update:
        with open(args.fresh) as fh:
            merged = json.load(fh)
        if os.path.exists(args.baseline):      # sections the fresh files
            with open(args.baseline) as fh:    # do not produce survive
                old = json.load(fh)
            for k, v in old.items():
                merged.setdefault(k, v)
        used = [args.fresh]
        for section, path in section_files:
            if os.path.exists(path):
                with open(path) as fh:
                    merged[section] = json.load(fh)
                used.append(path)
        with open(args.baseline, "w") as fh:
            json.dump(merged, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"baseline merged from {' + '.join(used)} -> {args.baseline}")
        return

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    for section, path in section_files:
        if os.path.exists(path):
            with open(path) as fh:
                fresh[section] = json.load(fh)
    violations = compare(baseline, fresh, args.latency_tol)
    if violations:
        print(f"PERF REGRESSION vs {args.baseline} "
              f"(latency tol +{args.latency_tol * 100:.0f}%):")
        for v in violations:
            print(f"  {v}")
        sys.exit(1)
    n_arms = sum(len(r) for r in baseline["backends"].values())
    notes = ""
    if "decode" in baseline and "decode" in fresh:
        notes += (f"; decode b8/b1="
                  f"{fresh['decode']['speedup_b8_over_b1']:.2f}x "
                  f"(floor {DECODE_SPEEDUP_MIN:.1f}x)")
    if "mixed_precision" in fresh:
        mp = fresh["mixed_precision"]
        notes += (f"; mixed {mp['mixed']['layers_per_block']:.1f} vs int8 "
                  f"{mp['int8']['layers_per_block']:.1f} layers/block @ "
                  f"fidelity {mp['fidelity_target']:g}")
    if "multi_tenant" in baseline and "multi_tenant" in fresh:
        notes += (f"; multi-tenant hi p99 speedup "
                  f"{fresh['multi_tenant']['hi_p99_speedup']:.2f}x "
                  f"(floor {MULTI_TENANT_HI_SPEEDUP_MIN:.1f}x)")
    if "fleet" in baseline and "fleet" in fresh:
        notes += (f"; fleet cold/warm "
                  f"{fresh['fleet']['arrival']['cold_over_warm']:.2f}x "
                  f"(ceiling {FLEET_COLD_OVER_WARM_MAX:.1f}x)")
    print(f"perf gate clean: {len(baseline['backends'])} backends, "
          f"{n_arms} arms within +{args.latency_tol * 100:.0f}% latency / "
          f"exact bytes of {args.baseline}{notes}")


if __name__ == "__main__":
    main()
