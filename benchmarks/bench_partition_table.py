"""Table 3: the run-time partition lookup table (candidate partitions with
peak memory + predicted latency; infeasible rows pruned at run time)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_vision, emit, vision_infos
from benchmarks.bench_coefficients import profile_delay_model
from repro.core.partition import PartitionPlanner

BATCH = 4


def run() -> None:
    dm = profile_delay_model()
    _, layers, params, hw = build_vision("resnet")
    infos = vision_infos(layers, params, hw, BATCH)
    planner = PartitionPlanner(infos, dm)
    total = float(np.sum(planner.sizes))
    budget = total * 0.55
    from repro.core.partition import n_blocks_for_budget
    n = max(3, n_blocks_for_budget(total, budget))
    table = planner.lookup_table(n, budget)
    feas = [r for r in table if r.latency is not None]
    while not feas and n < planner.L:           # smaller blocks until feasible
        n += 1
        table = planner.lookup_table(n, budget)
        feas = [r for r in table if r.latency is not None]
    best = min(feas, key=lambda r: r.latency)
    emit("table3.rows", 0.0,
         f"candidates={len(table)};feasible={len(feas)};"
         f"best_points={best.points};best_ms={best.latency*1e3:.1f};"
         f"best_peak_mb={best.max_memory/1e6:.2f}")
    worst = max(feas, key=lambda r: r.latency)
    emit("table3.spread", 0.0,
         f"worst_ms={worst.latency*1e3:.1f};"
         f"gain_vs_worst={100*(1-best.latency/worst.latency):.1f}%")
