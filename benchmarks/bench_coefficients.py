"""Fig. 9: profile the four device-dependent coefficients (alpha, beta,
gamma, eta) by linear regression over real swap/execute measurements.

Profiling uses controlled synthetic blocks — size and depth varied
independently (the paper's one-off offline device profiling) — then the
fitted DelayModel drives every scheduler decision in the other benches.
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.cost_model import DelayModel
from repro.core.swap_engine import LayerStore, SwapEngine

_CACHE = {}

SIZES_MB = (2, 4, 8, 16, 32)
DEPTHS = (2, 16, 64)
REPS = 3


def _synthetic_unit(size_bytes: int, depth: int, seed: int) -> dict:
    per = max(size_bytes // depth // 4, 16)
    rng = np.random.default_rng(seed)
    return {f"t{i}": rng.standard_normal(per).astype(np.float32)
            for i in range(depth)}


def profile_delay_model(verbose: bool = False) -> DelayModel:
    if "dm" in _CACHE:
        return _CACHE["dm"]
    units = []
    for s_mb in SIZES_MB:
        for dpt in DEPTHS:
            units.append((f"u{s_mb}mb_d{dpt}",
                          _synthetic_unit(s_mb << 20, dpt, s_mb * dpt)))
    s_in, s_ex, s_out = [], [], []
    with tempfile.TemporaryDirectory() as d:
        store = LayerStore.build(units, d)
        eng = SwapEngine(store, mode="snet")
        for rep in range(REPS):
            for name, _ in units:
                h = eng.swap_in([name])
                skel = store.skeletons[name]
                if rep:                      # rep 0 warms the file cache
                    s_in.append((skel.nbytes, skel.depth, h.io_s + h.asm_s))
                t_out = eng.swap_out(h)
                if rep:
                    s_out.append((skel.depth, t_out))
        eng.close()
    # execution samples: jit matmuls of varying FLOPs
    x = jax.random.normal(jax.random.key(0), (8, 4096))
    mm = jax.jit(lambda w, xx: xx @ w)
    for k in (256, 512, 1024, 2048, 4096):
        w = jax.random.normal(jax.random.key(k), (4096, k))
        mm(w, x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            mm(w, x).block_until_ready()
        s_ex.append((2.0 * 8 * 4096 * k, (time.perf_counter() - t0) / 10))
    dm = DelayModel.fit(s_in, s_ex, s_out)
    _CACHE["dm"] = dm
    _CACHE["samples"] = (s_in, s_ex, s_out)
    return dm


def run() -> None:
    dm = profile_delay_model()
    s_in, s_ex, s_out = _CACHE["samples"]
    r2 = dm.r2_in(s_in)
    emit("fig9.alpha_us_per_mb", dm.alpha * 1e12,
         f"r2_in={r2:.3f};swap_bw_gbps={1e-9/max(dm.alpha,1e-30):.2f}")
    emit("fig9.beta_us_per_ref", dm.beta * 1e6, "per-reference assembly")
    emit("fig9.gamma_us_per_gflop", dm.gamma * 1e15, "execution slope")
    emit("fig9.eta_us_per_ref", dm.eta * 1e6, "pointer reset + gc")
