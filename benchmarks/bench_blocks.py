"""Fig. 16: more blocks -> less memory, more latency."""
from __future__ import annotations

import tempfile

import jax
import numpy as np

from benchmarks.common import build_vision, emit, vision_infos
from benchmarks.bench_coefficients import profile_delay_model
from repro.core.partition import PartitionPlanner
from repro.core.runtime import SwappedSequential
from repro.models import vision

BATCH = 4


def run() -> None:
    dm = profile_delay_model()
    kind = "resnet"
    _, layers, params, hw = build_vision(kind)
    x = jax.random.normal(jax.random.key(3), (BATCH, hw, hw, 3))
    units = [(f"{kind}{i:02d}", p) for i, p in enumerate(params)]
    infos = vision_infos(layers, params, hw, BATCH)
    planner = PartitionPlanner(infos, dm)

    for n in range(3, 8):
        table = planner.lookup_table(n, budget=float("inf"), delta=0.0)
        best = min((r for r in table if r.latency is not None),
                   key=lambda r: r.latency)
        with tempfile.TemporaryDirectory() as d:
            sw = SwappedSequential(
                units, lambda i, p, xx: vision.apply_layer(layers[i], p, xx),
                d, mode="snet")
            sw.set_plan(best.points)
            sw.forward(x)
            sw.engine.stats.__init__()
            _, st = sw.forward(x)
            sw.close()
        emit(f"fig16.blocks_{n}", st["latency_s"] * 1e6,
             f"mem_mb={st['peak_resident_mb']:.2f};"
             f"pred_ms={best.latency*1e3:.1f}")
