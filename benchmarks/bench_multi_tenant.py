"""Multi-tenant serving latency under mixed priorities: the serialized
single-executor baseline vs the concurrent priority-aware scheduler
(`core/serving_scheduler.py`).

Workload: a burst of low-priority requests for every tenant, then
high-urgency arrivals landing BEHIND them — the adversarial shape for a
FIFO executor (the high-urgency request eats the whole backlog's latency)
and the motivating case for urgency-weighted admission + block-boundary
preemption. Reports p50/p99 per priority class for both arms, the ledger
peak vs the budget (must never exceed), and the headline ratio
``hi_p99_speedup`` = serialized hi-class p99 / scheduled hi-class p99.

A third, decode-heavy arm mixes traffic kinds: a burst of low-priority
GENERATION requests (continuous-batching decode through the paged KV cache,
``submit_generate``) with high-urgency prefill requests landing mid-decode.
Decode drivers yield at decode-STEP boundaries — the decode analogue of
block-boundary preemption — so the hi class overtakes without waiting for
any sequence to retire; reported per class (``gen_lo`` / ``hi``) plus the
per-model engine stats (occupancy, preemptions, KV-pool hygiene).

Standalone CLI for the CI smoke point::

    python -m benchmarks.bench_multi_tenant --smoke
    # -> results/BENCH_multi_tenant.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time
import urllib.request

import jax
import numpy as np

from benchmarks.common import RESULTS_DIR, emit
from repro.configs import ARCHS
from repro.core.multi_model import MultiModelRuntime
from repro.core.serving_scheduler import ServingScheduler
from repro.models.transformer import Model
from repro.serving.control_plane import ControlPlane
from repro.serving.engine import Request

ARCH_SET = ("qwen2.5-3b", "gemma2-9b")
PRIO_LO, PRIO_HI = 1.0, 8.0
# tight enough that a concurrent (1/K-sliced) plan has SEVERAL blocks per
# pass — preemption happens at block boundaries, so single-block plans
# would make the preemptive arm degenerate to run-to-completion
BUDGET = 10 * 1024 * 1024
SEQ = 32
BATCH = 2
# the decode-heavy arm also reserves KV pages out of the shared budget, so
# it runs under a larger envelope to keep several weight blocks per pass
BUDGET_DECODE = 16 * 1024 * 1024


def _build_models():
    out = {}
    for i, arch in enumerate(ARCH_SET):
        cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
        model = Model(cfg)
        params = model.init(jax.random.key(i))
        rng = np.random.default_rng(i)
        batch = {"tokens": jax.numpy.asarray(
            rng.integers(0, cfg.vocab_size, (BATCH, SEQ)), jax.numpy.int32)}
        out[arch] = (model, params, batch)
    return out

def _workload(n_lo: int, n_hi: int):
    """(arch, priority) burst: lo-class first, hi-class arrives behind it."""
    lo = [(ARCH_SET[i % len(ARCH_SET)], PRIO_LO) for i in range(n_lo)]
    hi = [(ARCH_SET[i % len(ARCH_SET)], PRIO_HI) for i in range(n_hi)]
    return lo + hi


def _percentiles(lat_ms):
    return {"n": len(lat_ms),
            "p50_ms": float(np.percentile(lat_ms, 50)) if lat_ms else 0.0,
            "p99_ms": float(np.percentile(lat_ms, 99)) if lat_ms else 0.0}


def _run_arm(models, workload, executors: int, preempt: bool,
             honor_priority: bool, hi_delay_s: float = 0.08) -> dict:
    """One serving arm over a fresh runtime. ``honor_priority=False`` is
    the serialized baseline: every request submitted at the same priority,
    so admission degenerates to arrival order (FIFO) — the pre-scheduler
    behaviour — while the class label is kept for reporting.

    ``hi_delay_s`` staggers the high-urgency arrivals behind the low-class
    burst so they land while every executor is mid-pass on low-priority
    work — the case block-boundary preemption exists for (a simultaneous
    burst would let urgency-weighted admission alone serve the hi class
    first, and no pass would ever need to yield)."""
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(BUDGET, cache_frac=0.25, executors=executors)
        for arch, (model, params, _) in models.items():
            rt.add_model(arch, model, params, d)
        rt.plan(batch=BATCH, seq=SEQ)
        for arch, (_, _, batch) in models.items():
            rt.forward(arch, batch)             # warm: trace/dispatch caches
        sched = ServingScheduler(rt, executors=executors, preempt=preempt)
        label_of = {}
        submitted = []
        for arch, prio in workload:
            if prio == PRIO_HI and hi_delay_s and not any(
                    label_of[r.rid] == "hi" for r in submitted):
                time.sleep(hi_delay_s)          # land mid-pass of the burst
            r = sched.submit(arch, models[arch][2],
                             priority=prio if honor_priority else PRIO_LO)
            label_of[r.rid] = "hi" if prio == PRIO_HI else "lo"
            submitted.append(r)
        for r in submitted:
            r.wait(timeout=600)
        sched.shutdown()
        st = rt.stats()
        rt.close()
    classes = {"lo": [], "hi": []}
    for r in submitted:
        classes[label_of[r.rid]].append(r.latency_s * 1e3)
    return {
        "executors": executors,
        "preempt": preempt,
        "preemptions": sched.preemptions,
        "peak_resident_mb": st["peak_resident_mb"],
        "budget_mb": BUDGET / 1e6,
        "budget_ok": bool(st["peak_resident_mb"] * 1e6 <= BUDGET),
        "classes": {k: _percentiles(v) for k, v in classes.items()},
    }


def _http(base: str, path: str, body=None, timeout: float = 120.0):
    req = urllib.request.Request(
        base + path,
        data=(json.dumps(body).encode() if body is not None else None),
        headers={"Content-Type": "application/json"},
        method="POST" if body is not None else "GET")
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        return json.loads(resp.read())


def _run_arm_http(models, workload, executors: int = 2,
                  preempt: bool = True, hi_delay_s: float = 0.08) -> dict:
    """The scheduled arm driven PURELY over the HTTP control plane
    (serving/control_plane.py) instead of in-process ``sched.submit``:
    same runtime, same scheduler, same workload — the requests enter
    through ``POST /v1/submit`` and the latencies come back from
    ``GET /v1/requests/<rid>`` polls. Reported latency is the scheduler's
    own arrival->completion ``latency_s`` (the poll just reads it), so the
    arm measures what the HTTP SEAM adds to scheduling behaviour, not the
    client's polling cadence; the client-observed wall time is reported
    separately as ``mean_poll_overhead_ms``."""
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(BUDGET, cache_frac=0.25, executors=executors)
        for arch, (model, params, _) in models.items():
            rt.add_model(arch, model, params, d)
        rt.plan(batch=BATCH, seq=SEQ)
        for arch, (_, _, batch) in models.items():
            rt.forward(arch, batch)             # warm: trace/dispatch caches
        sched = ServingScheduler(rt, executors=executors, preempt=preempt)
        with ControlPlane(rt, sched, host="127.0.0.1", port=0) as cp:
            base = cp.url
            label_of, rids, t_submit = {}, [], {}
            hi_landed = False
            for arch, prio in workload:
                if prio == PRIO_HI and hi_delay_s and not hi_landed:
                    time.sleep(hi_delay_s)      # land mid-pass of the burst
                    hi_landed = True
                rows = np.asarray(models[arch][2]["tokens"]).tolist()
                resp = _http(base, "/v1/submit",
                             {"model": arch, "tokens": rows,
                              "priority": prio})
                rid = resp["rid"]
                label_of[rid] = "hi" if prio == PRIO_HI else "lo"
                t_submit[rid] = time.perf_counter()
                rids.append(rid)
            lat_of, overheads = {}, []
            deadline = time.monotonic() + 600
            for rid in rids:
                while True:
                    out = _http(base, f"/v1/requests/{rid}")
                    if out["status"] == "done":
                        lat_of[rid] = out["latency_s"] * 1e3
                        overheads.append(
                            (time.perf_counter() - t_submit[rid]) * 1e3
                            - lat_of[rid])
                        break
                    assert out["status"] == "pending", out
                    assert time.monotonic() < deadline, f"rid {rid} stuck"
                    time.sleep(0.02)
        sched.shutdown()
        st = rt.stats()
        rt.close()
    classes = {"lo": [], "hi": []}
    for rid in rids:
        classes[label_of[rid]].append(lat_of[rid])
    return {
        "transport": "http",
        "executors": executors,
        "preempt": preempt,
        "preemptions": sched.preemptions,
        "peak_resident_mb": st["peak_resident_mb"],
        "budget_mb": BUDGET / 1e6,
        "budget_ok": bool(st["peak_resident_mb"] * 1e6 <= BUDGET),
        "mean_poll_overhead_ms": float(np.mean(overheads)),
        "classes": {k: _percentiles(v) for k, v in classes.items()},
    }


def _http_parity(in_proc: dict, http: dict, tolerance: float) -> dict:
    """Per-class p50/p99 agreement between the in-process scheduled arm
    and the HTTP-driven one: each ratio must land in
    ``[1/tolerance, tolerance]``. Same scheduler, same workload — a ratio
    outside that band means the HTTP seam DISTORTED serving (e.g. latency
    measured from the poll loop instead of the scheduler)."""
    ratios, ok = {}, True
    for cls in ("hi", "lo"):
        for q in ("p50_ms", "p99_ms"):
            a = in_proc["classes"][cls][q]
            b = http["classes"][cls][q]
            r = (b / a) if a else float("inf")
            ratios[f"{cls}.{q}"] = r
            ok = ok and (1.0 / tolerance) <= r <= tolerance
    return {"tolerance": tolerance, "ok": bool(ok), "ratios": ratios}


def _run_decode_heavy(models, n_gen: int, n_hi: int, max_new: int = 6,
                      hi_delay_s: float = 0.05) -> dict:
    """Mixed prefill/decode traffic through the priority-aware scheduler:
    low-priority generation requests decode in continuous batches under the
    shared ledger (weights + KV pages, ONE budget), and high-urgency prefill
    requests landing behind them are served at the next decode-step
    boundary — the driver yields the batch, the hi pass runs, the batch
    resumes with its paged KV state intact."""
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(BUDGET_DECODE, cache_frac=0.2, executors=2,
                               kv_frac=0.25, page_tokens=4, max_batch=4)
        for arch, (model, params, _) in models.items():
            rt.add_model(arch, model, params, d)
        rt.plan(batch=BATCH, seq=SEQ)
        for arch, (_, _, batch) in models.items():
            rt.forward(arch, batch)             # warm: trace/dispatch caches
        sched = ServingScheduler(rt, executors=2, preempt=True)
        rng = np.random.default_rng(7)
        label_of, submitted = {}, []
        for i in range(n_gen):
            arch = ARCH_SET[i % len(ARCH_SET)]
            cfg = models[arch][0].cfg
            gr = Request(i, list(map(int, rng.integers(
                0, cfg.vocab_size, 8))), max_new_tokens=max_new)
            r = sched.submit_generate(arch, gr, priority=PRIO_LO)
            label_of[r.rid] = "gen_lo"
            submitted.append(r)
        if hi_delay_s:
            time.sleep(hi_delay_s)              # land mid-decode
        for i in range(n_hi):
            arch = ARCH_SET[i % len(ARCH_SET)]
            r = sched.submit(arch, models[arch][2], priority=PRIO_HI)
            label_of[r.rid] = "hi"
            submitted.append(r)
        for r in submitted:
            r.wait(timeout=600)
        engines = {a: rt.batch_engine(a) for a in ARCH_SET}
        eng_stats = {a: e.stats() for a, e in engines.items()}
        pool_clean = all(e.kv.pages_in_use == 0 for e in engines.values())
        sched.shutdown()
        st = rt.stats()
        rt.close()
    classes = {"gen_lo": [], "hi": []}
    for r in submitted:
        classes[label_of[r.rid]].append(r.latency_s * 1e3)
    return {
        "budget_mb": BUDGET_DECODE / 1e6,
        "workload": {"gen_lo": n_gen, "hi": n_hi, "max_new": max_new},
        "preemptions": sched.preemptions,
        "peak_resident_mb": st["peak_resident_mb"],
        "budget_ok": bool(st["peak_resident_mb"] * 1e6 <= BUDGET_DECODE),
        "kv_pool_clean": pool_clean,
        "classes": {k: _percentiles(v) for k, v in classes.items()},
        "engines": {a: {"tokens_emitted": s["tokens_emitted"],
                        "mean_occupancy": s["mean_occupancy"],
                        "preemptions": s["preemptions"],
                        "tok_per_s": s["tok_per_s"]}
                    for a, s in eng_stats.items()},
    }


def run(n_lo: int, n_hi: int, parity_tolerance: float = 4.0) -> dict:
    models = _build_models()
    workload = _workload(n_lo, n_hi)
    report = {
        "models": list(ARCH_SET),
        "budget_mb": BUDGET / 1e6,
        "workload": {"lo": n_lo, "hi": n_hi,
                     "prio_lo": PRIO_LO, "prio_hi": PRIO_HI},
        "arms": {
            "serialized": _run_arm(models, workload, executors=1,
                                   preempt=False, honor_priority=False),
            "scheduled": _run_arm(models, workload, executors=2,
                                  preempt=True, honor_priority=True),
            "scheduled_http": _run_arm_http(models, workload, executors=2,
                                            preempt=True),
        },
        "decode_heavy": _run_decode_heavy(models, n_gen=max(n_lo // 2, 2),
                                          n_hi=max(n_hi, 2)),
    }
    ser = report["arms"]["serialized"]["classes"]["hi"]["p99_ms"]
    sch = report["arms"]["scheduled"]["classes"]["hi"]["p99_ms"]
    report["hi_p99_speedup"] = ser / sch if sch else 0.0
    report["http_parity"] = _http_parity(report["arms"]["scheduled"],
                                         report["arms"]["scheduled_http"],
                                         parity_tolerance)
    assert report["http_parity"]["ok"], \
        f"HTTP arm diverged from the in-process scheduler: " \
        f"{report['http_parity']['ratios']}"
    return report


def write_report(report: dict, path: str = None) -> str:
    path = path or os.path.join(RESULTS_DIR, "BENCH_multi_tenant.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small workload: the cheap CI data point")
    ap.add_argument("--lo", type=int, default=None,
                    help="low-priority requests in the burst")
    ap.add_argument("--hi", type=int, default=None,
                    help="high-urgency requests arriving behind the burst")
    args = ap.parse_args()
    n_lo = args.lo if args.lo is not None else (8 if args.smoke else 24)
    n_hi = args.hi if args.hi is not None else (4 if args.smoke else 12)

    report = run(n_lo, n_hi)
    for arm, a in report["arms"].items():
        for cls in ("hi", "lo"):
            c = a["classes"][cls]
            emit(f"multi_tenant.{arm}.{cls}", c["p99_ms"] * 1e3,
                 f"n={c['n']};p50_ms={c['p50_ms']:.1f};"
                 f"p99_ms={c['p99_ms']:.1f};"
                 f"executors={a['executors']};"
                 f"preemptions={a['preemptions']};"
                 f"peak_mb={a['peak_resident_mb']:.1f};"
                 f"budget_ok={a['budget_ok']}")
    emit("multi_tenant.hi_p99_speedup", 0.0,
         f"serialized/scheduled={report['hi_p99_speedup']:.2f}x")
    par = report["http_parity"]
    emit("multi_tenant.http_parity", 0.0,
         f"ok={par['ok']};tolerance={par['tolerance']};"
         + ";".join(f"{k}={v:.2f}" for k, v in par["ratios"].items())
         + f";poll_overhead_ms="
           f"{report['arms']['scheduled_http']['mean_poll_overhead_ms']:.1f}")
    dh = report["decode_heavy"]
    for cls in ("hi", "gen_lo"):
        c = dh["classes"][cls]
        emit(f"multi_tenant.decode_heavy.{cls}", c["p99_ms"] * 1e3,
             f"n={c['n']};p50_ms={c['p50_ms']:.1f};p99_ms={c['p99_ms']:.1f};"
             f"preemptions={dh['preemptions']};"
             f"peak_mb={dh['peak_resident_mb']:.1f};"
             f"budget_ok={dh['budget_ok']};"
             f"kv_pool_clean={dh['kv_pool_clean']}")
    path = write_report(report)
    print(f"# multi-tenant point -> {path}", flush=True)


if __name__ == "__main__":
    main()
