"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from
results/dryrun/*.json. Usage: PYTHONPATH=src python -m benchmarks.report"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_roofline import (CHIPS, HBM_BW, LINK_BW, PEAK_FLOPS,
                                       load_rows, model_flops)
from repro.configs import ARCHS, SHAPES, applicable

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def fmt(x, digits=3):
    if x == 0:
        return "0"
    return f"{x:.{digits}g}"


def dryrun_table(mesh: str) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "dryrun",
                                              f"*__{mesh}.json"))):
        r = json.load(open(path))
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | **{r.get('error','?')[:40]}** | | | | |")
            continue
        ca, mem = r["cost_analysis"], r["memory_analysis"]
        coll = sum(v["bytes"] for v in r["collectives"].values())
        ncoll = sum(v["count"] for v in r["collectives"].values())
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok ({r['compile_s']}s) "
            f"| {fmt(ca.get('flops',0))} | {fmt(ca.get('bytes accessed',0))} "
            f"| {mem.get('argument_size_in_bytes',0)/1e9:.2f} / "
            f"{mem.get('temp_size_in_bytes',0)/1e9:.2f} "
            f"| {coll/1e9:.2f} ({ncoll}) |")
    hdr = ("| arch | shape | compile | HLO FLOPs/dev | HLO bytes/dev "
           "| args / temps (GB/dev) | collective GB/dev (#ops) |\n"
           "|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def skip_table() -> str:
    out = []
    for a in ARCHS:
        for s in SHAPES:
            if not applicable(ARCHS[a], SHAPES[s]):
                why = ("encoder-only (no decode)" if not ARCHS[a].supports_decode()
                       else "pure full attention — no sub-quadratic variant")
                out.append(f"| {a} | {s} | {why} |")
    return ("| arch | shape | reason |\n|---|---|---|\n" + "\n".join(out))


def roofline_table() -> str:
    rows = load_rows()
    out = ["| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
           "| dominant | MODEL/HLO flops | one-line fix |",
           "|---|---|---|---|---|---|---|---|"]
    notes = {
        "collective": "reduce cross-device bytes (flash-decode psum stats / "
                      "weight-stationary expert sharding)",
        "memory": "cut staged/recomputed bytes (bf16 staging, chunk remat, "
                  "seq-parallel residuals, windowed KV)",
        "compute": "at the MXU roofline — gains only from fewer FLOPs "
                   "(sparsity, caching)",
    }
    for r in rows:
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | {r['error'][:40]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['t_compute'])} "
            f"| {fmt(r['t_memory'])} | {fmt(r['t_collective'])} "
            f"| **{r['dominant']}** | {r['model_flops_ratio']:.2f} "
            f"| {notes[r['dominant']]} |")
    return "\n".join(out)


def perf_compare(base_file: str, opt_file: str) -> dict:
    b = json.load(open(base_file))
    o = json.load(open(opt_file))

    def terms(r):
        ca = r["cost_analysis"]
        coll = sum(v["bytes"] for v in r["collectives"].values())
        return {
            "flops": ca.get("flops", 0), "bytes": ca.get("bytes accessed", 0),
            "coll": coll,
            "t_c": ca.get("flops", 0) / PEAK_FLOPS,
            "t_m": ca.get("bytes accessed", 0) / HBM_BW,
            "t_n": coll / LINK_BW,
            "temp_gb": r["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9,
        }
    return {"base": terms(b), "opt": terms(o)}


if __name__ == "__main__":
    print("## §Dry-run — single-pod 16x16 (256 chips)\n")
    print(dryrun_table("16x16"))
    print("\n## §Dry-run — multi-pod 2x16x16 (512 chips)\n")
    print(dryrun_table("2x16x16"))
    print("\n### Documented skips\n")
    print(skip_table())
    print("\n## §Roofline (single-pod)\n")
    print(roofline_table())
