from repro.distributed.sharding import (
    MODEL_AXIS, DATA_AXIS, POD_AXIS, PROD_AXIS_SIZES,
    ParamDef, pspec, batch_spec, filter_spec, init_from_defs, specs_from_defs,
    stack_specs,
)
