"""Sharding rules: logical axes -> mesh axes, with divisibility downgrade.

Production meshes (launch/mesh.py):
    single-pod: (16, 16)        axes ("data", "model")
    multi-pod : (2, 16, 16)     axes ("pod", "data", "model")

Logical axes used by the model zoo:
    "residual" -> FSDP over "data" (weights gathered at use)
    "tp"       -> tensor parallel over "model" (heads / mlp hidden / vocab)
    "experts"  -> expert parallel over "model"
    None       -> replicated

The "pod" axis is pure data parallelism: parameter specs never name it, batch
specs include it when present in the mesh.
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import keystr, tree_flatten_with_path

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"

# Extents of the production mesh axes. Used for the divisibility downgrade at
# param-def time; a 1-device (smoke) mesh never consults these because smoke
# tests jit without shardings.
PROD_AXIS_SIZES = {POD_AXIS: 2, DATA_AXIS: 16, MODEL_AXIS: 16}

RULES = {
    "residual": DATA_AXIS,
    "tp": MODEL_AXIS,
    "vocab": MODEL_AXIS,
    "experts": MODEL_AXIS,
    None: None,
}


def _axis_extent(mesh_axes: Union[str, Tuple[str, ...]]) -> int:
    if isinstance(mesh_axes, str):
        return PROD_AXIS_SIZES[mesh_axes]
    return int(np.prod([PROD_AXIS_SIZES[a] for a in mesh_axes]))


def pspec(shape: Sequence[int], logical: Sequence[Optional[str]]) -> P:
    """PartitionSpec for ``shape`` given per-dim logical axes.

    A dim whose extent is not divisible by its mesh-axis extent is replicated
    instead (explicit downgrade — never silent padding).
    """
    assert len(shape) == len(logical), (shape, logical)
    out = []
    for dim, name in zip(shape, logical):
        mesh_ax = RULES.get(name, None) if isinstance(name, (str, type(None))) else name
        if mesh_ax is None or dim % _axis_extent(mesh_ax) != 0:
            out.append(None)
        else:
            out.append(mesh_ax)
    return P(*out)


def batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(a for a in (POD_AXIS, DATA_AXIS) if a in mesh.axis_names)


def batch_spec(mesh: jax.sharding.Mesh, *trailing) -> P:
    """Spec for a [batch, ...] array: batch over (pod, data)."""
    return P(batch_axes(mesh), *trailing)


def filter_spec(spec: P, mesh: jax.sharding.Mesh) -> P:
    """Drop axes not present in ``mesh`` from a PartitionSpec."""
    names = set(mesh.axis_names)

    def _f(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in names else None
        kept = tuple(a for a in entry if a in names)
        return kept if kept else None

    return P(*[_f(e) for e in spec])


# --------------------------------------------------------------------------
# Param definitions: build once, derive both init arrays and PartitionSpecs.
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | small
    scale: Optional[float] = None
    dtype: str = "float32"

    def spec(self) -> P:
        return pspec(self.shape, self.logical)


def _path_key(key: jax.Array, path: str) -> jax.Array:
    h = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def init_from_defs(defs, key: jax.Array):
    """defs: pytree (nested dicts) of ParamDef -> pytree of arrays."""
    flat, treedef = tree_flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    arrs = []
    for path, d in flat:
        pstr = keystr(path)
        dt = jnp.dtype(d.dtype)
        if d.init == "zeros":
            arrs.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            arrs.append(jnp.ones(d.shape, dt))
        else:
            scale = d.scale
            if scale is None:
                fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[-1], 1)
                scale = fan_in ** -0.5
            if d.init == "small":
                scale = 0.02
            arrs.append(scale * jax.random.normal(_path_key(key, pstr), d.shape, dt))
    return jax.tree.unflatten(jax.tree.structure(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)), arrs)


def specs_from_defs(defs):
    return jax.tree.map(lambda d: d.spec(), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


# --------------------------------------------------------------------------
# Mesh context: models call maybe_constrain() on large intermediates; it is a
# no-op unless the launcher installed a mesh (smoke tests run unconstrained).
# --------------------------------------------------------------------------
_CURRENT_MESH: Optional[jax.sharding.Mesh] = None


def set_mesh(mesh: Optional[jax.sharding.Mesh]) -> None:
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


def get_mesh() -> Optional[jax.sharding.Mesh]:
    return _CURRENT_MESH


def maybe_constrain(x: jax.Array, spec: P) -> jax.Array:
    if _CURRENT_MESH is None:
        return x
    s = filter_spec(spec, _CURRENT_MESH)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_CURRENT_MESH, s))


def stack_specs(specs, n_leading: int = 1):
    """Prepend ``n_leading`` replicated dims (for scan-stacked segments)."""
    return jax.tree.map(lambda s: P(*((None,) * n_leading), *s), specs,
                        is_leaf=lambda x: isinstance(x, P))
