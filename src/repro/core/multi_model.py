"""Multi-model swap runtime (paper §6 multi-DNN scheduling, end-to-end).

Several models co-reside under ONE memory budget:

  * a single shared :class:`MemoryLedger` spans every engine — the sum of all
    models' resident blocks, plus the shared cache, is what must fit ``b``;
  * a shared LRU :class:`BlockCache` keeps hot units (embeddings, shared
    blocks, small heads) assembled across requests, so repeat swap-ins of a
    recently-served model skip the I/O + assembly path entirely;
  * each model keeps its own depth-m prefetch pipeline; requests interleave
    at request granularity (one executor — the edge-device model), so the
    worst-case residency is ``cache + pinned + m blocks of the active model``.

The partition step reserves the cache + pinned bytes off the top and sizes
every model's blocks against the remainder, so the ledger can never exceed
the budget no matter how requests interleave.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

from repro.core.cost_model import DelayModel
from repro.core.partition import BlockPlan
from repro.core.runtime import SwappedModel
from repro.core.swap_engine import (BlockCache, MemoryLedger,
                                    size_aware_policy)
from repro.models.transformer import Model


class MultiModelRuntime:
    """Owner of the shared ledger + cache and the per-model swapped runtimes.

    Usage::

        rt = MultiModelRuntime(budget=64e6, cache_frac=0.25)
        rt.add_model("qwen", model_a, params_a, workdir)
        rt.add_model("gemma", model_b, params_b, workdir)
        rt.plan(batch=2, seq=32)
        logits, stats = rt.forward("qwen", batch)       # interleave freely
    """

    def __init__(self, budget: int, mode: str = "snet",
                 prefetch_depth: int = 2, cache_frac: float = 0.25,
                 dm: Optional[DelayModel] = None, delta: float = 0.05,
                 store_backend: Optional[str] = None,
                 precision: Optional[str] = None):
        assert 0.0 <= cache_frac < 1.0
        self.budget = int(budget)
        self.mode = mode
        self.store_backend = store_backend
        self.precision = precision
        self.prefetch_depth = max(prefetch_depth, 1)
        self.delta = delta
        self.dm = dm if dm is not None else DelayModel()
        self.ledger = MemoryLedger(self.budget)
        self.cache = BlockCache(int(self.budget * cache_frac), self.ledger)
        self.models: Dict[str, SwappedModel] = {}
        self._planned = False

    # ------------------------------------------------------------ registry
    def add_model(self, name: str, model: Model, params: dict,
                  workdir: str,
                  store_backend: Optional[str] = None,
                  precision: Optional[str] = None) -> SwappedModel:
        """``store_backend`` overrides the runtime default per model (a
        quant-ineligible config falls back to mmap either way);
        ``precision`` overrides the config's per-model swap precision
        (int8 | int4) for the quant backend."""
        assert name not in self.models, f"duplicate model name {name!r}"
        backend = store_backend or self.store_backend
        sm = SwappedModel(model, params, os.path.join(workdir, name),
                          mode=self.mode, prefetch_depth=self.prefetch_depth,
                          ledger=self.ledger, cache=self.cache, name=name,
                          store_backend=backend,
                          precision=precision or self.precision)
        self.models[name] = sm
        self._planned = False
        return sm

    def _pinned_bytes(self) -> int:
        """Bytes the engines will pin into the cache regardless of capacity
        (shared blocks): reserved off the top of every model's block budget.
        Pinned units cost their RESIDENT bytes (quantized backends pin the
        quantized payload)."""
        total = 0
        for sm in self.models.values():
            # the ENGINE's store is the mode-resolved reader (copy_in /
            # dummy_asm attach a 2-3x-residency view over the built store)
            total += sum(sm.engine.store.resident_nbytes(n)
                         for n in sm.engine.pinned
                         if n in sm.store.skeletons)
        return total

    def block_budget(self) -> int:
        """What is left for one model's resident blocks after the shared
        cache and the pinned units take their cut."""
        return self.budget - self.cache.capacity - self._pinned_bytes()

    # ------------------------------------------------------------ planning
    def plan(self, batch: int, seq: int) -> Dict[str, BlockPlan]:
        """Partition every registered model against the shared budget.

        Call after ALL models are registered: the cache + pinned reserve
        depends on the full co-resident set."""
        b = self.block_budget()
        if b <= 0:
            raise ValueError(
                f"budget {self.budget/1e6:.1f} MB leaves no room for blocks "
                f"after cache {self.cache.capacity/1e6:.1f} MB + pinned "
                f"{self._pinned_bytes()/1e6:.1f} MB")
        plans = {}
        for name, sm in self.models.items():
            plans[name] = sm.partition(b, self.dm, batch, seq,
                                       delta=self.delta)
        # Cache admission informed by the partition tables' per-unit sizes
        # (ROADMAP item (d)): admit exactly the units that provably co-fit,
        # costed at their resident bytes (what a cache entry charges). The
        # ENGINE's store is the mode-resolved reader, whose resident cost
        # includes any ablation-mode extra copies.
        sizes = {n: sm.engine.store.resident_nbytes(n)
                 for sm in self.models.values() for n in sm.store.order}
        self.cache.set_policy(size_aware_policy(sizes, self.cache.capacity))
        self._planned = True
        return plans

    # ------------------------------------------------------------ serving
    def forward(self, name: str, batch: dict) -> Tuple[Any, Dict]:
        assert self._planned, "call plan() after registering all models"
        return self.models[name].forward(batch)

    def decode(self, name: str, prompt_tokens, max_new_tokens: int = 8,
               max_len: int = 128) -> Tuple[Any, Dict]:
        assert self._planned, "call plan() after registering all models"
        return self.models[name].decode_loop(prompt_tokens, max_new_tokens,
                                             max_len)

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, Any]:
        per_model = {}
        for name, sm in self.models.items():
            st = sm.engine.stats
            per_model[name] = {
                "n_blocks": sm.plan.n_blocks if sm.plan else None,
                "m": sm.plan.m if sm.plan else None,
                "overlap_efficiency": st.overlap_efficiency(),
                "cache_hit_rate": st.cache_hit_rate(),
                "bytes_swapped_mb": st.bytes_swapped / 1e6,
                "bytes_logical_mb": st.bytes_logical / 1e6,
                "bytes_resident_quantized_mb":
                    st.bytes_resident_quantized / 1e6,
                "vmem_working_set_mb": st.vmem_working_set / 1e6,
                "store_backend": sm.store_backend,
                "precision": sm.precision,
            }
        return {
            "budget_mb": self.budget / 1e6,
            "peak_resident_mb": self.ledger.peak / 1e6,
            "cache_capacity_mb": self.cache.capacity / 1e6,
            "cache_resident_mb": self.cache.resident_bytes / 1e6,
            "cache_hit_rate": self.cache.hit_rate(),
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "models": per_model,
        }

    def close(self) -> None:
        for sm in self.models.values():
            sm.close()
        self.cache.clear()
