"""Multi-model swap runtime (paper §6 multi-DNN scheduling, end-to-end).

Several models co-reside under ONE memory budget:

  * a single shared :class:`MemoryLedger` spans every engine — the sum of all
    models' resident blocks, plus the shared cache, is what must fit ``b``;
  * a shared LRU :class:`BlockCache` keeps hot units (embeddings, shared
    blocks, small heads) assembled across requests, so repeat swap-ins of a
    recently-served model skip the I/O + assembly path entirely;
  * each model keeps its own depth-m prefetch pipeline; requests interleave
    at request granularity (one executor — the edge-device model), so the
    worst-case residency is ``cache + pinned + m blocks of the active model``.

The partition step reserves the cache + pinned bytes off the top and sizes
every model's blocks against the remainder, so the ledger can never exceed
the budget no matter how requests interleave.

With ``executors=K > 1`` the runtime supports K truly CONCURRENT passes
(one per model at a time — the serving scheduler serializes same-model
requests): each model's blocks are planned against a 1/K slice of the block
budget so any K co-running pipelines provably co-fit, engines switch to the
ledger's blocking ``reserve()`` (priority wakeup) instead of the raising
``add()``, and :meth:`MultiModelRuntime.replan_budgets` re-splits the block
budget with :class:`MultiDNNScheduler` (Eq. 1, urgency-weighted) when the
live queue mix shifts.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Mapping, Optional, Tuple

from repro.core.cost_model import DelayModel
from repro.core.partition import BlockPlan
from repro.core.runtime import PassState, SwappedModel
from repro.core.scheduler import MultiDNNScheduler, ScheduledModel
from repro.core.swap_engine import (BlockCache, MemoryLedger,
                                    size_aware_policy)
from repro.models.transformer import Model


class MultiModelRuntime:
    """Owner of the shared ledger + cache and the per-model swapped runtimes.

    Usage::

        rt = MultiModelRuntime(budget=64e6, cache_frac=0.25)
        rt.add_model("qwen", model_a, params_a, workdir)
        rt.add_model("gemma", model_b, params_b, workdir)
        rt.plan(batch=2, seq=32)
        logits, stats = rt.forward("qwen", batch)       # interleave freely
    """

    def __init__(self, budget: int, mode: str = "snet",
                 prefetch_depth: int = 2, cache_frac: float = 0.25,
                 dm: Optional[DelayModel] = None, delta: float = 0.05,
                 store_backend: Optional[str] = None,
                 precision: Optional[str] = None,
                 executors: int = 1,
                 reserve_timeout: Optional[float] = 30.0,
                 kv_frac: float = 0.0, page_tokens: int = 16,
                 max_batch: int = 8,
                 fidelity: Optional[float] = None,
                 calib_method: str = "output",
                 calib_seed: int = 0):
        assert 0.0 <= cache_frac < 1.0
        assert 0.0 <= kv_frac < 1.0 and cache_frac + kv_frac < 1.0
        self.budget = int(budget)
        # paged-KV serving reserve: kv_frac of the budget is carved out for
        # KV pages (serving/paged_kv.py) before blocks are planned, so weight
        # streaming and decode batches provably co-fit under ONE ledger
        self.kv_frac = float(kv_frac)
        self.page_tokens = int(page_tokens)
        self.max_batch = int(max_batch)
        self._batch_engines: Dict[str, Any] = {}
        self.mode = mode
        self.store_backend = store_backend
        self.precision = precision
        # mixed-precision knobs: the fidelity target the auto-calibration in
        # add_model solves against (see repro/calibrate/), plus the profiler
        # method/seed so registration stays deterministic
        self.fidelity = fidelity
        self.calib_method = calib_method
        self.calib_seed = int(calib_seed)
        self.prefetch_depth = max(prefetch_depth, 1)
        self.delta = delta
        self.executors = max(int(executors), 1)
        self.reserve_timeout = reserve_timeout
        self.dm = dm if dm is not None else DelayModel()
        self.ledger = MemoryLedger(self.budget)
        self.cache = BlockCache(int(self.budget * cache_frac), self.ledger)
        self.models: Dict[str, SwappedModel] = {}
        self._planned = False

    @classmethod
    def from_config(cls, cfg) -> "MultiModelRuntime":
        """Construct from a resolved :class:`repro.config.ServeConfig` —
        the launcher/scheduler seam: every knob that used to be a
        positional flag threads through the config's ``runtime`` section.
        Requires ``runtime.budget_mb`` (a budget IS the runtime's reason to
        exist); the KV reserve is carved only when paging is on."""
        rt_cfg = cfg.runtime
        if rt_cfg.budget_mb is None:
            raise ValueError("runtime.budget_mb is required to build a "
                             "MultiModelRuntime (unswapped serving has no "
                             "shared ledger)")
        return cls(int(rt_cfg.budget_mb * 1e6),
                   prefetch_depth=rt_cfg.prefetch_depth,
                   cache_frac=rt_cfg.cache_frac,
                   store_backend=rt_cfg.store,
                   precision=rt_cfg.precision,
                   executors=rt_cfg.executors,
                   kv_frac=rt_cfg.kv_frac if rt_cfg.paged else 0.0,
                   page_tokens=rt_cfg.page_tokens,
                   max_batch=rt_cfg.max_batch,
                   fidelity=rt_cfg.fidelity)

    # ------------------------------------------------------------ registry
    def add_model(self, name: str, model: Model, params: dict,
                  workdir: str,
                  store_backend: Optional[str] = None,
                  precision: Optional[str] = None,
                  store_options: Optional[dict] = None) -> SwappedModel:
        """``store_backend`` overrides the runtime default per model (a
        quant-ineligible config falls back to mmap either way);
        ``precision`` overrides the config's per-model swap precision
        (int8 | int4) for the quant backend; ``store_options`` passes extra
        backend build options through (the faulty backend's ``inner`` /
        ``p`` / ``seed`` knobs — how the chaos suite wires fault injection
        into ONE tenant of a shared-ledger runtime).

        With ``precision='mixed'`` (per model or runtime-wide) and no
        explicit ``plan`` in ``store_options``, registration runs the
        calibration pass HERE — profile the arriving model on a synthetic
        batch, solve the precision assignment against ``self.fidelity``,
        and build the quant store from the resulting plan."""
        assert name not in self.models, f"duplicate model name {name!r}"
        backend = store_backend or self.store_backend
        eff_precision = precision or self.precision
        if (backend == "quant" and eff_precision == "mixed"
                and model.cfg.quant_eligible
                and (store_options or {}).get("plan") is None):
            if self.fidelity is None:
                raise ValueError(
                    "precision='mixed' needs a fidelity target: construct "
                    "the runtime with fidelity=... (runtime.fidelity)")
            from repro.calibrate import calibrate_model
            _, plan = calibrate_model(
                model, params, fidelity=self.fidelity,
                method=self.calib_method, seed=self.calib_seed, name=name,
                prefetch_depth=self.prefetch_depth)
            store_options = dict(store_options or {})
            store_options["plan"] = plan
        sm = SwappedModel(model, params, os.path.join(workdir, name),
                          mode=self.mode, prefetch_depth=self.prefetch_depth,
                          ledger=self.ledger, cache=self.cache, name=name,
                          store_backend=backend,
                          precision=precision or self.precision,
                          store_options=store_options)
        if self.executors > 1:
            # concurrent passes: a transiently full ledger means WAIT for
            # another tenant's swap-out (priority wakeup), not fail
            sm.engine.reserve_blocking = True
            sm.engine.reserve_timeout = self.reserve_timeout
        self.models[name] = sm
        self._planned = False
        return sm

    def _pinned_bytes(self) -> int:
        """Bytes the engines will pin into the cache regardless of capacity
        (shared blocks): reserved off the top of every model's block budget.
        Pinned units cost their RESIDENT bytes (quantized backends pin the
        quantized payload)."""
        total = 0
        for sm in self.models.values():
            # the ENGINE's store is the mode-resolved reader (copy_in /
            # dummy_asm attach a 2-3x-residency view over the built store)
            total += sum(sm.engine.store.resident_nbytes(n)
                         for n in sm.engine.pinned
                         if n in sm.store.skeletons)
        return total

    def kv_reserve(self) -> int:
        """Bytes carved out of the budget for paged-KV decode batches."""
        return int(self.budget * self.kv_frac)

    def block_budget(self) -> int:
        """What is left for one model's resident blocks after the shared
        cache, the pinned units, and the KV-page reserve take their cut."""
        return (self.budget - self.cache.capacity - self._pinned_bytes()
                - self.kv_reserve())

    # ------------------------------------------------------------ planning
    def plan(self, batch: int, seq: int) -> Dict[str, BlockPlan]:
        """Partition every registered model against the shared budget.

        Call after ALL models are registered: the cache + pinned reserve
        depends on the full co-resident set. With ``executors=K`` each model
        is planned against a 1/K slice of the block budget, so ANY K
        concurrently running pipelines (one per model) co-fit: K windows of
        at most b/K bytes each, plus cache + pinned, stay under ``budget``
        no matter how the scheduler interleaves them."""
        b = self.block_budget()
        if b <= 0:
            raise ValueError(
                f"budget {self.budget/1e6:.1f} MB leaves no room for blocks "
                f"after cache {self.cache.capacity/1e6:.1f} MB + pinned "
                f"{self._pinned_bytes()/1e6:.1f} MB")
        per_exec = b // min(self.executors, max(len(self.models), 1))
        if per_exec <= 0:
            raise ValueError(
                f"block budget {b/1e6:.1f} MB split across "
                f"{self.executors} executors leaves none per pipeline")
        plans = {}
        for name, sm in self.models.items():
            plans[name] = sm.partition(per_exec, self.dm, batch, seq,
                                       delta=self.delta)
        # Cache admission informed by the partition tables' per-unit sizes
        # (ROADMAP item (d)): admit exactly the units that provably co-fit,
        # costed at their resident bytes (what a cache entry charges). The
        # ENGINE's store is the mode-resolved reader, whose resident cost
        # includes any ablation-mode extra copies.
        sizes = {n: sm.engine.store.resident_nbytes(n)
                 for sm in self.models.values() for n in sm.store.order}
        self.cache.set_policy(size_aware_policy(sizes, self.cache.capacity))
        self._planned = True
        return plans

    def replan_budgets(self, urgencies: Mapping[str, float]) -> Dict[str, float]:
        """React to the live queue mix: re-split the block budget across
        models with :class:`MultiDNNScheduler` (Eq. 1) instead of the uniform
        1/K slice, weighting each model by the urgency of its queued work.

        Cheap — partition lookup tables are memoized per planner, so this is
        the paper's 60-70 ms re-selection path, not a re-profile. Per-model
        budgets sum to the block budget, so ANY subset of models running
        concurrently still co-fits (Eq. 1 slices are disjoint). Plans swap
        atomically; passes already in flight keep their snapshotted block
        list (``PassState.blocks``). Returns the new per-model budgets."""
        assert self._planned, "call plan() before replan_budgets()"
        scheduled = [ScheduledModel(name, sm.planner,
                                    urgency=max(float(urgencies.get(name, 1.0)),
                                                1e-6))
                     for name, sm in self.models.items()]
        reserved = float(self.cache.capacity + self._pinned_bytes()
                         + self.kv_reserve())
        sched = MultiDNNScheduler(scheduled, available=float(self.budget),
                                  delta=self.delta, reserved=reserved)
        for s in sched.models:
            sm = self.models[s.name]
            sm.plan, sm.table = s.plan, s.table
        return {s.name: s.budget for s in sched.models}

    # ------------------------------------------------------------ serving
    def forward(self, name: str, batch: dict) -> Tuple[Any, Dict]:
        assert self._planned, "call plan() after registering all models"
        return self.models[name].forward(batch)

    def forward_partial(self, name: str, batch: dict,
                        state: Optional[PassState] = None,
                        should_yield=None,
                        priority: float = 0.0) -> Tuple[PassState, Optional[Dict]]:
        """Resumable swapped pass for one model (the serving scheduler's
        entry point): ``priority`` tags the engine so its swap-ins get
        priority wakeup on the shared ledger; ``should_yield`` is consulted
        at every block boundary (see :meth:`SwappedModel.forward_partial`).
        Same-model calls must be serialized by the caller."""
        assert self._planned, "call plan() after registering all models"
        sm = self.models[name]
        sm.engine.set_priority(priority)
        return sm.forward_partial(batch, state=state, should_yield=should_yield)

    def batch_engine(self, name: str):
        """The model's continuous-batching decode engine
        (:class:`~repro.serving.batch_engine.BatchDecodeEngine`), built
        lazily on first use: its KV page pool is sized from an equal split
        of the KV reserve and charged to the SHARED ledger, so decode
        batches of one tenant squeeze against every tenant's weight blocks.
        Requires ``kv_frac > 0`` and a decode-capable uniform-attention
        model (see ``PagedKVCache``)."""
        assert self._planned, "call plan() after registering all models"
        if name not in self._batch_engines:
            if self.kv_reserve() <= 0:
                raise ValueError(
                    "paged decode needs a KV reserve: construct the runtime "
                    "with kv_frac > 0")
            from repro.serving.batch_engine import BatchDecodeEngine
            from repro.serving.paged_kv import PagedKVCache
            sm = self.models[name]
            kv = PagedKVCache.for_budget(
                sm.cfg, self.ledger,
                self.kv_reserve() // max(len(self.models), 1),
                page_tokens=self.page_tokens, name=name)
            self._batch_engines[name] = BatchDecodeEngine(
                sm, kv, max_batch=self.max_batch)
        return self._batch_engines[name]

    def decode(self, name: str, prompt_tokens, max_new_tokens: int = 8,
               max_len: int = 128) -> Tuple[Any, Dict]:
        assert self._planned, "call plan() after registering all models"
        return self.models[name].decode_loop(prompt_tokens, max_new_tokens,
                                             max_len)

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, Any]:
        per_model = {}
        for name, sm in self.models.items():
            st = sm.engine.stats
            per_model[name] = {
                "n_blocks": sm.plan.n_blocks if sm.plan else None,
                "m": sm.plan.m if sm.plan else None,
                "overlap_efficiency": st.overlap_efficiency(),
                "cache_hit_rate": st.cache_hit_rate(),
                "bytes_swapped_mb": st.bytes_swapped / 1e6,
                "bytes_logical_mb": st.bytes_logical / 1e6,
                "bytes_resident_quantized_mb":
                    st.bytes_resident_quantized / 1e6,
                "bytes_by_precision_mb": {
                    p: b / 1e6 for p, b in st.bytes_by_precision.items()},
                "vmem_working_set_mb": st.vmem_working_set / 1e6,
                "store_backend": sm.store_backend,
                "precision": sm.precision,
                "retries": st.retries,
                "faults": dict(st.faults),
            }
        return {
            "budget_mb": self.budget / 1e6,
            "peak_resident_mb": self.ledger.peak / 1e6,
            "cache_capacity_mb": self.cache.capacity / 1e6,
            "cache_resident_mb": self.cache.resident_bytes / 1e6,
            "cache_hit_rate": self.cache.hit_rate(),
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "models": per_model,
        }

    def close(self) -> None:
        for sm in self.models.values():
            sm.close()
        self.cache.clear()
