"""Block partitioning (paper §3, §6.2.2): layers -> blocks.

Implements the paper's operations:
  1) ``get_layers``      — initial layer-wise division (one-time per DNN);
  2) partition-point search over the allocated budget (lookup table, Table 3);
  3) ``create_blocks``   — assemble blocks from partition points (index-only,
     ~60-70 ms adaptation when the budget changes; here it is pure index math).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import DelayModel, LayerInfo

MAX_EXHAUSTIVE = 20_000


@dataclass(frozen=True)
class BlockPlan:
    """A partition scheme p = {p_1..p_{n-1}} over L layers (paper notation:
    p_i are layer indices; block i covers [p_{i-1}, p_i)). ``m`` is the
    residency the plan was sized for: the executor may hold at most m blocks
    at once — 1 = degraded serial (no prefetch), 2 = the paper's double
    buffer, m > 2 = deeper prefetch pipelines that absorb swap-in jitter."""
    points: Tuple[int, ...]
    n_layers: int
    m: int = 2

    @property
    def n_blocks(self) -> int:
        return len(self.points) + 1

    def blocks(self) -> List[Tuple[int, int]]:
        bounds = (0,) + self.points + (self.n_layers,)
        return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]


def get_layers(infos: Sequence[LayerInfo]) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Layer-wise arrays (sizes, depths, flops) — the smallest divisible units."""
    return (np.asarray([i.size for i in infos], np.float64),
            np.asarray([i.depth for i in infos], np.float64),
            np.asarray([i.flops for i in infos], np.float64))


def create_blocks(plan: BlockPlan, sizes, depths, flops):
    """Aggregate per-layer stats into per-block (s_i, d_i, f_i)."""
    s, d, f = [], [], []
    for lo, hi in plan.blocks():
        s.append(float(np.sum(sizes[lo:hi])))
        d.append(float(np.sum(depths[lo:hi])))
        f.append(float(np.sum(flops[lo:hi])))
    return np.asarray(s), np.asarray(d), np.asarray(f)


def simulate_pipeline(s, d, f, dm: DelayModel, m: int = 2) -> float:
    """Exact makespan of the depth-m prefetch pipeline: one swap-in channel,
    one executor; swap-in of block i may start only once block i-m has been
    swapped out (memory holds at most m blocks). m=2 is the paper's double
    buffer; m=1 is strictly serial; m>2 prefetches deeper."""
    assert m >= 1
    n = len(s)
    t_in = [dm.t_in(s[i], d[i]) for i in range(n)]
    t_ex = [dm.t_ex(f[i]) for i in range(n)]
    t_out = [dm.t_out(d[i]) for i in range(n)]
    load_done = [0.0] * n
    exec_done = [0.0] * n
    freed = [0.0] * n
    for i in range(n):
        start = load_done[i - 1] if i else 0.0
        if i >= m:
            start = max(start, freed[i - m])
        load_done[i] = start + t_in[i]
        exec_start = max(load_done[i], exec_done[i - 1] if i else 0.0)
        exec_done[i] = exec_start + t_ex[i]
        freed[i] = exec_done[i] + t_out[i]
    return freed[-1]


def paper_objective(s, d, f, dm: DelayModel) -> float:
    """The paper's Eq. 4 surrogate: sum_i max(t_i^ov, 0) with
    t_i^ov = (t_{i-1}^out + t_{i+1}^in) - (t_i^ex + t_{i-1}^ov)."""
    n = len(s)
    total, prev_ov = 0.0, 0.0
    for i in range(1, n):
        t_next_in = dm.t_in(s[i], d[i])
        ov = (dm.t_out(d[i - 1]) + t_next_in) - (dm.t_ex(f[i - 1]) + prev_ov)
        total += max(ov, 0.0)
        prev_ov = max(ov, 0.0)
    return total


def n_blocks_for_budget(total_size: float, budget: float, m: int = 2) -> int:
    """Paper: n = ceil(m * s / b)."""
    return max(m, int(math.ceil(m * total_size / max(budget, 1.0))))


@dataclass
class TableRow:
    points: Tuple[int, ...]
    max_memory: float        # peak bytes with m resident (max m-block window)
    latency: Optional[float]  # None -> "exceed"


def plan_peak_bytes(sizes: np.ndarray, m: int) -> float:
    """Peak weight residency of a block-size vector under depth-m residency:
    the largest sum over any window of min(m, n) consecutive blocks."""
    n = len(sizes)
    w = min(max(m, 1), n)
    csum = np.concatenate([[0.0], np.cumsum(sizes)])
    return float(np.max(csum[w:] - csum[:-w]))


class PartitionPlanner:
    """Builds the run-time lookup table (Table 3) and picks partitions."""

    def __init__(self, infos: Sequence[LayerInfo], dm: DelayModel, m: int = 2):
        self.infos = list(infos)
        self.sizes, self.depths, self.flops = get_layers(infos)
        self.dm = dm
        self.m = m
        self.L = len(self.infos)
        self._rows_cache: dict = {}   # (n, m) -> [(points, peak, latency)]

    # -------------------------------------------------- candidate generation
    def _candidates(self, n: int) -> List[Tuple[int, ...]]:
        if n == 1:
            return [()]
        n_comb = math.comb(self.L - 1, n - 1)
        if n_comb <= MAX_EXHAUSTIVE:
            return list(itertools.combinations(range(1, self.L), n - 1))
        # large search space: seeded local search around the equal-bytes split
        return self._local_candidates(n)

    def _equal_split(self, n: int) -> Tuple[int, ...]:
        csum = np.cumsum(self.sizes)
        targets = [csum[-1] * k / n for k in range(1, n)]
        pts = sorted({int(np.searchsorted(csum, t)) + 1 for t in targets})
        pts = [min(max(p, 1), self.L - 1) for p in pts]
        # de-dup while keeping strictly increasing
        out = []
        for p in pts:
            while p in out or p < 1:
                p += 1
            if p < self.L:
                out.append(p)
        while len(out) < n - 1:
            cand = 1
            while cand in out:
                cand += 1
            out.append(cand)
        return tuple(sorted(out[:n - 1]))

    def _score(self, pts: Tuple[int, ...]) -> float:
        plan = BlockPlan(pts, self.L)
        s, d, f = create_blocks(plan, self.sizes, self.depths, self.flops)
        return simulate_pipeline(s, d, f, self.dm, self.m)

    def _local_candidates(self, n: int, radius: int = 3, rounds: int = 5,
                          beam: int = 24) -> List[Tuple[int, ...]]:
        """Beam-limited local search seeded at the equal-bytes split (the
        exhaustive table is infeasible for large L x n)."""
        seen = set()
        out: List[Tuple[int, ...]] = []
        cur = {self._equal_split(n)}
        for _ in range(rounds):
            fresh = [p for p in cur if p not in seen]
            seen.update(fresh)
            out.extend(fresh)
            neigh = set()
            for pts in cur:
                for j in range(len(pts)):
                    for dlt in range(-radius, radius + 1):
                        if not dlt:
                            continue
                        q = list(pts)
                        q[j] = min(max(q[j] + dlt, 1), self.L - 1)
                        q = tuple(sorted(set(q)))
                        if len(q) == n - 1 and q not in seen:
                            neigh.add(q)
            if not neigh:
                break
            cur = set(sorted(neigh, key=self._score)[:beam])
        return out or [self._equal_split(n)]

    # -------------------------------------------------- table + selection
    def _rows(self, n: int, m: int):
        """Budget-INDEPENDENT rows (points, peak, latency), memoized — the
        paper precomputes the lookup tables offline and prunes by the current
        budget at run time (its 60-70 ms adaptation path)."""
        key = (n, m)
        if key not in self._rows_cache:
            rows = []
            for pts in self._candidates(n):
                plan = BlockPlan(pts, self.L)
                s, d, f = create_blocks(plan, self.sizes, self.depths,
                                        self.flops)
                peak = plan_peak_bytes(s, m)
                rows.append((pts, peak,
                             simulate_pipeline(s, d, f, self.dm, m)))
            self._rows_cache[key] = rows
        return self._rows_cache[key]

    def prewarm(self, budgets: Sequence[float]) -> None:
        """Precompute tables for the block counts the given budgets imply."""
        total = float(np.sum(self.sizes))
        for b in budgets:
            n0 = min(max(n_blocks_for_budget(total, b, self.m), 1), self.L)
            for n in range(n0, min(n0 + 3, self.L) + 1):
                self._rows(n, self.m)

    def lookup_table(self, n: int, budget: float, delta: float = 0.05,
                     m: Optional[int] = None) -> List[TableRow]:
        """Table 3: every candidate partition with peak memory and predicted
        latency; infeasible rows (Eq. 3 violated) carry latency=None."""
        m = self.m if m is None else m
        return [TableRow(pts, peak,
                         lat if peak <= budget * (1.0 - delta) else None)
                for pts, peak, lat in self._rows(n, m)]

    def min_feasible_budget(self, delta: float = 0.05) -> float:
        """Smallest budget any partition can satisfy: with m=1 degradation the
        floor is the largest single layer (plus the reserve delta)."""
        return float(np.max(self.sizes)) / (1.0 - delta) + 1.0

    def best_partition(self, budget: float, delta: float = 0.05,
                       max_extra_blocks: int = 8,
                       allow_degrade: bool = True,
                       improve_tol: float = 0.01) -> Tuple[BlockPlan, List[TableRow]]:
        """Pick the feasible partition with the least SIMULATED latency over
        a range of block counts, starting at the paper's n = ceil(m*s/b).

        The paper stops at the first feasible n — correct for its byte-bound
        workloads, but it under-pipelines backends whose resident bytes are
        far below the budget (the quantized/fused stores): the budget admits
        the whole model in m blocks, so the plan degenerates to n == m and
        the cold first block — half the model — can never be hidden behind
        compute. Searching upward from n0 lets ``simulate_pipeline`` trade
        a smaller exposed first block against the per-block fixed cost
        (``DelayModel.kappa``); the search stops after two consecutive block
        counts fail to improve the best makespan by ``improve_tol``.

        If no candidate fits even at single-layer blocks, progressively
        shallow the pipeline down to m=1 — sequential swapping with no
        overlap — before giving up (a below-paper-minimum budget)."""
        total = float(np.sum(self.sizes))
        depths = tuple(range(self.m, 0, -1)) if allow_degrade else (self.m,)
        for m in depths:
            n0 = min(max(n_blocks_for_budget(total, budget, m), 1), self.L)
            best_row = best_table = best_m = None
            stale = 0
            for n in range(n0, min(n0 + max_extra_blocks, self.L) + 1):
                table = self.lookup_table(n, budget, delta, m=m)
                feasible = [r for r in table if r.latency is not None]
                if not feasible:
                    continue
                row = min(feasible, key=lambda r: r.latency)
                if (best_row is None
                        or row.latency < best_row.latency * (1 - improve_tol)):
                    best_row, best_table, best_m = row, table, m
                    stale = 0
                else:
                    stale += 1
                    if stale >= 2:      # two counts without improvement
                        break
            if best_row is not None:
                return BlockPlan(best_row.points, self.L, best_m), best_table
        raise ValueError(
            f"no feasible partition within budget {budget/1e6:.1f} MB "
            f"(largest layer exceeds it even with m=1)")
