"""SwapNet core: block swapping controller, assembly-by-reference skeleton,
delay abstractions, budget allocation, multi-DNN scheduling (paper §3-§6)."""
from repro.core.budget import ModelDemand, allocate_budgets, performance_score
from repro.core.cost_model import DelayModel, LayerInfo, layer_flops
from repro.core.multi_model import MultiModelRuntime
from repro.core.partition import (BlockPlan, PartitionPlanner, TableRow,
                                  create_blocks, n_blocks_for_budget,
                                  paper_objective, plan_peak_bytes,
                                  simulate_pipeline)
from repro.core.runtime import (SwappedModel, Unit, split_units, swap_schedule,
                                unit_infos)
from repro.core.scheduler import MultiDNNScheduler, ScheduledModel
from repro.core.skeleton import (Skeleton, assemble, assemble_dummy,
                                 assemble_np, flatten_params)
from repro.core.swap_engine import (BlockCache, LayerStore, MemoryLedger,
                                    MmapStore, QuantizedStore, RawIOStore,
                                    SwapEngine, size_aware_policy)
