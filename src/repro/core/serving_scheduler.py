"""Priority/urgency-aware concurrent request scheduling (paper §6, grown
into an actual serving system).

The single-executor :class:`~repro.serving.engine.MultiModelServingEngine`
serializes every request — a high-urgency request queued behind a batch
tenant's full pass eats that pass's whole latency. This module adds the
serving layer the multi-DNN showcase implies:

  * :class:`ServingRequest`  — one unit of work (model, batch, priority,
    optional deadline); admission order is the urgency-weighted deadline
    ``arrival + slack / priority`` (weighted EDF: urgency divides the slack,
    so a priority-8 request with the same slack sorts like one whose
    deadline is 8x nearer; aging via ``arrival`` prevents starvation —
    preempted or passed-over requests keep their original arrival and
    eventually become the most urgent work in the queue);
  * :class:`RequestQueue`    — thread-safe admission queue over that order,
    with model-busy filtering (same-model passes must serialize: one
    engine, one prefetch pipeline per model);
  * :class:`ServingScheduler` — K executor threads over one planned
    :class:`~repro.core.multi_model.MultiModelRuntime`. Different models
    run truly concurrently (the runtime plans 1/K block-budget slices so
    K pipelines co-fit; the shared ledger's blocking ``reserve()`` with
    priority wakeup covers transients). A running pass is PREEMPTED at
    block boundaries: when strictly-higher-priority work is waiting, the
    executor parks the pass (its :class:`~repro.core.runtime.PassState`
    carries the activation + next block; in-flight prefetches are drained,
    so only cache-resident bytes stay charged), requeues it, and takes the
    urgent request — a high-urgency arrival never waits for a whole foreign
    model pass, only for the current block.

Optionally (``auto_rebalance=True``) the scheduler feeds the live queue
mix's per-model urgencies into ``MultiModelRuntime.replan_budgets`` (Eq. 1
via :class:`~repro.core.scheduler.MultiDNNScheduler` with the cache +
pinned bytes reserved), so block plans track WHO is actually asking for
service, not just who is registered.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.multi_model import MultiModelRuntime
from repro.core.runtime import PassState
from repro.errors import RequestCancelled, SwapError, SwapTimeoutError

__all__ = ["ServingRequest", "RequestQueue", "ServingScheduler"]


@dataclass
class ServingRequest:
    """One prefill request against a named model of the runtime.

    ``priority`` is the paper's urgency u (higher = more urgent);
    ``deadline`` is a relative slack in seconds (None = the queue's default).
    The scheduler fills ``arrival`` on submit and ``logits`` / ``stats`` /
    ``latency_s`` on completion; ``error`` carries a failed pass's exception
    instead of losing it on an executor thread.

    ``kind="generate"`` requests (``submit_generate``) carry a decode
    request ``gen`` (:class:`repro.serving.engine.Request`) instead of a
    prefill batch: the executor drives the model's continuous-batching
    engine until that sequence retires, yielding at decode-step boundaries
    the way prefill passes yield at block boundaries."""
    model: str
    batch: dict
    priority: float = 1.0
    deadline: Optional[float] = None
    rid: int = 0
    arrival: float = 0.0
    state: Optional[PassState] = None
    logits: Any = None
    stats: Optional[Dict] = None
    error: Optional[BaseException] = None
    latency_s: float = 0.0
    kind: str = "prefill"
    gen: Any = None
    done: threading.Event = field(default_factory=threading.Event, repr=False)

    def urgency_key(self, default_slack: float) -> Tuple[float, float, int]:
        """Urgency-weighted deadline (weighted EDF): smaller sorts first."""
        slack = self.deadline if self.deadline is not None else default_slack
        virtual_deadline = self.arrival + slack / max(self.priority, 1e-9)
        return (virtual_deadline, self.arrival, self.rid)

    def wait(self, timeout: Optional[float] = None) -> "ServingRequest":
        """Block until served; re-raises the pass's exception, if any."""
        if not self.done.wait(timeout):
            raise TimeoutError(f"request {self.rid} ({self.model}) not "
                               f"served within {timeout}s")
        if self.error is not None:
            raise self.error
        return self


class RequestQueue:
    """Thread-safe admission queue ordered by urgency-weighted deadline."""

    def __init__(self, default_slack: float = 1.0):
        self.default_slack = default_slack
        self._cond = threading.Condition()
        self._heap: List[Tuple[Tuple[float, float, int], ServingRequest]] = []
        self._closed = False

    def submit(self, req: ServingRequest) -> None:
        with self._cond:
            assert not self._closed, "queue closed"
            heapq.heappush(self._heap,
                           (req.urgency_key(self.default_slack), req))
            self._cond.notify_all()

    def requeue(self, req: ServingRequest) -> None:
        """Re-admit a preempted (or pop-raced) request. Unlike submit this
        tolerates a closed queue — a pass preempted during shutdown must
        land back in the heap to be drained, not raise on an executor
        thread. The request keeps its ORIGINAL arrival, so its virtual
        deadline keeps aging: preemption can delay it, never starve it."""
        with self._cond:
            heapq.heappush(self._heap,
                           (req.urgency_key(self.default_slack), req))
            self._cond.notify_all()

    def pop_ready(self, busy: Sequence[str] = (),
                  timeout: Optional[float] = None) -> Optional[ServingRequest]:
        """Most urgent request whose model is not in ``busy`` (same-model
        passes serialize on one engine). None on timeout; None with the
        queue closed AND drained means "executor may exit" (check
        :attr:`closed`)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        busy = set(busy)
        with self._cond:
            while True:
                skipped = []
                found = None
                while self._heap:
                    key, req = heapq.heappop(self._heap)
                    if req.model in busy:
                        skipped.append((key, req))
                    else:
                        found = req
                        break
                for item in skipped:
                    heapq.heappush(self._heap, item)
                if found is not None:
                    return found
                if self._closed and not self._heap:
                    return None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                    self._cond.wait(remaining)
                else:
                    self._cond.wait()

    def remove(self, rid: int) -> Optional[ServingRequest]:
        """Remove (and return) the queued request with this rid; None if it
        is not in the heap (already popped by an executor, or unknown).
        O(n) scan + re-heapify — cancellation is rare, the queue is small."""
        with self._cond:
            for i, (_, req) in enumerate(self._heap):
                if req.rid == rid:
                    last = self._heap.pop()
                    if i < len(self._heap):
                        self._heap[i] = last
                        heapq.heapify(self._heap)
                    return req
            return None

    def max_waiting_priority(self) -> float:
        """Highest priority among queued (not yet running) requests."""
        with self._cond:
            return max((req.priority for _, req in self._heap),
                       default=float("-inf"))

    def max_runnable_priority(self, busy: Sequence[str] = ()) -> float:
        """Highest priority among queued requests that could actually run
        if one more executor freed up — a request whose model is being
        served ELSEWHERE can't (same-model passes serialize), so a pass
        yielding for it would drain its prefetches for nothing."""
        busy = set(busy)
        with self._cond:
            return max((req.priority for _, req in self._heap
                        if req.model not in busy),
                       default=float("-inf"))

    def kick(self) -> None:
        """Wake executors blocked in pop_ready: a model just left the busy
        set, so a request skipped as same-model-busy may now be runnable
        (without this, the handoff waits out the poll timeout)."""
        with self._cond:
            self._cond.notify_all()

    def urgency_mix(self) -> Dict[str, float]:
        """Per-model max queued priority — the live demand signal
        ``MultiModelRuntime.replan_budgets`` reacts to."""
        with self._cond:
            mix: Dict[str, float] = {}
            for _, req in self._heap:
                mix[req.model] = max(mix.get(req.model, 0.0), req.priority)
            return mix

    def __len__(self) -> int:
        with self._cond:
            return len(self._heap)

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()


class ServingScheduler:
    """K concurrent executors + preemptive priority scheduling over one
    planned :class:`MultiModelRuntime`.

    Usage::

        rt = MultiModelRuntime(budget, executors=2)
        rt.add_model("qwen", ...); rt.add_model("gemma", ...)
        rt.plan(batch=2, seq=32)
        with ServingScheduler(rt) as sched:
            hi = sched.submit("qwen", batch, priority=8.0)
            lo = sched.submit("gemma", batch)        # priority 1.0
            hi.wait(); lo.wait()

    ``preempt=False`` degrades to run-to-completion (still priority-ordered
    admission); ``executors=1, preempt=False`` with uniform priorities is
    exactly the old serialized engine — the bench's baseline arm.
    """

    def __init__(self, runtime: MultiModelRuntime,
                 executors: Optional[int] = None, preempt: bool = True,
                 default_slack: float = 1.0, auto_rebalance: bool = False,
                 fail_fast_after: int = 3, shed_deadlines: bool = False):
        self.runtime = runtime
        self.executors = int(executors if executors is not None
                             else runtime.executors)
        assert self.executors >= 1
        self.preempt = preempt
        self.auto_rebalance = auto_rebalance
        # Graceful degradation knobs (docs/ARCHITECTURE.md "Failure
        # handling"): ``fail_fast_after`` consecutive SwapError passes mark
        # a model DOWN — its queued and future requests fail immediately
        # with a structured error of the same class instead of each burning
        # a full retry ladder, while co-tenant models keep serving
        # (``reset_model`` re-admits after the operator fixes the storage).
        # ``shed_deadlines=True`` rejects a request whose deadline already
        # passed while it queued (SwapTimeoutError) rather than running it
        # late — opt-in: shedding is a policy choice, not a default.
        assert fail_fast_after >= 1
        self.fail_fast_after = int(fail_fast_after)
        self.shed_deadlines = bool(shed_deadlines)
        self.queue = RequestQueue(default_slack)
        self.completed: List[ServingRequest] = []
        self.preemptions = 0
        self.shed = 0
        self.failed_fast = 0
        self._rid = itertools.count()
        self._lock = threading.Lock()          # busy set + counters + mix
        self._busy: set = set()
        self._model_failures: Dict[str, int] = {}   # consecutive SwapErrors
        self._model_down: Dict[str, BaseException] = {}
        self._last_mix: Dict[str, float] = {}
        self._threads = [
            threading.Thread(target=self._worker, name=f"swapnet-exec-{i}",
                             daemon=True)
            for i in range(self.executors)]
        for t in self._threads:
            t.start()

    @classmethod
    def from_config(cls, runtime: MultiModelRuntime, cfg) -> "ServingScheduler":
        """Construct from a resolved :class:`repro.config.ServeConfig`'s
        ``scheduler`` section (the runtime carries the executor count)."""
        s = cfg.scheduler
        return cls(runtime, preempt=s.preempt, default_slack=s.default_slack,
                   auto_rebalance=s.rebalance,
                   fail_fast_after=s.fail_fast_after,
                   shed_deadlines=s.shed_deadlines)

    # ---------------------------------------------------------- submission
    def submit(self, model: str, batch: dict, priority: float = 1.0,
               deadline: Optional[float] = None) -> ServingRequest:
        req = ServingRequest(model=model, batch=batch,
                             priority=float(priority), deadline=deadline,
                             rid=next(self._rid),
                             arrival=time.perf_counter())
        self.queue.submit(req)
        if self.auto_rebalance:
            self._maybe_rebalance()
        return req

    def submit_generate(self, model: str, gen_request,
                        priority: float = 1.0,
                        deadline: Optional[float] = None) -> ServingRequest:
        """Queue a GENERATION (prefill + multi-token decode) against the
        model's continuous-batching engine (``runtime.batch_engine``).

        One driver ServingRequest is queued per generation; the busy set
        serializes same-model drivers, so whichever driver holds the model
        steps the WHOLE decode batch — its stepping serves every admitted
        sequence, and each driver exits as soon as ITS OWN sequence retires
        (possibly without ever stepping, if another driver already carried
        it to completion). Completion is signalled from the engine's retire
        callback, so ``req.wait()`` returns the moment the sequence
        finishes, whichever driver ran the final step."""
        engine = self.runtime.batch_engine(model)     # build early: raises
        req = ServingRequest(model=model, batch={},   # surface on submit
                             priority=float(priority), deadline=deadline,
                             rid=next(self._rid),
                             arrival=time.perf_counter(),
                             kind="generate", gen=gen_request)

        def on_retire(_gen, _req=req):
            _req.latency_s = time.perf_counter() - _req.arrival
            _req.error = getattr(_gen, "error", None)
            if _req.error is None:
                with self._lock:
                    self.completed.append(_req)
            else:       # a failed sequence (evicted by the batch engine)
                # surfaces through wait() and counts against the breaker
                self._note_failure(_req.model, _req.error)
            _req.done.set()

        engine.submit(gen_request, on_retire=on_retire)
        self.queue.submit(req)
        if self.auto_rebalance:
            self._maybe_rebalance()
        return req

    def cancel(self, rid: int) -> bool:
        """Remove a still-queued request (e.g. after the caller's own
        ``wait(timeout)`` expired) so it never becomes a ghost entry that
        executes later against a caller who stopped listening.

        Returns True when the request was cancelled: it completes
        immediately with :class:`RequestCancelled` (``wait`` re-raises it).
        Returns False — cleanly, no side effects — when the request is
        already running on an executor, already completed, or unknown:
        cancellation is queue-removal, never pass-abortion (a running pass
        holds ledger bytes and cache leases that must unwind through its
        own drain path)."""
        req = self.queue.remove(rid)
        if req is None:
            return False
        if req.kind == "generate" and req.gen is not None:
            # un-submit the sequence from the batch engine too (pending-only
            # there as well; if another driver already admitted it, the
            # engine keeps it and the retire callback still fires)
            try:
                self.runtime.batch_engine(req.model).cancel(req.gen.rid)
            except Exception:       # noqa: BLE001 — best-effort cleanup
                pass
        req.error = RequestCancelled(
            f"request {rid} ({req.model}) cancelled before dispatch")
        req.done.set()
        return True

    def reset_model(self, model: str) -> None:
        """Clear the fail-fast breaker for ``model`` (storage was repaired /
        remounted): its requests are served normally again."""
        with self._lock:
            self._model_failures.pop(model, None)
            self._model_down.pop(model, None)

    def model_down(self, model: str) -> Optional[BaseException]:
        """The SwapError that tripped the model's breaker, or None."""
        with self._lock:
            return self._model_down.get(model)

    def _maybe_rebalance(self) -> None:
        """Re-split the block budget when the queued demand mix changes."""
        mix = self.queue.urgency_mix()
        with self._lock:
            if mix == self._last_mix or not mix:
                return
            self._last_mix = dict(mix)
        try:
            self.runtime.replan_budgets(mix)
        except ValueError:
            pass          # infeasible mix (floors don't fit): keep old plans

    # ---------------------------------------------------------- executors
    def _busy_snapshot(self) -> frozenset:
        with self._lock:
            return frozenset(self._busy)

    def _worker(self) -> None:
        rt = self.runtime
        while True:
            req = self.queue.pop_ready(busy=self._busy_snapshot(),
                                       timeout=0.05)
            if req is None:
                if self.queue.closed and not len(self.queue):
                    return
                continue
            if self._degrade(req):      # breaker tripped / deadline shed:
                continue                # completed with a structured error
            with self._lock:
                if req.model in self._busy:
                    # raced with another executor picking the same model:
                    # put it back and try again
                    self.queue.requeue(req)
                    continue
                self._busy.add(req.model)
            try:
                if req.kind == "generate":
                    self._drive_generate(req)
                else:
                    state, stats = rt.forward_partial(
                        req.model, req.batch, state=req.state,
                        should_yield=self._make_yield(req),
                        priority=req.priority)
                    if stats is None:                   # preempted
                        req.state = state
                        with self._lock:
                            self.preemptions += 1
                        self.queue.requeue(req)
                    else:
                        req.logits, req.stats = state.logits, stats
                        req.latency_s = time.perf_counter() - req.arrival
                        with self._lock:
                            self.completed.append(req)
                        req.done.set()
            except BaseException as e:                  # noqa: BLE001
                req.error = e
                self._note_failure(req.model, e)
                req.done.set()
            else:
                with self._lock:    # clean pass: the breaker counts
                    self._model_failures.pop(req.model, None)   # CONSECUTIVE
            finally:                                            # failures
                with self._lock:
                    self._busy.discard(req.model)
                self.queue.kick()

    def _degrade(self, req: ServingRequest) -> bool:
        """Scheduler-tier degradation, decided BEFORE the request takes an
        executor slot: fail fast against a down model; shed a request whose
        deadline already passed while queued. True = request completed
        (with a structured error) and must not run."""
        with self._lock:
            down = self._model_down.get(req.model)
        if down is not None:
            # same exception CLASS as the tripping error, so callers'
            # isinstance handling (SwapIOError vs SwapCorruptionError)
            # works identically for fast-failed requests
            req.error = type(down)(
                f"model {req.model!r} is marked failed "
                f"({self.fail_fast_after} consecutive swap errors; "
                f"last: {down}) — failing fast; reset_model() re-admits",
                model=req.model)
            with self._lock:
                self.failed_fast += 1
            self._finish_degraded(req)
            return True
        if (self.shed_deadlines and req.deadline is not None
                and time.perf_counter() - req.arrival > req.deadline):
            req.error = SwapTimeoutError(
                f"request {req.rid} ({req.model}) shed: queued "
                f"{time.perf_counter() - req.arrival:.2f}s past its "
                f"{req.deadline:.2f}s deadline", model=req.model)
            with self._lock:
                self.shed += 1
            self._finish_degraded(req)
            return True
        return False

    def _finish_degraded(self, req: ServingRequest) -> None:
        if req.kind == "generate" and req.gen is not None:
            try:        # un-submit from the batch engine (pending-only)
                self.runtime.batch_engine(req.model).cancel(req.gen.rid)
            except Exception:       # noqa: BLE001 — best-effort cleanup
                pass
        req.done.set()

    def _note_failure(self, model: str, err: BaseException) -> None:
        """Per-model circuit breaker: only SwapErrors count (a cancelled
        request or a caller bug must not poison the model), and only
        CONSECUTIVE ones trip it."""
        if not isinstance(err, SwapError):
            return
        if err.model is None:
            err.model = model
        with self._lock:
            n = self._model_failures.get(model, 0) + 1
            self._model_failures[model] = n
            if n >= self.fail_fast_after:
                self._model_down.setdefault(model, err)

    def _drive_generate(self, req: ServingRequest) -> None:
        """Drive the model's continuous-batching engine until ``req``'s own
        sequence retires or a higher-priority runnable request appears at a
        decode-step boundary (the decode analogue of block-boundary
        preemption). Completion bookkeeping lives in the engine's retire
        callback (``submit_generate``), so the driver only decides whether
        to requeue itself."""
        engine = self.runtime.batch_engine(req.model)
        self.runtime.models[req.model].engine.set_priority(req.priority)
        finished = engine.run_until(req.gen.rid,
                                    should_yield=self._make_gen_yield(req))
        if not finished:
            with self._lock:
                self.preemptions += 1
            self.queue.requeue(req)

    def _make_gen_yield(self, req: ServingRequest):
        if not self.preempt:
            return None

        def should_yield() -> bool:
            # same policy as prefill passes, consulted between decode steps
            with self._lock:
                others_busy = self._busy - {req.model}
            return self.queue.max_runnable_priority(others_busy) > req.priority
        return should_yield

    def _make_yield(self, req: ServingRequest):
        if not self.preempt:
            return None

        def should_yield(state: PassState) -> bool:
            # Yield only for strictly-higher-priority work that could take
            # this slot: my own model frees when I park, so requests for it
            # count; requests for models busy on OTHER executors don't —
            # yielding for those would re-buy my prefetches for nothing.
            # Strict inequality: equal-priority tenants never churn.
            with self._lock:
                others_busy = self._busy - {req.model}
            return self.queue.max_runnable_priority(others_busy) > req.priority
        return should_yield

    # ---------------------------------------------------------- reporting
    def latency_by_class(self) -> Dict[float, List[float]]:
        """Completed-request latencies grouped by priority class."""
        with self._lock:
            out: Dict[float, List[float]] = {}
            for r in self.completed:
                out.setdefault(r.priority, []).append(r.latency_s)
            return out

    # ---------------------------------------------------------- lifecycle
    def shutdown(self, wait: bool = True) -> None:
        self.queue.close()
        if wait:
            for t in self._threads:
                t.join()

    def __enter__(self) -> "ServingScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=True)
