"""Block swapping controller (paper §4): swap-in / swap-out executor.

Storage is a pluggable tier (``repro.store``): the engine asks its
:class:`~repro.store.BlockStore` for each unit and does the bookkeeping —
wall-clock (t_in split into I/O + assembly, t_out, and the stall time the
executor spends waiting on prefetch futures), actual storage->host traffic
(``SwapStats.bytes_swapped``; quantized backends move ~4x less than the
logical unit bytes), and a resident-bytes ledger (peak is what the paper's
Figs. 11-13 report).

The paper's ablation arms (Fig. 15) remain the engine's ``mode`` flag and are
resolved against the store:
  * "snet"      — read the store through its own backend (zero-copy mmap for
                  the default store; quantized+dequant for QuantizedStore);
  * "copy_in"   — w/o-uni-add: reinterpret a raw store through RawIOStore
                  (read() page-cache copy + staging copy + transfer, + the
                  GPU dispatch copy for gpu_dispatch models);
  * "dummy_asm" — w/o-mod-ske: zero-copy I/O but framework-default dummy
                  assembly (per-tensor copies, 2x resident during assembly).

The ledger may be PRIVATE (one model, the seed behaviour) or SHARED across
several engines (the §6.2 multi-DNN scenario: co-resident models under one
budget). Prefetch runs on a single loader thread — one swap-in channel,
matching the paper's pipeline model — at any queue depth m >= 1.

An optional LRU BlockCache keeps hot units (embeddings, shared blocks, small
heads) resident across requests so repeat swap-ins skip the I/O + assembly
path entirely; cached bytes are charged to the shared ledger exactly once,
no matter how many engines or handles reference them.
"""
from __future__ import annotations

import gc
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence

from repro.errors import SwapError, SwapIOError, SwapTimeoutError
from repro.store import (BlockStore, LayerStore, MmapStore, QuantizedStore,
                         RawIOStore, as_reader)

__all__ = ["LayerStore", "MmapStore", "RawIOStore", "QuantizedStore",
           "MemoryLedger", "BlockCache", "size_aware_policy", "BlockHandle",
           "SwapStats", "SwapEngine"]


# ------------------------------------------------------------------ ledger
class MemoryLedger:
    """Resident-bytes accounting, optionally shared by several SwapEngines.

    One ledger == one memory budget: when co-resident models each hold blocks
    (plus the shared block cache), the SUM of their bytes is what must stay
    under budget — per-engine ledgers cannot see each other's residency.
    Thread-safe: loader threads add while executor threads drop; a running
    total keeps every operation O(1) so the lock is held for nanoseconds
    (concurrent executors contend on it at every block boundary).

    Two admission paths:

      * :meth:`add` — immediate: over budget raises ``MemoryError`` (the
        single-tenant semantics: a plan whose blocks don't fit is a
        scheduling bug, fail loudly);
      * :meth:`reserve` — blocking: over budget WAITS until other tenants
        drop bytes, with PRIORITY WAKEUP — when bytes free, the
        highest-priority waiter is admitted first (FIFO within one priority
        class), so a high-urgency request's swap-ins never queue behind a
        batch tenant's. Used by concurrent serving (``executors > 1``).
    """

    def __init__(self, budget: Optional[int] = None):
        self.budget = budget
        self._entries: Dict[object, int] = {}
        self._total = 0
        self._cond = threading.Condition()
        # active reserve() tickets, ordered by (-priority, seq): the minimum
        # ticket is the next waiter allowed to admit (anti-inversion barrier)
        self._waiting: List[tuple] = []
        self._seq = 0
        self.peak = 0

    @property
    def resident(self) -> int:
        with self._cond:
            return self._total

    def _admit_locked(self, key: object, nbytes: int) -> bool:
        """Try to charge under the lock; False if it would exceed budget."""
        delta = nbytes - self._entries.get(key, 0)
        if self.budget is not None and self._total + delta > self.budget:
            return False
        self._entries[key] = nbytes
        self._total += delta
        self.peak = max(self.peak, self._total)
        return True

    def add(self, key: object, nbytes: int, what: str = "block") -> int:
        """Charge ``nbytes``; returns the post-add resident total. Over
        budget: nothing is recorded before raising, so one rejected request
        cannot permanently inflate a ledger other tenants share."""
        with self._cond:
            if self._admit_locked(key, nbytes):
                return self._total
            total = self._total + nbytes
        # The paper treats this as a scheduling bug: blocks must fit b.
        raise MemoryError(
            f"resident {total/1e6:.1f} MB exceeds budget "
            f"{self.budget/1e6:.1f} MB (while adding {what})")

    def try_add(self, key: object, nbytes: int) -> bool:
        """Non-raising add: False (and no charge) if over budget. The cache
        insertion path — under concurrency a transiently full ledger means
        "don't cache this unit", not "kill the request"."""
        with self._cond:
            return self._admit_locked(key, nbytes)

    def reserve(self, key: object, nbytes: int, what: str = "block",
                priority: float = 0.0,
                timeout: Optional[float] = None) -> int:
        """Blocking add: wait until ``nbytes`` fit under the budget.

        Waiters are admitted highest-priority-first (ties FIFO); while a
        higher-priority waiter is pending, later lower-priority arrivals
        queue behind it even if they would fit — admitting them could eat
        the bytes the urgent request is waiting for (priority inversion).
        ``timeout`` bounds the wait (None = forever); on expiry, or when
        ``nbytes`` alone exceed the budget, raises ``MemoryError``.
        """
        if self.budget is not None and nbytes > self.budget:
            raise MemoryError(
                f"{what}: {nbytes/1e6:.1f} MB can never fit budget "
                f"{self.budget/1e6:.1f} MB")
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            self._seq += 1
            ticket = (-float(priority), self._seq)
            self._waiting.append(ticket)
            try:
                while True:
                    if (min(self._waiting) == ticket
                            and self._admit_locked(key, nbytes)):
                        return self._total
                    if deadline is not None:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            raise MemoryError(
                                f"reserve timeout: {nbytes/1e6:.1f} MB for "
                                f"{what} did not fit budget "
                                f"{(self.budget or 0)/1e6:.1f} MB within "
                                f"{timeout:.1f}s "
                                f"(resident {self._total/1e6:.1f} MB)")
                        self._cond.wait(remaining)
                    else:
                        self._cond.wait()
            finally:
                self._waiting.remove(ticket)
                # our departure may unblock the next-best waiter
                self._cond.notify_all()

    def drop(self, key: object) -> None:
        with self._cond:
            nbytes = self._entries.pop(key, None)
            if nbytes is not None:
                self._total -= nbytes
                self._cond.notify_all()


# ------------------------------------------------------------------ cache
def size_aware_policy(unit_sizes: Mapping[str, int],
                      capacity: int) -> Callable[[str, int], bool]:
    """Admission informed by the partition table's per-unit sizes (ROADMAP
    item (d), shipped): admit exactly the units small enough that the whole
    admitted set provably co-fits in ``capacity``.

    The threshold is the largest size s such that EVERY unit of size <= s
    fits in ``capacity`` together (distinct sizes considered ascending,
    whole size-classes at a time: admitting some-but-not-all units of one
    size would let the marginal ones thrash the cyclic block scan and evict
    the genuinely hot small units). Unlike the static ``admit_frac``
    heuristic this adapts to the actual size distribution: a model of many
    small units caches them all, a model of few huge blocks caches none.
    Unknown names fall back to their observed size.
    """
    sizes = sorted(s for s in unit_sizes.values() if s > 0)
    cum, threshold, i = 0, 0, 0
    while i < len(sizes):
        j = i
        while j < len(sizes) and sizes[j] == sizes[i]:
            j += 1
        group = sizes[i] * (j - i)
        if cum + group > capacity:
            break
        cum += group
        threshold = sizes[i]
        i = j

    def policy(name: str, nbytes: int) -> bool:
        size = unit_sizes.get(name, nbytes)
        return 0 < size <= threshold

    return policy


class BlockCache:
    """LRU cache of assembled units, shared across engines and requests.

    Entries are charged to the ledger once under a per-name key — a unit
    shared by two models (or referenced by several in-flight handles) never
    double-counts. Entries pinned via :meth:`pin` are never evicted (the
    seed's ``pinned=`` behaviour); other entries are evicted LRU-first once
    ``capacity`` bytes are exceeded, but only when no handle still references
    them (refcounted, so the ledger never loses sight of live bytes).

    Admission is a pluggable ``policy`` (a ``(name, nbytes) -> bool``
    constructor argument). Default (policy=None) is the thresholded
    heuristic: only units no larger than ``admit_frac`` of capacity enter —
    a block traversal is a cyclic scan, so admit-everything LRU would evict
    each unit just before its next use and hit 0%. :func:`size_aware_policy`
    upgrades this with the partition table's per-unit sizes (installed by
    ``MultiModelRuntime.plan``)."""

    def __init__(self, capacity: int, ledger: MemoryLedger,
                 admit_frac: float = 0.25,
                 policy: Optional[Callable[[str, int], bool]] = None):
        self.capacity = capacity
        self.admit_frac = admit_frac
        self.policy = policy
        self.ledger = ledger
        self._lock = threading.RLock()
        # name -> [params, ledger_bytes, refcount]
        self._entries: "OrderedDict[str, list]" = OrderedDict()
        self._pinned: set = set()
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------ policy
    def pin(self, names: Sequence[str]) -> None:
        with self._lock:
            self._pinned.update(names)

    @property
    def pinned(self) -> frozenset:
        with self._lock:
            return frozenset(self._pinned)

    def set_policy(self,
                   policy: Optional[Callable[[str, int], bool]]) -> None:
        with self._lock:
            self.policy = policy

    def admits(self, name: str, nbytes: int) -> bool:
        """Pinned units always enter; others go through the admission policy
        (per-unit-size aware when installed, else the admit_frac heuristic).
        ``nbytes`` is the unit's RESIDENT cost when cached (stored bytes for
        quantized backends)."""
        with self._lock:
            if name in self._pinned:
                return True
            if self.policy is not None:
                return self.policy(name, nbytes)
            return 0 < nbytes <= self.capacity * self.admit_frac

    # ------------------------------------------------------------ lookup
    def acquire(self, name: str, count: bool = True):
        """Return cached params (bumping LRU + refcount) or None."""
        with self._lock:
            e = self._entries.get(name)
            if e is None:
                if count:
                    self.misses += 1
                return None
            self._entries.move_to_end(name)
            e[2] += 1
            if count:
                self.hits += 1
            return e[0]

    def release(self, name: str) -> None:
        with self._lock:
            e = self._entries.get(name)
            if e is not None:
                e[2] = max(e[2] - 1, 0)

    def put(self, name: str, params, ledger_bytes: int) -> bool:
        """Insert (idempotent) and evict LRU unpinned idle entries to fit.
        Returns whether the unit is cache-resident afterwards: a transiently
        full shared ledger declines the insert (False) instead of raising —
        under concurrency "can't cache right now" must not kill the request
        (the caller charges its own handle instead)."""
        with self._lock:
            if name in self._entries:
                return True
            # charge first: if the ledger declines (budget), nothing inserted
            if not self.ledger.try_add(("cache", name), ledger_bytes):
                return False
            self._entries[name] = [params, ledger_bytes, 0]
            self._evict_to_capacity()
            return name in self._entries

    def _evict_to_capacity(self) -> None:
        over = self._unpinned_bytes() - self.capacity
        if over <= 0:
            return
        for name in list(self._entries):
            if over <= 0:
                break
            e = self._entries[name]
            if name in self._pinned or e[2] > 0:
                continue
            over -= e[1]
            del self._entries[name]
            self.ledger.drop(("cache", name))

    def _unpinned_bytes(self) -> int:
        return sum(e[1] for n, e in self._entries.items()
                   if n not in self._pinned)

    # ------------------------------------------------------------ stats
    def active_leases(self) -> Dict[str, int]:
        """Entries some in-flight handle still references (name ->
        refcount). Outside a pass this must be EMPTY — a non-zero refcount
        with no live handle is a leaked lease that makes the entry
        unevictable forever; the fault-path regression tests assert on it."""
        with self._lock:
            return {n: e[2] for n, e in self._entries.items() if e[2] > 0}

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(e[1] for e in self._entries.values())

    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def clear(self) -> None:
        with self._lock:
            for name in list(self._entries):
                self.ledger.drop(("cache", name))
            self._entries.clear()


# ------------------------------------------------------------------ handles
@dataclass
class BlockHandle:
    names: List[str]
    params: List[dict]           # assembled (by reference) param trees
    nbytes: int                  # logical (dequantized) block bytes
    resident_bytes: int          # ledger bytes incl. mode-induced extra copies
    io_s: float = 0.0
    asm_s: float = 0.0
    cached_names: List[str] = field(default_factory=list)


@dataclass
class SwapStats:
    """Wall-clock + byte accounting of one engine. The three byte currencies
    the ledger report distinguishes:

      * ``bytes_logical``            — LOGICAL (dequantized) bytes the
                                       swap-ins delivered;
      * ``bytes_swapped``            — STREAMED: actual storage->host I/O
                                       traffic (quantized backends move
                                       4-8x less than logical);
      * ``bytes_resident_quantized`` — RESIDENT-quantized: payload bytes
                                       delivered still in quantized form
                                       (``QuantizedTensor`` leaves, the
                                       fused path) — these stay quantized
                                       in device memory and in the VMEM
                                       weight stream.

    ``vmem_working_set`` is the per-kernel figure: bytes the weight-stream
    matmul holds in VMEM at the default tiling for this engine's store
    precision (set by the runtime from ``kernels.swap_linear.vmem_bytes``;
    the fused path shrinks the weight window 2x int8 / 4x int4).

    ``timeline`` is the per-stage event log the overlap analysis runs on:
    ``(stage, start, end)`` tuples in ``time.perf_counter`` absolute
    seconds. Loader-side stages come from each :class:`UnitRead` ("read" =
    storage -> host, "unpack" = dequant/assembly, "dispatch" = host ->
    device incl. the on-device flush); the engine adds executor-side
    events ("wait" = stall on a prefetch future, "exec" = block compute).
    A healthy depth-m pipeline shows block i+1's "read" span INSIDE block
    i's "exec" span — :meth:`overlap_seconds` measures exactly that, so a
    serialization point is attributable to the stage that caused it
    instead of disappearing into an aggregate latency."""
    t_in: List[float] = field(default_factory=list)
    t_in_io: List[float] = field(default_factory=list)
    t_in_asm: List[float] = field(default_factory=list)
    t_ex: List[float] = field(default_factory=list)
    t_out: List[float] = field(default_factory=list)
    t_wait: List[float] = field(default_factory=list)   # executor stalls
    timeline: List[tuple] = field(default_factory=list)
    peak_resident: int = 0
    bytes_swapped: int = 0       # actual storage->host I/O traffic
    bytes_logical: int = 0       # dequantized bytes those swap-ins delivered
    bytes_resident_quantized: int = 0   # delivered still-quantized (fused)
    vmem_working_set: int = 0    # per-kernel VMEM bytes at this precision
    cache_hits: int = 0
    cache_misses: int = 0
    # fault accounting (docs/ARCHITECTURE.md "Failure handling"): ``retries``
    # counts re-read attempts the loader burned recovering; ``faults`` tallies
    # every failed read attempt by taxonomy class (SwapIOError /
    # SwapCorruptionError / SwapTimeoutError) INCLUDING the ones retries
    # absorbed — a healthy-looking pass over flaky storage is visible here.
    # The timeline gains "retry" spans covering each backoff sleep.
    retries: int = 0
    faults: Dict[str, int] = field(default_factory=dict)
    # streamed I/O split by STORED precision ({"fp"|"int8"|"int4": bytes},
    # summing to ``bytes_swapped``): under a mixed-precision plan this is
    # the realized per-precision byte breakdown; uniform stores report one
    # bucket (their precision, "fp" for exact backends).
    bytes_by_precision: Dict[str, int] = field(default_factory=dict)

    # ------------------------------------------------------------ timeline
    def stage_spans(self, stage: str) -> List[tuple]:
        """All ``(start, end)`` spans recorded for ``stage``, in log order."""
        return [(s, e) for st, s, e in self.timeline if st == stage]

    def stage_seconds(self, stage: str) -> float:
        """Total wall-clock spent in ``stage`` across the log."""
        return sum(e - s for _, s, e in
                   (ev for ev in self.timeline if ev[0] == stage))

    def overlap_seconds(self, stage_a: str, stage_b: str) -> float:
        """Wall-clock during which ``stage_a`` and ``stage_b`` ran
        CONCURRENTLY (intersection of their merged span sets) — e.g.
        ``overlap_seconds("read", "exec")`` is the host-read time genuinely
        hidden behind compute, the quantity the fused-path fix targets."""

        def merged(stage):
            spans = sorted(self.stage_spans(stage))
            out: List[List[float]] = []
            for s, e in spans:
                if out and s <= out[-1][1]:
                    out[-1][1] = max(out[-1][1], e)
                else:
                    out.append([s, e])
            return out

        a, b = merged(stage_a), merged(stage_b)
        total, i, j = 0.0, 0, 0
        while i < len(a) and j < len(b):
            lo = max(a[i][0], b[j][0])
            hi = min(a[i][1], b[j][1])
            if hi > lo:
                total += hi - lo
            if a[i][1] < b[j][1]:
                i += 1
            else:
                j += 1
        return total

    def overlap_efficiency(self) -> float:
        """Fraction of total swap-in time hidden behind execution: 1.0 means
        the executor never stalled on a prefetch (paper Fig. 10's ideal);
        0.0 means every swap-in was fully visible (serial)."""
        total_in = sum(self.t_in)
        if total_in <= 0.0:
            return 1.0
        return max(0.0, 1.0 - sum(self.t_wait) / total_in)

    def cache_hit_rate(self) -> float:
        n = self.cache_hits + self.cache_misses
        return self.cache_hits / n if n else 0.0


class SwapEngine:
    """One model's swap-in/swap-out executor over a pluggable BlockStore.

    ``ledger`` and ``cache`` may be shared with other engines (multi-model
    serving under one budget); by default each engine gets a private ledger
    seeded from ``budget`` and a pin-only cache (capacity 0: only ``pinned``
    units are retained, the seed behaviour). ``mode`` selects the paper's
    ablation arms against a raw-format store (see module docstring)."""

    def __init__(self, store: BlockStore, mode: str = "snet",
                 budget: Optional[int] = None, gpu_dispatch: bool = False,
                 pinned: Sequence[str] = (),
                 ledger: Optional[MemoryLedger] = None,
                 cache: Optional[BlockCache] = None):
        assert mode in ("snet", "copy_in", "dummy_asm")
        self.store = as_reader(store, mode=mode, gpu_dispatch=gpu_dispatch)
        self.mode = mode
        self.gpu_dispatch = gpu_dispatch
        self.ledger = ledger if ledger is not None else MemoryLedger(budget)
        self.cache = cache if cache is not None else BlockCache(0, self.ledger)
        self.cache.pin(pinned)
        self.stats = SwapStats()
        # per-kernel VMEM working set of the weight-stream matmul at this
        # store's precision; the runtime sets it (kernels.vmem_bytes) and
        # swap_in republishes it into stats so resets don't lose it
        self.vmem_working_set = 0
        # Concurrent serving knobs (set by MultiModelRuntime when
        # executors > 1): reserve_blocking makes over-budget swap-ins WAIT
        # for other tenants to free bytes (priority wakeup) instead of
        # raising; priority is the urgency of the request currently being
        # served through this engine (per-model passes serialize, so one
        # value per engine suffices); the timeout converts a genuine
        # cross-tenant deadlock into a loud MemoryError.
        self.reserve_blocking = False
        self.reserve_timeout: Optional[float] = 30.0
        self.priority = 0.0
        # Fault-tolerance knobs (docs/ARCHITECTURE.md "Failure handling"):
        # a failed unit read is retried up to ``read_retries`` times with
        # exponential backoff starting at ``retry_backoff_s`` (doubling per
        # attempt); ``read_deadline_s`` bounds ONE read attempt — a read
        # that returns after the deadline is discarded and counted as
        # SwapTimeoutError (retryable), so a storage latency cliff cannot
        # silently become unbounded serving tail latency.
        self.read_retries = 2
        self.retry_backoff_s = 0.01
        self.read_deadline_s: Optional[float] = None
        self._loader = ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix="swapnet-loader")

    # -------------------------------------------------------------- ledger
    @property
    def pinned(self) -> frozenset:
        """The cache is the single source of truth for pinned-ness (a shared
        cache may pin units for several engines; callers filter by store)."""
        return self.cache.pinned

    @property
    def budget(self) -> Optional[int]:
        return self.ledger.budget

    @property
    def resident_bytes(self) -> int:
        return self.ledger.resident

    def set_priority(self, priority: float) -> None:
        """Urgency of the request this engine is currently serving; swap-ins
        issued on the loader thread inherit it for ledger priority wakeup."""
        self.priority = float(priority)

    def _ledger_add(self, handle: BlockHandle) -> None:
        what = (f"block[{','.join(handle.names[:3])}...]"
                if len(handle.names) > 3
                else f"block[{','.join(handle.names)}]")
        if self.reserve_blocking:
            total = self.ledger.reserve(id(handle), handle.resident_bytes,
                                        what, priority=self.priority,
                                        timeout=self.reserve_timeout)
        else:
            total = self.ledger.add(id(handle), handle.resident_bytes, what)
        # per-engine peak = residency observed while THIS engine was adding;
        # resettable via stats.__init__() (the ledger's .peak is the
        # monotone lifetime number the multi-model stats report).
        self.stats.peak_resident = max(self.stats.peak_resident, total)

    # -------------------------------------------------------------- swap-in
    def _read_with_retry(self, name: str):
        """One unit read through the fault-tolerance tier: normalize store
        exceptions to the SwapError taxonomy, enforce the per-read deadline,
        retry with exponential backoff. Returns the clean ``UnitRead``; what
        escapes the retries carries ``unit``/``attempts`` context for the
        scheduler tier. Runs on the loader thread (like the read itself)."""
        delay = self.retry_backoff_s
        attempt = 0
        while True:
            attempt += 1
            t0 = time.perf_counter()
            try:
                r = self.store.read_unit(name)
            except SwapError as e:
                err = e
            except OSError as e:
                err = SwapIOError(f"unit {name!r}: {e}", unit=name)
                err.__cause__ = e
            else:
                took = time.perf_counter() - t0
                if (self.read_deadline_s is None
                        or took <= self.read_deadline_s):
                    return r
                # late data is failed data: keeping it would let one slow
                # read stretch the pipeline unboundedly — discard and retry
                err = SwapTimeoutError(
                    f"unit {name!r}: read took {took * 1e3:.1f} ms, "
                    f"deadline {self.read_deadline_s * 1e3:.1f} ms",
                    unit=name)
            kind = type(err).__name__
            self.stats.faults[kind] = self.stats.faults.get(kind, 0) + 1
            if attempt > self.read_retries:
                err.attempts = attempt
                raise err
            self.stats.retries += 1
            s0 = time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            self.stats.timeline.append(("retry", s0, time.perf_counter()))
            delay *= 2

    def swap_in(self, names: Sequence[str]) -> BlockHandle:
        params: List[dict] = []
        cached: List[str] = []
        total, ledger, loaded, io_s, asm_s = 0, 0, 0, 0.0, 0.0
        try:
            for name in names:
                hit = self.cache.acquire(name)
                if hit is not None:
                    params.append(hit)
                    cached.append(name)
                    self.stats.cache_hits += 1
                    continue
                r = self._read_with_retry(name)
                n = self.store.nbytes(name)
                params.append(r.params)
                io_s += r.io_s
                asm_s += r.asm_s
                loaded += r.io_bytes
                self.stats.timeline.extend(r.stages)
                self.stats.bytes_logical += n
                self.stats.bytes_resident_quantized += r.quantized_bytes
                # per-precision I/O split: mixed stores report it per read;
                # single-precision backends bucket the whole read under the
                # store's precision ("fp" for exact ones)
                pb = r.precision_bytes
                if pb is None:
                    pb = {getattr(self.store, "precision", "fp"): r.io_bytes}
                for prec, b in pb.items():
                    if b:
                        self.stats.bytes_by_precision[prec] = \
                            self.stats.bytes_by_precision.get(prec, 0) + b
                self.stats.cache_misses += 1
                # admission reasons in the unit's RESIDENT cost — exactly
                # what the cache entry will charge the ledger (2-3x logical
                # for rawio, the quantized payload for quant): sizing by
                # stored bytes would admit sets that overflow capacity and
                # thrash the cyclic scan to a 0% hit rate.
                if (n and self.cache.admits(name, r.ledger_bytes)
                        and self.cache.put(name, r.params, r.ledger_bytes)):
                    # hot unit: retained across requests, charged to the
                    # ledger once under the cache's key — not this handle's.
                    if self.cache.acquire(name, count=False) is not None:
                        cached.append(name)
                    else:           # raced out by eviction: charge the handle
                        total += n
                        ledger += r.ledger_bytes
                else:
                    total += n
                    ledger += r.ledger_bytes
            handle = BlockHandle(list(names), params, total, ledger,
                                 io_s, asm_s, cached_names=cached)
            self._ledger_add(handle)
        except BaseException:
            # failed partway (I/O error, ledger rejection): no handle will
            # ever be swapped out, so drop the cache leases taken above —
            # a leaked refcount would make those entries unevictable forever.
            for name in cached:
                self.cache.release(name)
            raise
        self.stats.t_in.append(io_s + asm_s)
        self.stats.t_in_io.append(io_s)
        self.stats.t_in_asm.append(asm_s)
        self.stats.vmem_working_set = self.vmem_working_set
        self.stats.bytes_swapped += loaded   # actual I/O traffic: cache hits
        return handle                        # skip it, admitted loads count

    def prefetch(self, names: Sequence[str]) -> Future:
        """Pipelined prefetch: the loader thread fetches upcoming blocks while
        the executor runs the current one (paper Fig. 10). A single loader
        thread = one swap-in channel; queue depth is the caller's m-1."""
        return self._loader.submit(self.swap_in, list(names))

    def wait(self, fut: Future) -> BlockHandle:
        """Block on a prefetch future, recording the stall as visible t_in."""
        t0 = time.perf_counter()
        handle = fut.result()
        t1 = time.perf_counter()
        self.stats.t_wait.append(t1 - t0)
        self.stats.timeline.append(("wait", t0, t1))
        return handle

    # -------------------------------------------------------------- swap-out
    def swap_out(self, handle: BlockHandle) -> float:
        """Write-back-free: parameters are immutable — drop references, GC.
        Cache-resident units merely drop their lease. Returns t_out."""
        t0 = time.perf_counter()
        handle.params = []
        for name in handle.cached_names:
            self.cache.release(name)
        handle.cached_names = []
        self.ledger.drop(id(handle))
        gc.collect(0)
        dt = time.perf_counter() - t0
        self.stats.t_out.append(dt)
        return dt

    def record_exec(self, seconds: float) -> None:
        """Executor-side compute accounting: called right after a block's
        forward with its wall-clock, so the "exec" timeline span is the
        interval ending now."""
        now = time.perf_counter()
        self.stats.t_ex.append(seconds)
        self.stats.timeline.append(("exec", now - seconds, now))

    def close(self) -> None:
        self._loader.shutdown(wait=True)
