"""Block swapping controller (paper §4): swap-in / swap-out executor.

Modes (the full system + the paper's ablation arms, Fig. 15):
  * "snet"      — zero-copy swap-in: mem-mapped block file (direct-I/O
                  analogue: no page-cache staging copy), host-side assembly by
                  reference (numpy views), ONE host->device transfer per block
                  (the irreducible DMA). Write-back-free swap-out: drop refs.
  * "copy_in"   — w/o-uni-add: standard swap-in — read() into a page-cache
                  copy, a staging copy, the device transfer, PLUS the GPU
                  dispatch copy the paper eliminates. 2x resident bytes
                  (3x for GPU-dispatched models).
  * "dummy_asm" — w/o-mod-ske: zero-copy I/O but framework-default assembly:
                  instantiate a dummy block and copy parameters in
                  (per-tensor copies, 2x resident during assembly).

The engine tracks wall-clock (t_in split into I/O + assembly, t_out) and a
logical resident-bytes ledger (peak is what the paper's Figs. 11-13 report).
Double-buffered prefetch (m=2) runs on a single loader thread.
"""
from __future__ import annotations

import gc
import os
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.skeleton import (Skeleton, assemble_dummy, assemble_np,
                                 flatten_params)


# ------------------------------------------------------------------ store
class LayerStore:
    """Per-layer (smallest divisible unit) flat files + resident skeletons.

    Blocks are ranges of layer units; adaptation only re-indexes ranges
    (paper §6.2.2 operations 2-3), never rewrites files (operation 1 is the
    one-time ``get_layers`` division)."""

    def __init__(self, workdir: str):
        self.workdir = workdir
        self.skeletons: Dict[str, Skeleton] = {}
        self.order: List[str] = []

    @classmethod
    def build(cls, units: Sequence[Tuple[str, dict]], workdir: str) -> "LayerStore":
        os.makedirs(workdir, exist_ok=True)
        store = cls(workdir)
        for name, params in units:
            store.order.append(name)
            if name in store.skeletons:     # shared unit (zamba2): stored once
                continue
            buf, skel = flatten_params(params)
            with open(store._path(name), "wb") as fh:
                fh.write(buf.tobytes())
            store.skeletons[name] = skel
        return store

    def _path(self, name: str) -> str:
        return os.path.join(self.workdir, name.replace("/", "_") + ".bin")

    def nbytes(self, name: str) -> int:
        return self.skeletons[name].nbytes

    def meta_bytes(self) -> int:
        """Resident skeleton overhead (paper Fig. 19a: 0.01-0.06 MB/model)."""
        return sum(s.meta_bytes() for s in self.skeletons.values())


# ------------------------------------------------------------------ handles
@dataclass
class BlockHandle:
    names: List[str]
    params: List[dict]           # assembled (by reference) param trees
    nbytes: int
    resident_bytes: int          # ledger bytes incl. mode-induced extra copies
    io_s: float = 0.0
    asm_s: float = 0.0


@dataclass
class SwapStats:
    t_in: List[float] = field(default_factory=list)
    t_in_io: List[float] = field(default_factory=list)
    t_in_asm: List[float] = field(default_factory=list)
    t_ex: List[float] = field(default_factory=list)
    t_out: List[float] = field(default_factory=list)
    peak_resident: int = 0
    bytes_swapped: int = 0


class SwapEngine:
    def __init__(self, store: LayerStore, mode: str = "snet",
                 budget: Optional[int] = None, gpu_dispatch: bool = False,
                 pinned: Sequence[str] = ()):
        assert mode in ("snet", "copy_in", "dummy_asm")
        self.store = store
        self.mode = mode
        self.budget = budget
        self.gpu_dispatch = gpu_dispatch
        self.pinned = set(pinned)
        self._resident: Dict[int, int] = {}
        self._pinned_handles: Dict[str, BlockHandle] = {}
        self.stats = SwapStats()
        self._loader = ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix="swapnet-loader")

    # -------------------------------------------------------------- ledger
    @property
    def resident_bytes(self) -> int:
        return sum(self._resident.values())

    def _ledger_add(self, handle: BlockHandle) -> None:
        self._resident[id(handle)] = handle.resident_bytes
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.resident_bytes)
        if self.budget is not None and self.resident_bytes > self.budget:
            # The paper treats this as a scheduling bug: blocks must fit b.
            raise MemoryError(
                f"resident {self.resident_bytes/1e6:.1f} MB exceeds budget "
                f"{self.budget/1e6:.1f} MB (mode={self.mode})")

    def _ledger_drop(self, handle: BlockHandle) -> None:
        self._resident.pop(id(handle), None)

    # -------------------------------------------------------------- swap-in
    def _load_unit(self, name: str) -> Tuple[dict, int, float, float]:
        """Returns (params, ledger_bytes, io_s, asm_s)."""
        skel = self.store.skeletons[name]
        path = self.store._path(name)
        n = skel.nbytes
        if n == 0:                      # parameter-less unit (pool/gap/...)
            return assemble_np(skel, np.zeros(0, np.uint8)), 0, 0.0, 0.0

        if self.mode == "copy_in":
            t0 = time.perf_counter()
            with open(path, "rb") as fh:       # read(): page-cache copy
                raw = fh.read()
            staged = np.frombuffer(raw, np.uint8).copy()   # staging copy
            t1 = time.perf_counter()
            host_tree = assemble_np(skel, staged)
            dev = jax.tree.map(jnp.asarray, host_tree)     # device transfer
            if self.gpu_dispatch:
                dev = jax.tree.map(jnp.array, dev)         # dispatch copy (.to('cuda'))
                extra = 3 * n
            else:
                extra = 2 * n
            t2 = time.perf_counter()
            return dev, extra, t1 - t0, t2 - t1

        # zero-copy I/O path (snet / dummy_asm): memmap = direct fetch channel
        t0 = time.perf_counter()
        buf = np.memmap(path, dtype=np.uint8, mode="r")
        t1 = time.perf_counter()
        if self.mode == "dummy_asm":
            host_tree = assemble_dummy(skel, buf)          # dummy-model copies
            dev = jax.tree.map(jnp.asarray, host_tree)
            extra = 2 * n
        else:
            host_tree = assemble_np(skel, buf)             # views: zero copy
            dev = jax.tree.map(jnp.asarray, host_tree)     # the one DMA
            extra = n
        t2 = time.perf_counter()
        return dev, extra, t1 - t0, t2 - t1

    def swap_in(self, names: Sequence[str]) -> BlockHandle:
        params, total, ledger, io_s, asm_s = [], 0, 0, 0.0, 0.0
        for name in names:
            if name in self.pinned and name in self._pinned_handles:
                params.append(self._pinned_handles[name].params[0])
                continue
            p, extra, io, asm = self._load_unit(name)
            n = self.store.nbytes(name)
            params.append(p)
            total += n
            ledger += extra
            io_s += io
            asm_s += asm
            if name in self.pinned:
                h = BlockHandle([name], [p], n, extra, io, asm)
                self._pinned_handles[name] = h
                self._ledger_add(h)
                ledger -= extra
                total -= n
        handle = BlockHandle(list(names), params, total, ledger, io_s, asm_s)
        self._ledger_add(handle)
        self.stats.t_in.append(io_s + asm_s)
        self.stats.t_in_io.append(io_s)
        self.stats.t_in_asm.append(asm_s)
        self.stats.bytes_swapped += total
        return handle

    def prefetch(self, names: Sequence[str]) -> Future:
        """Double buffering: loader thread fetches the next block while the
        executor runs the current one (paper Fig. 10)."""
        return self._loader.submit(self.swap_in, list(names))

    # -------------------------------------------------------------- swap-out
    def swap_out(self, handle: BlockHandle) -> float:
        """Write-back-free: parameters are immutable — drop references, GC.
        Returns t_out."""
        t0 = time.perf_counter()
        handle.params = []
        self._ledger_drop(handle)
        gc.collect(0)
        dt = time.perf_counter() - t0
        self.stats.t_out.append(dt)
        return dt

    def record_exec(self, seconds: float) -> None:
        self.stats.t_ex.append(seconds)

    def close(self) -> None:
        self._loader.shutdown(wait=True)
