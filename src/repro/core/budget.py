"""Cross-model memory budget allocation (paper §6.2.2, Eq. 1).

A_i = (M_i / sum M) * (1 - 1/n) * M  +  (PS_i / sum PS) * (1/n) * M
with performance score PS_i = u_i * latency_i / memory_i (urgency-weighted).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence


@dataclass
class ModelDemand:
    name: str
    memory: float          # M_i, bytes required (model size)
    latency: float         # direct-inference latency estimate (s)
    urgency: float = 1.0   # u_i, user-configured


def performance_score(d: ModelDemand) -> float:
    return d.urgency * d.latency / max(d.memory, 1.0)


def allocate_budgets(demands: Sequence[ModelDemand], available: float) -> List[float]:
    """Paper Eq. 1. If everything fits, give each model what it asks for."""
    total = sum(d.memory for d in demands)
    if total <= available:
        return [d.memory for d in demands]
    n = len(demands)
    ps = [performance_score(d) for d in demands]
    ps_sum = max(sum(ps), 1e-30)
    return [
        (d.memory / total) * (1.0 - 1.0 / n) * available
        + (p / ps_sum) * (1.0 / n) * available
        for d, p in zip(demands, ps)
    ]
