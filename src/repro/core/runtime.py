"""SwappedModel: end-to-end swapped inference of any repro model (paper §3).

Splits a model into swappable units (embedding, each layer, head), stores
them via a pluggable block store (``store_backend``: mmap | rawio | quant,
see repro/store/), and executes a forward pass block-by-block under a
memory budget with a depth-m prefetch pipeline (m=2 is the paper's double
buffer; deeper pipelines absorb swap-in jitter). With the default (mmap)
backend the output is bit-identical to the in-memory model (lossless — the
paper's headline property); the quant backend trades a documented bounded
quantization error for 4x (int8) to 8x (int4) less swap-in I/O, keeps
units quantized-RESIDENT (fp is never materialized for MLP/head weights —
they stream through the fused dequant-matmul kernel; other consumers
dequantize at use), and lets the block planner pack more layers per block
since the ledger is charged payload bytes.

Engines may share a MemoryLedger and BlockCache with other models — the
multi-DNN serving path (core/multi_model.py) relies on this to keep several
co-resident models under ONE budget while hot units stay cached.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import (DelayModel, LayerInfo, layer_flops,
                                   resident_infos)
from repro.core.partition import BlockPlan, PartitionPlanner
from repro.core.swap_engine import BlockCache, MemoryLedger, SwapEngine
from repro.kernels.qtensor import (QuantizedTensor, cast_unit_params,
                                   materialize_tree)
from repro.kernels.swap_linear import vmem_bytes
from repro.models.layers import linear, rms_norm, softcap
from repro.store import build_store
from repro.models.transformer import Model, apply_layer


def swap_schedule(eng: SwapEngine, blocks, unit_names: Sequence[str], m: int):
    """Drive the depth-m prefetch pipeline over ``blocks``.

    Yields (block_index, lo, hi, handle) with the handle's block resident;
    swap-out happens after the caller's body returns control. Issues the load
    of block i only once block i-m has been freed, so at most m blocks are
    ever resident — the executor-side mirror of partition.simulate_pipeline.
    """
    m = max(m, 1)
    futs: deque = deque()
    issued = 0

    def pump(limit: int) -> None:
        nonlocal issued
        while issued < min(limit, len(blocks)):
            lo, hi = blocks[issued]
            futs.append(eng.prefetch(list(unit_names[lo:hi])))
            issued += 1

    pump(m)
    try:
        for bi, (lo, hi) in enumerate(blocks):
            handle = eng.wait(futs.popleft())
            try:
                yield bi, lo, hi, handle
            finally:
                eng.swap_out(handle)
            pump(bi + 1 + m)
    finally:
        # Abandoned mid-run (body raised, wait raised, or caller closed the
        # generator): drain in-flight prefetches so their ledger bytes and
        # cache leases are released — a shared ledger must not keep charging
        # a failed request's blocks against every other tenant's budget.
        while futs:
            try:
                eng.swap_out(futs.popleft().result())
            except Exception:
                continue


@dataclass
class PassState:
    """A swapped forward pass, resumable at block boundaries.

    The serving scheduler's preemption unit: a pass that yields between
    blocks carries everything needed to continue later — the activation,
    the position carrier, and the index of the next block — so a preempted
    request re-executes NOTHING on resume (bit-identical to an
    uninterrupted pass). ``blocks`` AND the pipeline depth ``m`` are
    snapshotted at pass start: a live budget re-plan
    (``MultiModelRuntime.replan_budgets``) only affects passes that start
    after it, never one already in flight — resuming old blocks at a new
    plan's (possibly deeper) m could hold more bytes than the old plan's
    budget slice promised."""
    blocks: List[Tuple[int, int]]
    m: int = 2
    x: Any = None
    positions: Any = None
    next_block: int = 0
    t_active: float = 0.0     # wall clock while actually executing (not paused)
    preemptions: int = 0
    logits: Any = None
    caches: Any = None        # layer_id -> prefill cache (collect_cache=True)

    @property
    def done(self) -> bool:
        return self.next_block >= len(self.blocks)


@dataclass
class Unit:
    name: str
    kind: str                 # embed | head | dense | moe | mamba2 | rwkv6 | shared_attn
    layer_id: Optional[int]
    params: dict


def split_units(model: Model, params: dict) -> List[Unit]:
    """The paper's get_layers(Net): one-time layer-wise division."""
    cfg = model.cfg
    units: List[Unit] = []
    head_p = {k: params[k] for k in ("embed", "frontend", "mask_emb")
              if k in params}
    if head_p:
        units.append(Unit("embed", "embed", None, head_p))
    for si, seg in enumerate(model.plan):
        if not seg.scanned:
            units.append(Unit("shared_attn", "shared_attn",
                              seg.layer_ids[0], params["shared_attn"]))
            continue
        stacked = params["segments"][si]
        for j, lid in enumerate(seg.layer_ids):
            p = jax.tree.map(lambda a, _j=j: np.asarray(a[_j]), stacked)
            units.append(Unit(f"layer{lid:03d}_{seg.kind}", seg.kind, lid, p))
    tail = {"final_norm": params["final_norm"]}
    if "lm_head" in params:
        tail["lm_head"] = params["lm_head"]
    elif cfg.tie_embeddings and cfg.embed_inputs:
        # tied head: materialize the transposed table in the head unit so the
        # embed block need not stay resident (storage, not memory, pays)
        tail["lm_head"] = np.asarray(params["embed"]).T.copy()
    units.append(Unit("head", "head", None, tail))
    return units


def unit_infos(model: Model, units: Sequence[Unit], batch: int,
               seq: int) -> List[LayerInfo]:
    """Model info table rows (paper Table 2) aligned 1:1 with units."""
    cfg = model.cfg
    rows = []
    for u in units:
        size = sum(np.asarray(l).nbytes for l in jax.tree.leaves(u.params))
        depth = len(jax.tree.leaves(u.params))
        if u.kind == "embed":
            f = 2.0 * batch * seq * cfg.d_model
        elif u.kind == "head":
            has_head = "lm_head" in u.params
            f = 2.0 * batch * cfg.d_model * cfg.vocab_size * (1 if has_head else 1)
        else:
            kind = "dense" if u.kind == "shared_attn" else u.kind
            f = layer_flops(cfg, kind, u.params, batch, seq)
        rows.append(LayerInfo(u.name, int(size), depth, float(f)))
    return rows


def resolve_backend(store_backend: Optional[str], mode: str) -> str:
    """Default the store backend and reject nonsensical combinations: the
    engine's ablation ``mode`` flags reinterpret the RAW file format, so
    they compose only with the mmap backend (rawio IS the copy_in arm;
    quant files cannot be read through the raw paths)."""
    backend = store_backend or "mmap"
    if backend != "mmap" and mode != "snet":
        raise ValueError(f"store backend {backend!r} requires mode='snet' "
                         f"(got mode={mode!r})")
    return backend


def store_opts(backend: str, gpu_dispatch: bool, precision: str = "int8",
               fused: bool = False) -> dict:
    """Per-backend build options derived from the executor flags.

    For the quant backend, ``precision`` picks the swap-unit bit-width
    (int8 | int4, or ``mixed`` for a per-unit calibration plan — the plan
    itself arrives via the ``store_options`` overlay as ``plan=...``, and
    the store keeps any unit the plan omits raw) and ``fused`` turns
    eager dequant OFF: units come back as QuantizedTensor leaves that
    linear layers stream through the fused dequant-matmul kernel
    (non-matmul consumers dequantize at use)."""
    if backend == "rawio":
        return {"gpu_dispatch": gpu_dispatch}
    if backend == "quant":
        assert precision in ("int8", "int4", "mixed"), precision
        return {"bits": 4 if precision == "int4" else 8, "eager": not fused}
    if backend == "faulty":
        # chaos arm: fault injection over the zero-copy path by default;
        # callers tune inner/p/seed via the ``store_options`` pass-through
        return {"inner": "mmap"}
    return {}


def kernel_vmem_working_set(precision: str, dtype: str = "bfloat16",
                            block_m: int = 256, block_n: int = 256,
                            block_k: int = 512) -> int:
    """Per-kernel VMEM working set of the weight-stream matmul at the
    default tiling for a store precision (the figure SwapStats reports:
    the fused path shrinks the weight window 2x int8 / 4x int4)."""
    item = jnp.dtype(dtype).itemsize
    # "mixed" reports the int8 window: the CONSERVATIVE per-kernel figure
    # (any int4-assigned unit streams a strictly smaller one)
    w_bits = {"fp": None, "int8": 8, "int4": 4, "mixed": 8}[precision]
    return vmem_bytes(block_m, block_n, block_k, item, w_bits=w_bits)


class SwappedSequential:
    """Generic swapped executor over an arbitrary unit list (used by the
    scenario benchmarks for the paper's conv workloads)."""

    def __init__(self, named_units, apply_fn, workdir: str,
                 mode: str = "snet", budget: Optional[int] = None,
                 gpu_dispatch: bool = False, prefetch_depth: int = 2,
                 ledger: Optional[MemoryLedger] = None,
                 cache: Optional[BlockCache] = None,
                 store_backend: Optional[str] = None,
                 precision: str = "int8", fused: bool = False,
                 store_options: Optional[dict] = None):
        """named_units: [(name, params)]; apply_fn(i, params, x) -> x.

        ``precision``/``fused`` apply to the quant backend only: fused=True
        hands apply_fn QuantizedTensor weight leaves (stream through the
        fused dequant-matmul via layers.linear, or materialize at use), so
        apply_fn must be quantization-aware (vision.apply_layer is).
        ``store_options`` overlays extra backend build options on top of the
        derived ones (e.g. ``inner``/``p``/``seed`` for the faulty arm)."""
        self.named_units = list(named_units)
        self.apply_fn = apply_fn
        self.prefetch_depth = max(prefetch_depth, 1)
        self.store_backend = resolve_backend(store_backend, mode)
        self.precision = precision if self.store_backend == "quant" else "fp"
        self.fused = fused and self.store_backend == "quant"
        opts = store_opts(self.store_backend, gpu_dispatch, precision, fused)
        opts.update(store_options or {})
        if self.precision == "mixed" and opts.get("plan") is None:
            raise ValueError("precision='mixed' needs a calibration plan: "
                             "pass store_options={'plan': ...} "
                             "(see repro.calibrate.calibrate_sequential)")
        self.store = build_store(self.named_units, workdir,
                                 backend=self.store_backend, **opts)
        self.engine = SwapEngine(self.store, mode=mode, budget=budget,
                                 gpu_dispatch=gpu_dispatch,
                                 ledger=ledger, cache=cache)
        # the eager quant arm dequantizes BEFORE the matmul, so its kernel
        # streams fp tiles: only the fused path earns the shrunken figure
        self.engine.vmem_working_set = kernel_vmem_working_set(
            self.precision if self.fused else "fp", "float32")
        self.plan: Optional[BlockPlan] = None
        self._block_fns: Dict[Tuple[int, int], Any] = {}
        # calibration seam (repro/calibrate): fn(global_unit_index, params)
        # -> params, applied on host after swap-in, before the jitted block
        # fn — lets the sensitivity profiler substitute one unit's weights
        # per pass while riding the production swap pipeline
        self.param_override: Optional[Any] = None

    def _block_fn(self, lo: int, hi: int):
        """One jitted function per block (layers lo..hi fused): block
        granularity is the execution unit, matching how the paper compiles
        each block into an executable object."""
        key = (lo, hi)
        if key not in self._block_fns:
            def fn(params_list, x, _lo=lo, _hi=hi):
                for off in range(_hi - _lo):
                    x = self.apply_fn(_lo + off, params_list[off], x)
                return x
            self._block_fns[key] = jax.jit(fn)
        return self._block_fns[key]

    def partition_with(self, infos, budget: int, dm: DelayModel,
                       delta: float = 0.05) -> BlockPlan:
        # plan against RESIDENT unit costs: quantized swap units shrink the
        # working set the budget must hold (rows align 1:1 with the units)
        infos = resident_infos(infos, self.engine.store,
                               [n for n, _ in self.named_units])
        planner = PartitionPlanner(infos, dm, m=self.prefetch_depth)
        self.plan, self.table = planner.best_partition(budget, delta)
        self.planner = planner
        return self.plan

    def set_plan(self, points) -> None:
        self.plan = BlockPlan(tuple(points), len(self.named_units),
                              m=self.prefetch_depth)

    def forward(self, x) -> Tuple[Any, Dict]:
        assert self.plan is not None
        eng = self.engine
        names = [n for n, _ in self.named_units]
        t_start = time.perf_counter()
        for bi, lo, hi, handle in swap_schedule(eng, self.plan.blocks(),
                                                names, self.plan.m):
            t0 = time.perf_counter()
            ps = handle.params
            if self.param_override is not None:
                ps = [self.param_override(lo + off, p)
                      for off, p in enumerate(ps)]
            x = self._block_fn(lo, hi)(ps, x)
            x = jax.block_until_ready(x)
            eng.record_exec(time.perf_counter() - t0)
        total = time.perf_counter() - t_start
        st = eng.stats
        return x, {"latency_s": total,
                   "peak_resident_mb": st.peak_resident / 1e6,
                   "t_in": list(st.t_in), "t_ex": list(st.t_ex),
                   "t_out": list(st.t_out),
                   "overlap_efficiency": st.overlap_efficiency(),
                   "cache_hit_rate": st.cache_hit_rate(),
                   "store_backend": self.store_backend,
                   "precision": self.precision,
                   "bytes_swapped": st.bytes_swapped,
                   "bytes_logical": st.bytes_logical,
                   "bytes_resident_quantized": st.bytes_resident_quantized,
                   "bytes_by_precision": dict(st.bytes_by_precision),
                   "vmem_working_set": st.vmem_working_set,
                   "retries": st.retries, "faults": dict(st.faults)}

    def close(self):
        self.engine.close()


class SwappedModel:
    """Executes ``model.prefill``-equivalent inference by swapping blocks."""

    def __init__(self, model: Model, params: dict, workdir: str,
                 mode: str = "snet", budget: Optional[int] = None,
                 gpu_dispatch: bool = False, prefetch_depth: int = 2,
                 ledger: Optional[MemoryLedger] = None,
                 cache: Optional[BlockCache] = None,
                 name: Optional[str] = None,
                 store_backend: Optional[str] = None,
                 precision: Optional[str] = None,
                 store_options: Optional[dict] = None):
        self.model = model
        self.cfg = model.cfg
        self.name = name or model.cfg.name
        self.prefetch_depth = max(prefetch_depth, 1)
        self.store_backend = resolve_backend(store_backend, mode)
        if self.store_backend == "quant" and not self.cfg.quant_eligible:
            # per-model eligibility knob (configs): architectures whose
            # dynamics amplify weight error serve from the exact store
            self.store_backend = "mmap"
        # precision axis: fp for exact stores; else the caller's override or
        # the config's per-model swap precision (int8 | int4). Quant units
        # stay quantized-RESIDENT (no eager dequant): 2-D MLP/head weights
        # stream through the fused dequant-matmul, the rest dequantize at
        # use (see kernels/qtensor.cast_unit_params).
        if self.store_backend == "quant":
            self.precision = precision or self.cfg.swap_precision
        else:
            self.precision = "fp"
        self.units = split_units(model, params)
        prefix = f"{name}/" if name else ""
        for u in self.units:            # namespace units per model so a
            u.name = prefix + u.name    # shared cache/store never collides
        pinned = tuple({u.name for u in self.units if u.kind == "shared_attn"})
        # de-dup shared units in the store
        seen, store_units = set(), []
        for u in self.units:
            if u.name in seen:
                continue
            seen.add(u.name)
            store_units.append((u.name, u.params))
        opts = store_opts(self.store_backend, gpu_dispatch,
                          self.precision, fused=True)
        opts.update(store_options or {})
        if self.precision == "mixed" and opts.get("plan") is None:
            # a mixed store without a plan would silently store EVERY unit
            # raw; the calibration pass must run first (multi_model and
            # serve.py do this automatically)
            raise ValueError("precision='mixed' needs a calibration plan: "
                             "pass store_options={'plan': ...} "
                             "(see repro.calibrate.calibrate_model)")
        self.store = build_store(store_units, workdir,
                                 backend=self.store_backend, **opts)
        self.engine = SwapEngine(self.store, mode=mode, budget=budget,
                                 gpu_dispatch=gpu_dispatch, pinned=pinned,
                                 ledger=ledger, cache=cache)
        self.engine.vmem_working_set = kernel_vmem_working_set(
            self.precision, self.cfg.dtype)
        self.plan: Optional[BlockPlan] = None
        self._jitted: Dict[str, Any] = {}
        # calibration seam (repro/calibrate): fn(Unit, params) -> params,
        # applied after swap-in inside forward_partial's unit loop
        self.param_override: Optional[Any] = None

    # ------------------------------------------------------------ partition
    def partition(self, budget: int, dm: DelayModel, batch: int, seq: int,
                  delta: float = 0.05) -> BlockPlan:
        infos = unit_infos(self.model, self.units, batch, seq)
        # block-plan search sees the RESIDENT working set: quantized units
        # cost their payload, so the same budget packs more layers per block
        infos = resident_infos(infos, self.engine.store,
                               [u.name for u in self.units])
        planner = PartitionPlanner(infos, dm, m=self.prefetch_depth)
        self.plan, self.table = planner.best_partition(budget, delta)
        self.planner = planner
        return self.plan

    def set_plan(self, points: Tuple[int, ...]) -> None:
        self.plan = BlockPlan(tuple(points), len(self.units),
                              m=self.prefetch_depth)

    # ------------------------------------------------------------ apply fns
    def _head_logits(self, uparams: dict, h):
        """Final-norm + lm_head projection; a quantized head streams through
        the fused kernel (vocab projections are the odd-shaped case the
        padded swap_linear grid now covers)."""
        cfg = self.cfg
        h = rms_norm(h, jnp.asarray(uparams["final_norm"]).astype(h.dtype),
                     cfg.norm_eps, plus_one=cfg.post_norms)
        w = uparams.get("lm_head")
        if w is None:
            raise ValueError("tied head needs the embed unit resident; "
                             "SwappedModel stores lm_head explicitly")
        if isinstance(w, QuantizedTensor):
            logits = linear(h.astype(jnp.float32), w)
        else:
            logits = h.astype(jnp.float32) @ jnp.asarray(w, jnp.float32)
        return softcap(logits, cfg.final_logit_softcap)

    def _apply_unit(self, unit: Unit, uparams: dict, x, positions, batch,
                    collect: Optional[dict] = None):
        cfg = self.cfg
        if unit.kind == "embed":
            # embeddings are gather/frontend consumers: dequantize at use
            x, positions = self.model._embed(
                materialize_tree(uparams), batch, "prefill")
            return x, positions
        if unit.kind == "head":
            return self._head_logits(uparams, x), positions
        kind = "dense" if unit.kind == "shared_attn" else unit.kind
        is_local = cfg.is_local_layer(unit.layer_id)
        p = cast_unit_params(uparams, jnp.dtype(cfg.dtype))
        x, new_cache, _ = apply_layer(cfg, kind, p, x, positions, is_local,
                                      None, None, "prefill")
        if collect is not None and unit.layer_id is not None:
            # prefill cache (e.g. the prompt's K/V) captured per layer so a
            # serving admit can seed the paged pool without a second pass
            collect[unit.layer_id] = new_cache
        return x, positions

    # ------------------------------------------------------------ decode
    def _unit_cache_struct(self, unit: Unit, batch: int, max_len: int):
        """Decode cache ShapeDtypeStructs for one layer unit."""
        import jax.numpy as jnp
        from repro.models import ssm as ssm_mod
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        kind = "dense" if unit.kind == "shared_attn" else unit.kind
        B, L = batch, max_len
        if kind == "mamba2":
            d_inner, nh, ds = ssm_mod.mamba2_dims(cfg)
            return {"h": jnp.zeros((B, nh, cfg.ssm.head_dim, ds), jnp.float32),
                    "conv": jnp.zeros((B, cfg.ssm.d_conv - 1, d_inner + 2 * ds), dt)}
        if kind == "rwkv6":
            nh, rhd = ssm_mod.rwkv6_dims(cfg)
            return {"S": jnp.zeros((B, nh, rhd, rhd), jnp.float32),
                    "shift1": jnp.zeros((B, 1, cfg.d_model), dt),
                    "shift2": jnp.zeros((B, 1, cfg.d_model), dt)}
        if cfg.mla is not None:
            m = cfg.mla
            return {"c_kv": jnp.zeros((B, L, m.kv_lora_rank), dt),
                    "k_rope": jnp.zeros((B, L, m.qk_rope_head_dim), dt)}
        KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        return {"k": jnp.zeros((B, L, KV, hd), dt),
                "v": jnp.zeros((B, L, KV, hd), dt)}

    def decode_loop(self, prompt_tokens, max_new_tokens: int = 8,
                    max_len: int = 128) -> Tuple[Any, Dict]:
        """Greedy generation with WEIGHT-BLOCK STREAMING (paper §10: LLMs on
        edge AI devices): every decode step swaps the model's blocks through
        the memory window with the m=2 pipeline; only the KV/state caches and
        one or two weight blocks are resident at any time.

        prompt_tokens: [B, S] int32. Returns (generated [B, max_new], stats).
        """
        assert self.plan is not None and self.cfg.supports_decode()
        cfg = self.cfg
        B, S = prompt_tokens.shape
        caches = {i: self._unit_cache_struct(u, B, max_len)
                  for i, u in enumerate(self.units) if u.layer_id is not None}

        unit_names = [u.name for u in self.units]

        def run_tokens(tokens, pos0):
            """Teacher-forced pass, one token at a time, swapped."""
            eng = self.engine
            blocks = self.plan.blocks()
            last_logits = None
            for t in range(tokens.shape[1]):
                tok = tokens[:, t:t + 1]
                pos = jnp.full((B,), pos0 + t, jnp.int32)
                batch = {"token": tok, "pos": pos}
                if cfg.rope_type == "mrope":
                    batch["positions"] = jnp.full((B, 1, 3), pos0 + t, jnp.int32)
                x = positions = None
                gen = swap_schedule(eng, blocks, unit_names, self.plan.m)
                try:
                    for bi, lo, hi, handle in gen:
                        for ui, p in zip(range(lo, hi), handle.params):
                            unit = self.units[ui]
                            if unit.kind == "embed":
                                x, positions = self.model._embed(
                                    materialize_tree(p), batch, "decode")
                            elif unit.kind == "head":
                                last_logits = self._head_logits(p, x)
                            else:
                                kind = "dense" if unit.kind == "shared_attn" else unit.kind
                                pc = cast_unit_params(p, jnp.dtype(cfg.dtype))
                                x, caches[ui], _ = apply_layer(
                                    cfg, kind, pc, x, positions,
                                    cfg.is_local_layer(unit.layer_id),
                                    caches[ui], pos, "decode")
                finally:
                    # a raising step body must drain in-flight prefetches
                    # NOW (ledger bytes, cache leases), not at gc time
                    gen.close()
            return last_logits

        t0 = time.time()
        logits = run_tokens(prompt_tokens, 0)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for step in range(max_new_tokens):
            out.append(tok)
            if S + step + 1 >= max_len or step == max_new_tokens - 1:
                break
            logits = run_tokens(tok, S + step)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        gen = jnp.concatenate(out, axis=1)
        return gen, {"wall_s": time.time() - t0,
                     "peak_resident_mb": self.engine.stats.peak_resident / 1e6}

    def decode_step_paged(self, batch: dict, view) -> jax.Array:
        """One BATCHED decode step through the paged KV cache (continuous
        batching, serving/batch_engine.py): the model's weight blocks stream
        through the memory window exactly ONCE and their swap-in cost
        amortizes over every active sequence — the step cost is
        ~(swap time) + B * (per-token compute) instead of B * (swap time) as
        with per-sequence decode_loop calls. Attention K/V land in the page
        pool via ``view`` (serving/paged_kv.PagedBatchView), so there is no
        contiguous per-batch cache and batch membership may change freely
        between steps.

        batch: ``{"token": [B, 1], "pos": [B]}`` (+ ``"positions"`` for
        mrope). Returns last-position logits [B, 1, vocab].
        """
        assert self.plan is not None and self.cfg.supports_decode()
        cfg = self.cfg
        eng = self.engine
        names = [u.name for u in self.units]
        x = positions = logits = None
        gen = swap_schedule(eng, self.plan.blocks(), names, self.plan.m)
        try:
            for bi, lo, hi, handle in gen:
                t0 = time.perf_counter()
                for ui, p in zip(range(lo, hi), handle.params):
                    unit = self.units[ui]
                    if unit.kind == "embed":
                        x, positions = self.model._embed(
                            materialize_tree(p), batch, "decode")
                    elif unit.kind == "head":
                        logits = self._head_logits(p, x)
                    else:
                        kind = ("dense" if unit.kind == "shared_attn"
                                else unit.kind)
                        pc = cast_unit_params(p, jnp.dtype(cfg.dtype))
                        x, _, _ = apply_layer(
                            cfg, kind, pc, x, positions,
                            cfg.is_local_layer(unit.layer_id),
                            None, batch["pos"], "decode",
                            paged=view.bind(unit.layer_id))
                x = jax.block_until_ready(x)
                eng.record_exec(time.perf_counter() - t0)
        finally:
            # a raising step body must drain in-flight prefetches NOW
            # (ledger bytes, cache leases), not at gc time
            gen.close()
        return logits

    # ------------------------------------------------------------ forward
    def forward_partial(self, batch: dict, state: Optional[PassState] = None,
                        should_yield=None, collect_cache: bool = False
                        ) -> Tuple[PassState, Optional[Dict]]:
        """Swapped forward pass with block-boundary yield points.

        Runs blocks from ``state`` (fresh pass when None). After each block
        completes (and its handle is swapped out), ``should_yield(state)``
        decides whether to pause: on True the pass returns ``(state, None)``
        with in-flight prefetches drained and only cache-resident bytes still
        charged — the serving scheduler requeues the request and the executor
        is free for higher-urgency work. Resuming re-executes nothing, so a
        preempted pass stays bit-identical to an uninterrupted one.

        Returns ``(state, stats)`` with ``stats`` None while the pass is
        unfinished; on completion ``state.logits`` holds the last-position
        logits and ``stats`` matches :meth:`forward`.
        """
        assert self.plan is not None, "call partition()/set_plan() first"
        eng = self.engine
        names = [u.name for u in self.units]
        if state is None:
            state = PassState(blocks=self.plan.blocks(), m=self.plan.m,
                              caches={} if collect_cache else None)

        t_start = time.perf_counter()
        pending = state.blocks[state.next_block:]
        gen = swap_schedule(eng, pending, names, state.m)
        try:
            for bi, lo, hi, handle in gen:
                t0 = time.perf_counter()
                for u, p in zip(self.units[lo:hi], handle.params):
                    if self.param_override is not None:
                        p = self.param_override(u, p)
                    state.x, state.positions = self._apply_unit(
                        u, p, state.x, state.positions, batch,
                        collect=state.caches)
                state.x = jax.block_until_ready(state.x)
                eng.record_exec(time.perf_counter() - t0)
                state.next_block += 1
                if (should_yield is not None and not state.done
                        and should_yield(state)):
                    state.preemptions += 1
                    break
        finally:
            gen.close()     # drains in-flight prefetches on early exit
        state.t_active += time.perf_counter() - t_start
        if not state.done:
            return state, None
        x = state.x
        if x.ndim == 3 and x.shape[-1] == self.cfg.vocab_size:
            state.logits = x[:, -1:]
        else:
            state.logits = x
        st = eng.stats
        return state, {
            "latency_s": state.t_active,
            "preemptions": state.preemptions,
            "t_in": list(st.t_in), "t_ex": list(st.t_ex), "t_out": list(st.t_out),
            "peak_resident_mb": st.peak_resident / 1e6,
            "meta_mb": self.store.meta_bytes() / 1e6,
            "overlap_efficiency": st.overlap_efficiency(),
            "cache_hit_rate": st.cache_hit_rate(),
            "store_backend": self.store_backend,
            "precision": self.precision,
            "bytes_swapped": st.bytes_swapped,
            "bytes_logical": st.bytes_logical,
            "bytes_resident_quantized": st.bytes_resident_quantized,
            "bytes_by_precision": dict(st.bytes_by_precision),
            "vmem_working_set": st.vmem_working_set,
            "retries": st.retries, "faults": dict(st.faults),
        }

    def forward(self, batch: dict) -> Tuple[jax.Array, Dict]:
        """Swapped forward pass. Returns (last-position logits, stats)."""
        state, stats = self.forward_partial(batch)
        return state.logits, stats

    def close(self):
        self.engine.close()
