"""Delay abstractions (paper §6.1) + model info table (Table 2).

SwapNet exposes three per-block delays to schedulers:
    t_in  = alpha * s_i + beta * d_i + kappa   (swap-in DMA + assembly
                                                references + per-block fixed
                                                dispatch overhead)
    t_ex  = gamma * f_i                        (execution)
    t_out = eta * d_i                          (pointer reset + GC)
with (alpha, beta, gamma, eta) profiled once per device by linear regression
(Fig. 9). s_i = block bytes, d_i = parameter depth (# tensors), f_i = FLOPs.

``kappa`` is the intercept of the swap-in regression: the fixed cost every
block pays regardless of size — prefetch-future bookkeeping, the loader
thread hop, the jitted block call dispatch. The paper's linear model omits
it, which makes "more, smaller blocks" look free; with the intercept the
block-count search (``PartitionPlanner.best_partition``) has a real
optimum: finer plans expose a smaller cold first block (better pipeline
overlap) until the per-block overhead eats the gain.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class LayerInfo:
    """One row of the model info table (paper Table 2)."""
    name: str
    size: int      # bytes (s contribution)
    depth: int     # parameter tensors (d contribution)
    flops: float   # forward FLOPs at the profiled shape (f contribution)


@dataclass
class DelayModel:
    alpha: float = 1.2e-9    # s / byte        (~0.8 GB/s swap-in channel)
    beta: float = 5.2e-5     # s / reference   (paper: 50-55 us per reference)
    gamma: float = 2.0e-11   # s / FLOP
    eta: float = 1.5e-5      # s / reference
    kappa: float = 2.5e-4    # s / block       (fixed swap-in dispatch cost)

    def t_in(self, size: float, depth: float) -> float:
        return self.alpha * size + self.beta * depth + self.kappa

    def t_ex(self, flops: float) -> float:
        return self.gamma * flops

    def t_out(self, depth: float) -> float:
        return self.eta * depth

    @staticmethod
    def fit(samples_in: Sequence[Tuple[float, float, float]],
            samples_ex: Sequence[Tuple[float, float]],
            samples_out: Sequence[Tuple[float, float]]) -> "DelayModel":
        """Linear regression over profiled samples (paper Fig. 9).

        samples_in:  (size, depth, measured_t_in) — fit WITH an intercept
                     column, so the per-block fixed cost ``kappa`` is
                     estimated from the same profile instead of assumed.
                     The regression minimizes RELATIVE error (rows weighted
                     1/t): timer noise scales with the measured latency, so
                     unweighted OLS lets the biggest blocks drown the
                     depth/intercept terms that only small blocks identify
        samples_ex:  (flops, measured_t_ex)
        samples_out: (depth, measured_t_out)
        """
        A = np.asarray([(s, d, 1.0) for s, d, _ in samples_in], np.float64)
        y = np.asarray([t for *_, t in samples_in], np.float64)
        w = 1.0 / np.maximum(y, 1e-12)
        (alpha, beta, kappa), *_ = np.linalg.lstsq(A * w[:, None], y * w,
                                                   rcond=None)
        # warm-page-cache profiles can fit a (meaningless) negative
        # bandwidth or intercept; clamp — the model must stay monotone
        alpha = max(float(alpha), 0.0)
        fx = np.asarray([f for f, _ in samples_ex], np.float64)
        ty = np.asarray([t for _, t in samples_ex], np.float64)
        gamma = float(fx @ ty / max(fx @ fx, 1e-30))
        dx = np.asarray([d for d, _ in samples_out], np.float64)
        oy = np.asarray([t for _, t in samples_out], np.float64)
        eta = float(dx @ oy / max(dx @ dx, 1e-30))
        return DelayModel(float(alpha), float(beta), gamma, eta,
                          max(float(kappa), 0.0))

    def calibrated(self, store, names: Optional[Sequence[str]] = None
                   ) -> "DelayModel":
        """Re-anchor ``alpha`` to a STORE's measured swap channel.

        The profiled coefficients describe one channel (the mmap profile
        rig). Store backends change the per-byte cost structurally — the
        quantized store adds host unpack/dequant work per byte, rawio adds
        staging copies — and planning a backend with another backend's
        alpha puts the block-count search in the wrong regime entirely: it
        under-costs fused swap-ins ~3x, concludes swap-in is nearly free,
        and stops at a shallow plan whose huge cold first block caps the
        achievable overlap (the PR 6 fused-path gap, planner half).

        Reads every unit once through ``store.read_unit`` (warm page
        cache, so this measures the CPU-side channel cost — read syscall,
        unpack/dequant, device dispatch — not cold storage latency) and
        rescales ONLY alpha so the model's total swap-in time over the
        store equals the measured total, net of the depth/intercept terms,
        which keep their profiled values:

            alpha' = max(0, (sum t - beta * sum d - kappa * n) / sum s)

        with s the unit's RESIDENT bytes — the same currency
        ``resident_infos`` feeds the planner."""
        import jax as _jax
        names = list(store.order) if names is None else list(names)
        t_sum = s_sum = d_sum = n_read = 0.0
        for name in names:
            if store.skeletons[name].nbytes == 0:
                continue
            t0 = time.perf_counter()
            r = store.read_unit(name)
            t_sum += time.perf_counter() - t0
            s_sum += store.resident_nbytes(name)
            d_sum += len(_jax.tree.leaves(r.params))
            n_read += 1
        if s_sum <= 0:
            return self
        alpha = (t_sum - self.beta * d_sum - self.kappa * n_read) / s_sum
        return dataclasses.replace(self, alpha=max(alpha, 0.0))

    def r2_in(self, samples_in) -> float:
        y = np.asarray([t for *_, t in samples_in])
        pred = np.asarray([self.t_in(s, d) for s, d, _ in samples_in])
        ss = np.sum((y - y.mean()) ** 2)
        return 1.0 - float(np.sum((y - pred) ** 2) / max(ss, 1e-30))


def resident_infos(infos: Sequence[LayerInfo], store,
                   names: Optional[Sequence[str]] = None) -> List[LayerInfo]:
    """Re-cost the info table in RESIDENT bytes so ``simulate_pipeline`` /
    the block-plan search see the working set the ledger will actually be
    charged: quantized-resident units (the fused swap path) cost their
    stored payload — 4-8x less than logical — so plans pack more layers per
    block under the same budget. ``names`` aligns rows with store unit
    names when they differ from ``LayerInfo.name`` (SwappedSequential);
    ``min`` keeps ablation backends whose resident cost EXCEEDS logical
    (rawio's staging copies) planned at logical size, matching the seed's
    behaviour for them."""
    names = [r.name for r in infos] if names is None else list(names)
    out = []
    for r, name in zip(infos, names):
        try:
            resident = store.resident_nbytes(name)
        except KeyError:
            out.append(r)
            continue
        out.append(dataclasses.replace(r, size=min(r.size, resident)))
    return out


def packing_density(plan) -> float:
    """Mean layers per block of a BlockPlan — the figure the mixed-precision
    policy maximizes (more layers per block = fewer, larger, better-
    overlapped swap-ins; see repro/calibrate/policy.py)."""
    return plan.n_layers / plan.n_blocks


# ---------------------------------------------------------------- info table
def _matmul_params(tree) -> int:
    import jax
    return sum(l.size for l in jax.tree.leaves(tree) if getattr(l, "ndim", 0) >= 2)


def _tree_bytes(tree) -> int:
    import jax
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def _tree_depth(tree) -> int:
    import jax
    return len(jax.tree.leaves(tree))


def layer_flops(cfg: ModelConfig, kind: str, tree, batch: int, seq: int) -> float:
    """Forward FLOPs of one layer at (batch, seq). Matmuls: 2*params*tokens;
    attention adds the 4*B*S*S_kv*H*hd score/value term; MoE counts only
    active experts."""
    T = batch * seq
    mm = _matmul_params(tree)
    if kind in ("dense", "moe", "shared_attn") and cfg.moe is not None and kind == "moe":
        e = cfg.moe
        per_expert = 3 * cfg.d_model * e.d_expert
        mm = mm - e.n_routed * per_expert + e.top_k * per_expert
    f = 2.0 * mm * T
    if kind in ("dense", "moe", "shared_attn"):
        skv = seq if cfg.sliding_window is None else min(seq, cfg.sliding_window)
        hd = cfg.resolved_head_dim
        f += 4.0 * batch * seq * skv * cfg.n_heads * hd / 2  # causal halves it
    elif kind in ("mamba2", "rwkv6"):
        s = cfg.ssm
        nh = (cfg.d_model * (s.expand if s.kind == "mamba2" else 1)) // s.head_dim
        state = s.d_state if s.kind == "mamba2" else s.head_dim
        f += 6.0 * T * nh * s.head_dim * state
    return f


def model_info_table(model, params: dict, batch: int, seq: int) -> List[LayerInfo]:
    """Per swappable unit: embedding, every layer (segments unstacked), head.
    This is the paper's per-DNN meta file (Table 2)."""
    import jax
    cfg = model.cfg
    rows: List[LayerInfo] = []

    head_units = {}
    for k in ("embed", "frontend", "mask_emb"):
        if k in params:
            head_units[k] = params[k]
    if head_units:
        rows.append(LayerInfo("embed", _tree_bytes(head_units),
                              _tree_depth(head_units),
                              2.0 * batch * seq * cfg.d_model))

    for si, seg in enumerate(model.plan):
        if not seg.scanned:
            p = params["shared_attn"]
            rows.append(LayerInfo(f"shared_attn@{seg.layer_ids[0]}",
                                  _tree_bytes(p), _tree_depth(p),
                                  layer_flops(cfg, "dense", p, batch, seq)))
            continue
        stacked = params["segments"][si]
        for j, lid in enumerate(seg.layer_ids):
            p = jax.tree.map(lambda a: a[j], stacked)
            rows.append(LayerInfo(f"{seg.kind}@{lid}", _tree_bytes(p),
                                  _tree_depth(p),
                                  layer_flops(cfg, seg.kind, p, batch, seq)))

    tail = {"final_norm": params["final_norm"]}
    if "lm_head" in params:
        tail["lm_head"] = params["lm_head"]
    rows.append(LayerInfo("head", _tree_bytes(tail), _tree_depth(tail),
                          2.0 * _matmul_params(tail) * batch * seq
                          + 2.0 * batch * seq * cfg.d_model * cfg.vocab_size
                          * (0 if "lm_head" in tail else 1)))
    return rows
