"""Assembly by reference (paper §5): flat block buffers + skeletons.

The paper replaces the framework's *dummy model* (a same-size random-weight
placeholder that parameters are copied into, doubling peak memory and paying a
per-tensor copy) with a **skeleton**: an object holding only pointers, indexed
identically to the flat parameter file, so assembly is O(depth) pointer writes.

JAX translation: a block's parameters are stored as ONE contiguous byte
buffer (``Fil{pars}``); the ``Skeleton`` (``Obj{sket}``) is the treedef plus a
list of (offset, shape, dtype) refs — a few hundred bytes, kept resident.
``assemble`` reinterprets the buffer in place: slice + bitcast + reshape,
which XLA lowers to views over the swapped-in buffer, never a second copy of
the parameters. ``assemble_np`` does the same on the host over a memmap
(zero host-side staging — the direct-I/O analogue).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ALIGN = 128  # byte alignment per tensor (TPU-friendly, DMA-friendly)


@dataclass(frozen=True)
class Ref:
    offset: int
    shape: Tuple[int, ...]
    dtype: str

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * jnp.dtype(self.dtype).itemsize


@dataclass
class Skeleton:
    """Obj{sket}: structure + pointers, no parameters."""
    treedef: Any
    refs: List[Ref]
    nbytes: int

    @property
    def depth(self) -> int:
        """Paper's d_i: number of parameter tensors (address references)."""
        return len(self.refs)

    def meta_bytes(self) -> int:
        """Resident footprint of the skeleton itself (paper: a few KB)."""
        return 64 + 48 * len(self.refs)


def _align(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


def skeleton_of(tree) -> Skeleton:
    """The skeleton alone — layout metadata without materializing the flat
    buffer (store backends with their own payload format need only this)."""
    leaves, treedef = jax.tree.flatten(tree)
    refs, cursor = [], 0
    for leaf in leaves:
        arr = np.asarray(leaf)
        refs.append(Ref(cursor, tuple(arr.shape), str(arr.dtype)))
        cursor = _align(cursor + arr.nbytes)
    return Skeleton(treedef, refs, cursor)


def flatten_params(tree) -> Tuple[np.ndarray, Skeleton]:
    """Serialize a param pytree into (byte buffer, skeleton)."""
    skel = skeleton_of(tree)
    buf = np.zeros(skel.nbytes, np.uint8)
    for leaf, ref in zip(jax.tree.leaves(tree), skel.refs):
        arr = np.ascontiguousarray(np.asarray(leaf))
        buf[ref.offset:ref.offset + arr.nbytes] = arr.view(np.uint8).reshape(-1)
    return buf, skel


def assemble(skel: Skeleton, buf: jax.Array):
    """Assembly by reference on device: views into the flat buffer.

    Each tensor is a slice+bitcast of ``buf`` — XLA keeps these as views of
    the single swapped-in allocation (the paper's ``dst = src``)."""
    leaves = []
    for r in skel.refs:
        dt = jnp.dtype(r.dtype)
        raw = jax.lax.dynamic_slice(buf, (r.offset,), (r.nbytes,))
        if dt == jnp.uint8:
            leaves.append(raw.reshape(r.shape))
            continue
        n = r.nbytes // dt.itemsize
        arr = jax.lax.bitcast_convert_type(raw.reshape(n, dt.itemsize), dt)
        leaves.append(arr.reshape(r.shape))
    return jax.tree.unflatten(skel.treedef, leaves)


def assemble_np(skel: Skeleton, buf: np.ndarray):
    """Host-side assembly by reference: numpy views over a (mem-mapped)
    buffer — zero copies, O(depth) pointer writes (the paper's registration
    loop: same index order in Obj{sket} and Fil{pars})."""
    leaves = []
    for r in skel.refs:
        view = buf[r.offset:r.offset + r.nbytes].view(jnp.dtype(r.dtype).type)
        leaves.append(view.reshape(r.shape))
    return jax.tree.unflatten(skel.treedef, leaves)


def assemble_dummy(skel: Skeleton, buf: np.ndarray):
    """ABLATION (w/o-mod-ske): the framework's default assembly — instantiate
    a dummy model of the same size, then copy each parameter into it. Costs a
    full extra copy of the block plus per-tensor copies."""
    dummy = [np.empty(r.shape, jnp.dtype(r.dtype).type) for r in skel.refs]
    leaves = []
    for r, slot in zip(skel.refs, dummy):
        src = buf[r.offset:r.offset + r.nbytes].view(jnp.dtype(r.dtype).type)
        slot[...] = src.reshape(r.shape)          # parameter-wise memory copy
        leaves.append(slot.copy())                # dummy -> executable object
    return jax.tree.unflatten(skel.treedef, leaves)
