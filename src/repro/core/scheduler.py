"""Multi-DNN scheduling on top of SwapNet (paper §6.2).

Combines budget allocation (Eq. 1), per-model partitioning (Eq. 3/4 via the
lookup table) and run-time adaptation (§6.2.2 "Adaptively Partition and
Exchange Blocks", Fig. 18): lookup tables are precomputed per plausible block
count; a budget change only re-selects a row (index math, no re-profiling),
matching the paper's 60-70 ms adaptation path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.budget import ModelDemand, allocate_budgets
from repro.core.cost_model import DelayModel, LayerInfo
from repro.core.partition import (BlockPlan, PartitionPlanner, TableRow,
                                  create_blocks, n_blocks_for_budget,
                                  simulate_pipeline)


@dataclass
class ScheduledModel:
    name: str
    planner: PartitionPlanner
    urgency: float = 1.0
    budget: float = 0.0
    plan: Optional[BlockPlan] = None
    table: List[TableRow] = field(default_factory=list)

    def demand(self) -> ModelDemand:
        s = float(np.sum(self.planner.sizes))
        f = float(np.sum(self.planner.flops))
        return ModelDemand(self.name, s, self.planner.dm.t_ex(f), self.urgency)

    def predicted_latency(self) -> float:
        s, d, f = create_blocks(self.plan, self.planner.sizes,
                                self.planner.depths, self.planner.flops)
        return simulate_pipeline(s, d, f, self.planner.dm, self.planner.m)


def lift_to_floors(budgets: Sequence[float], floors: Sequence[float],
                   usable: float, reserved: float = 0.0) -> List[float]:
    """Lift every budget to its physical floor, funding the lifts from the
    models with headroom; donors are CLAMPED at their own floor.

    Redistribution is iterative: each round takes the outstanding deficit
    from the remaining donors in proportion to their headroom, capping each
    donor's payment at its headroom. A single proportional round already
    respects the caps when the deficit is computed against the same budgets
    it is taken from, but clamping must not rely on that coincidence — any
    upstream change to how the deficit is measured (e.g. proportional to
    BUDGET rather than headroom, or budgets mutated between the two steps)
    silently pushed donors below their floor, which downstream turns into a
    best_partition failure for a model whose budget was supposedly feasible.
    The loop is invariant-true by construction: no output ever sits below
    its floor, and the total is preserved.
    """
    floors = [float(f) for f in floors]
    out = [float(b) for b in budgets]
    if sum(floors) > usable:
        raise ValueError(
            f"available memory {usable/1e6:.1f} MB (after "
            f"{reserved/1e6:.1f} MB reserved) below the "
            f"sum of per-model floors {sum(floors)/1e6:.1f} MB")
    deficit = sum(max(f - b, 0.0) for f, b in zip(floors, out))
    out = [max(b, f) for f, b in zip(floors, out)]
    while deficit > 1e-6:
        donors = [i for i in range(len(out)) if out[i] - floors[i] > 1e-9]
        if not donors:       # float dust: usable >= sum(floors) guarantees
            break            # the true deficit is already below tolerance
        hr_total = sum(out[i] - floors[i] for i in donors)
        take = min(deficit, hr_total)
        paid = 0.0
        for i in donors:
            pay = min(out[i] - floors[i],
                      (out[i] - floors[i]) / hr_total * take)
            out[i] -= pay
            paid += pay
        deficit -= paid
        if paid <= 0.0:
            break
    return out


class MultiDNNScheduler:
    """Paper §6.2: allocate budgets across DNNs, partition each, adapt on
    budget changes. Each model runs its own depth-m prefetch pipeline to
    overlap swap-in with execution; when the models share one runtime
    (core/multi_model.py) ``reserved`` carves the shared block cache +
    pinned units out of the available memory before Eq. 1 splits the rest."""

    def __init__(self, models: Sequence[ScheduledModel], available: float,
                 delta: float = 0.05, reserved: float = 0.0):
        self.models = list(models)
        self.available = available
        self.reserved = reserved
        self.delta = delta
        self.replan()

    def replan(self) -> None:
        budgets = allocate_budgets([m.demand() for m in self.models],
                                   self.available - self.reserved)
        # Eq. 1 is share-based and can dip below a model's physical floor
        # (its largest layer). Lift those to their floor and fund the lift
        # from the models with headroom — donors CLAMPED at their own floor.
        floors = [m.planner.min_feasible_budget(self.delta)
                  for m in self.models]
        budgets = lift_to_floors(budgets, floors,
                                 self.available - self.reserved,
                                 self.reserved)
        for m, b in zip(self.models, budgets):
            m.budget = b
            m.plan, m.table = m.planner.best_partition(b, self.delta)

    def adapt(self, new_available: float) -> float:
        """Runtime adaptation (Fig. 18): returns wall-time spent adapting.
        Only re-selects lookup-table rows / re-runs the cheap partition search
        — never re-profiles layers (operation 1 is one-time)."""
        t0 = time.perf_counter()
        self.available = new_available
        self.replan()
        return time.perf_counter() - t0

    def summary(self) -> List[Dict]:
        out = []
        for m in self.models:
            out.append({
                "model": m.name,
                "budget_mb": m.budget / 1e6,
                "n_blocks": m.plan.n_blocks,
                "points": m.plan.points,
                "predicted_latency_s": m.predicted_latency(),
            })
        return out
