"""Configuration schema for the repro framework.

One ``ModelConfig`` schema expresses all assigned architecture families
(dense / ssm / moe / hybrid / vlm / audio).  ``models/transformer.py`` consumes
these configs; ``configs/<arch>.py`` instantiate them with cited numbers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434)."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 0           # routed experts
    top_k: int = 1
    n_shared: int = 0           # always-on shared experts
    d_expert: int = 0           # FFN hidden size per routed expert
    d_shared: int = 0           # FFN hidden size of the (merged) shared expert
    aux_loss_weight: float = 0.01
    router_dtype: str = "float32"
    capacity_factor: float = 1.25   # per-expert slot headroom (tokens beyond drop)


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"        # "mamba2" | "rwkv6"
    d_state: int = 64           # SSM state size (mamba2) / head size (rwkv6)
    head_dim: int = 64
    expand: int = 2             # d_inner = expand * d_model (mamba2)
    d_conv: int = 4             # depthwise conv window (mamba2)
    chunk: int = 128            # chunked-scan block size (train/prefill)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # --- attention flavour ------------------------------------------------
    attn_bias: bool = False                 # QKV bias (qwen2)
    rope_type: str = "rope"                 # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2
    sliding_window: Optional[int] = None    # SWA window (danube, gemma2 local)
    attn_chunk: Optional[int] = None        # llama4 iRoPE: block-local attention
    chunked_global_every: int = 4           # every k-th layer is global (llama4)
    layer_pattern: str = "global"           # global | swa | alt_local_global | chunked
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    query_pre_attn_scalar: Optional[float] = None   # gemma2 uses d_model/n_heads
    mla: Optional[MLAConfig] = None
    # --- mixture of experts -----------------------------------------------
    moe: Optional[MoEConfig] = None
    # --- state space / linear attention ------------------------------------
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0   # zamba2: one shared attn block every k layers
    # --- modality / head ----------------------------------------------------
    is_encoder: bool = False     # hubert: bidirectional, no decode
    embed_inputs: bool = True    # False: inputs are frontend embeddings (audio)
    n_vision_tokens: int = 0     # vlm: patch embeddings prepended by the stub
    d_frontend: int = 0          # feature dim provided by the modality stub
    tie_embeddings: bool = True
    # --- misc ----------------------------------------------------------------
    act: str = "swiglu"          # swiglu | gelu
    norm_eps: float = 1e-6
    post_norms: bool = False     # gemma2 post-attn/post-ffn norms
    dtype: str = "bfloat16"
    quant_eligible: bool = True  # may the quantized swap store serve this
                                 # model? (per-channel units; opt out
                                 # where recurrent dynamics amplify weight
                                 # error — the runtime then falls back to
                                 # the exact mmap backend)
    swap_precision: str = "int8" # quantized swap-unit precision when the
                                 # quant store serves this model: "int8"
                                 # (127 steps/channel, ~4x fewer swap bytes
                                 # than fp32) or "int4" (packed two-per-
                                 # byte, ~8x, error bound max|w[:,c]|/14) —
                                 # per-arch by error tolerance; ignored by
                                 # exact backends and when quant_eligible
                                 # is False. A serve/runtime `precision`
                                 # override wins over this default.
    source: str = ""             # citation for the config numbers

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string. Drives segment construction in the model."""
        kinds = []
        for i in range(self.n_layers):
            if self.family in ("ssm",) or (self.ssm is not None and self.hybrid_attn_every == 0 and self.family == "ssm"):
                kinds.append(self.ssm.kind)
            elif self.hybrid_attn_every > 0:
                # zamba2: shared attn block replaces every k-th position
                kinds.append("shared_attn" if (i % self.hybrid_attn_every) == (self.hybrid_attn_every - 1) else self.ssm.kind)
            elif self.moe is not None:
                kinds.append("moe")
            else:
                kinds.append("dense")
        return tuple(kinds)

    def is_local_layer(self, i: int) -> bool:
        """True if layer i uses windowed/chunked (not global) attention."""
        if self.layer_pattern == "swa":
            return True
        if self.layer_pattern == "alt_local_global":
            return i % 2 == 0   # gemma2: even layers local
        if self.layer_pattern == "chunked":
            # llama4 iRoPE: every chunked_global_every-th layer is global
            return i % self.chunked_global_every != (self.chunked_global_every - 1)
        return False

    def supports_decode(self) -> bool:
        return not self.is_encoder

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid state, or bounded (SWA/chunked)
        attention on most layers (global layers decode at O(S) per token)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.attn_chunk is not None and self.layer_pattern == "chunked":
            return True
        return self.sliding_window is not None and self.layer_pattern in ("swa", "alt_local_global")

    def n_params(self) -> int:
        """Approximate parameter count (for budgets, roofline MODEL_FLOPS)."""
        D, H, KV, hd, F, V, L = (self.d_model, self.n_heads, self.n_kv_heads,
                                 self.resolved_head_dim, self.d_ff,
                                 self.vocab_size, self.n_layers)
        kinds = self.layer_kinds()
        total = V * D * (1 if self.tie_embeddings else 2)
        for i, kind in enumerate(kinds):
            if kind in ("mamba2", "rwkv6"):
                total += self._ssm_params()
                continue
            if kind == "shared_attn" and i != kinds.index("shared_attn"):
                continue  # shared weights counted once
            if self.mla is not None:
                m = self.mla
                qd = m.qk_nope_head_dim + m.qk_rope_head_dim
                total += D * H * qd                       # q proj
                total += D * (m.kv_lora_rank + m.qk_rope_head_dim)   # kv down
                total += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                total += H * m.v_head_dim * D             # o proj
            else:
                total += D * H * hd + 2 * D * KV * hd + H * hd * D
            if kind == "moe" and self.moe is not None:
                e = self.moe
                total += D * e.n_routed                   # router
                total += e.n_routed * 3 * D * e.d_expert
                if e.n_shared:
                    total += 3 * D * (e.d_shared or e.d_expert * e.n_shared)
            else:
                n_mats = 3 if ("glu" in self.act or self.act == "swiglu") else 2
                total += n_mats * D * F
            total += 2 * D  # norms
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE top-k only)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        full = self.n_params()
        per_expert = 3 * self.d_model * e.d_expert
        inactive = (e.n_routed - e.top_k) * per_expert * sum(
            1 for k in self.layer_kinds() if k == "moe")
        return full - inactive

    def _ssm_params(self) -> int:
        s = self.ssm
        D = self.d_model
        if s.kind == "rwkv6":
            # time-mix: r,k,v,g,o projections + decay lora + channel-mix
            return 5 * D * D + 2 * D * 64 + int(3.5 * D * D) + 8 * D
        d_inner = s.expand * D
        n_heads = d_inner // s.head_dim
        return (D * (2 * d_inner + 2 * s.d_state + n_heads)   # in_proj
                + s.d_conv * (d_inner + 2 * s.d_state)        # conv
                + 2 * n_heads + d_inner                       # A, dt, D skip
                + d_inner * D)                                # out proj

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers (4 if hybrid unit needs it),
        d_model <= 512, <= 4 experts, small vocab/window."""
        d = min(self.d_model, 256)
        hd = 64
        n_heads = max(2, min(4, self.n_heads))
        n_kv = 1 if self.n_kv_heads == 1 else min(2, n_heads)
        kw = dict(
            name=self.name + "-reduced",
            n_layers=4 if self.hybrid_attn_every else 2,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=None if self.sliding_window is None else 64,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            n_vision_tokens=16 if self.n_vision_tokens else 0,
            d_frontend=64 if self.d_frontend else 0,
        )
        if self.rope_type == "mrope":
            kw["mrope_sections"] = (8, 12, 12)   # sums to head_dim/2 = 32
        if self.attn_chunk is not None:
            kw["attn_chunk"] = 8

        if self.mla is not None:
            kw["mla"] = MLAConfig(kv_lora_rank=64, qk_nope_head_dim=32,
                                  qk_rope_head_dim=16, v_head_dim=32)
        if self.moe is not None:
            kw["moe"] = replace(self.moe, n_routed=4, top_k=min(2, self.moe.top_k),
                                d_expert=128, d_shared=128 if self.moe.n_shared else 0)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=32, chunk=16)
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str        # train | prefill | decode
