"""qwen2-vl-72b — VLM with M-RoPE and dynamic resolution [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. Vision encoder
(ViT-675M) is a frontend STUB per the brief: input_specs() provides patch
embeddings at the projector output dim; we build the LM backbone.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    attn_bias=True,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),   # t/h/w split of head_dim/2 = 64
    rope_theta=1_000_000.0,
    n_vision_tokens=1024,          # stub patch-embedding count per sample
    d_frontend=1280,               # ViT output dim before projector
    act="swiglu",
    tie_embeddings=False,
    source="Qwen2-VL [arXiv:2409.12191]",
)
