"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention [arXiv:2401.16818].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    sliding_window=4096,
    layer_pattern="swa",
    rope_theta=10000.0,
    act="swiglu",
    tie_embeddings=False,
    source="H2O-Danube [arXiv:2401.16818]",
)
