"""rwkv6-3b — Finch, attention-free RNN with data-dependent decay [arXiv:2404.05892].

32L d_model=2560 d_ff=8960 vocab=65536; WKV6 head size 64 -> 40 heads.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # WKV heads = d_model / head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    rope_type="none",
    ssm=SSMConfig(kind="rwkv6", d_state=64, head_dim=64, chunk=128),
    act="relu_sq",       # rwkv channel-mix uses squared relu
    tie_embeddings=False,
    # data-dependent decay: the WKV recurrence compounds per-step weight
    # error across the sequence, so int8 swap units are not worth the I/O
    quant_eligible=False,
    source="RWKV-6 Finch [arXiv:2404.05892]",
)
