"""Architecture registry: --arch <id> resolves here."""
from repro.configs.base import ModelConfig, ShapeConfig, MLAConfig, MoEConfig, SSMConfig
from repro.configs.shapes import SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K

from repro.configs import (
    granite_20b,
    rwkv6_3b,
    qwen2_vl_72b,
    qwen2_5_3b,
    zamba2_7b,
    hubert_xlarge,
    h2o_danube_3_4b,
    gemma2_9b,
    deepseek_v2_lite_16b,
    llama4_scout_17b,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        granite_20b, rwkv6_3b, qwen2_vl_72b, qwen2_5_3b, zamba2_7b,
        hubert_xlarge, h2o_danube_3_4b, gemma2_9b, deepseek_v2_lite_16b,
        llama4_scout_17b,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def applicable(arch: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether (arch, shape) runs, per DESIGN.md §5 skip rules."""
    if shape.mode == "decode":
        if not arch.supports_decode():
            return False
        if shape.seq_len > 100_000 and not arch.supports_long_context():
            return False
    return True
