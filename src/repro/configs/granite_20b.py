"""granite-20b — dense llama-arch code model [arXiv:2405.04324].

52L d_model=6144 48H (GQA kv=1, i.e. MQA) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    rope_theta=10000.0,
    act="gelu",   # gpt_bigcode-style MLP per the granite-20b-code card
    tie_embeddings=False,
    swap_precision="int4",  # big dense feed-forward stacks tolerate 4-bit
                            # per-channel weights (GPTQ-regime); halves the
                            # swap bytes of the quantized store again
    source="IBM Granite Code Models [arXiv:2405.04324]",
)
