"""hubert-xlarge — encoder-only audio model [arXiv:2106.07447].

48L d_model=1280 16H d_ff=5120 vocab=504 (k-means cluster targets).
Conv feature extractor (mel frontend) is a STUB per the brief: input_specs()
provides frame embeddings (B, S, d_frontend); we build the encoder backbone
and the masked-prediction head. Encoder-only: no decode shapes.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    rope_type="none",      # hubert uses conv positional embedding (folded into stub)
    is_encoder=True,
    embed_inputs=False,
    d_frontend=512,        # conv extractor output dim
    act="gelu",
    tie_embeddings=False,
    # masked-prediction targets are nearest-neighbour cluster ids: logit
    # margins are tight, so this model opts out of int8 swap units
    quant_eligible=False,
    source="HuBERT [arXiv:2106.07447]",
)
