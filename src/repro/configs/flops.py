"""Config-derived analytic FLOPs (no jax import side effects — usable from
both the dry-run launcher and the roofline bench)."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig


def analytic_flops_per_device(cfg: ModelConfig, shape: ShapeConfig,
                              n_devices: int) -> float:
    """Exact model math per device. Needed because XLA cost analysis counts a
    while-loop (scan) body once: train graphs keep the layer scan rolled, so
    their HLO FLOPs are ~n_layers too small; inference lowerings are unrolled
    and use HLO numbers directly."""
    B = shape.global_batch
    S = shape.seq_len
    tokens = B * (1 if shape.mode == "decode" else S)
    V, D = cfg.vocab_size, cfg.d_model
    embed = V * D * (1 if cfg.tie_embeddings else 2)
    mm = cfg.n_active_params() - embed          # matmul-ish params
    head = D * V                                # logits matmul
    fwd = 2.0 * (mm * tokens + head * tokens)
    n_attn = sum(1 for k in cfg.layer_kinds()
                 if k in ("dense", "moe", "shared_attn"))
    hd = cfg.resolved_head_dim
    skv = S if cfg.sliding_window is None else min(S, cfg.sliding_window)
    if shape.mode == "decode":
        fwd += 4.0 * B * skv * cfg.n_heads * hd * n_attn
    else:
        fwd += 4.0 * B * S * skv * cfg.n_heads * hd * n_attn / 2  # causal
    if shape.mode == "train":
        # fwd + bwd (2x fwd) + one remat recompute of fwd
        return 4.0 * fwd / n_devices
    return fwd / n_devices
