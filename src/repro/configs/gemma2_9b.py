"""gemma2-9b — alternating local/global attention + logit softcaps [arXiv:2408.00118].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000, head_dim=256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    sliding_window=4096,
    layer_pattern="alt_local_global",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_pre_attn_scalar=224.0,   # d_model / n_heads, per the gemma2 report
    post_norms=True,
    act="gelu_glu",                # gemma's GeGLU
    tie_embeddings=True,
    source="Gemma 2 [arXiv:2408.00118]",
)
