"""deepseek-v2-lite-16b — MoE with Multi-head Latent Attention [arXiv:2405.04434].

27L d_model=2048 16H d_ff=1408(per expert) vocab=102400.
MLA kv_lora_rank=512; 2 shared + 64 routed experts, top-6.
(The assignment line lists "64e top-6" with a "160 routed" note; we follow the
64-routed figure, which matches the published V2-Lite card.)
"""
from repro.configs.base import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=64, top_k=6, n_shared=2,
                  d_expert=1408, d_shared=2816),
    act="swiglu",
    tie_embeddings=False,
    source="DeepSeek-V2(-Lite) [arXiv:2405.04434]",
)
