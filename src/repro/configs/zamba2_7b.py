"""zamba2-7b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
One SHARED transformer block (weights reused at every occurrence) every 6
positions — zamba2's hallmark; the rest are Mamba2 blocks.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, expand=2, chunk=128),
    hybrid_attn_every=6,
    act="swiglu",
    tie_embeddings=True,
    source="Zamba2 [arXiv:2411.15242]",
)
