"""llama4-scout-17b-a16e — MoE (16 routed top-1 + 1 shared), early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192(per expert) vocab=202048.
Vision (early-fusion) frontend is a STUB: input_specs() provides patch
embeddings; we build the MoE LM backbone. iRoPE-style attention: 3 of every
4 layers attend block-locally (8192-token chunks), every 4th is global —
this is what makes long_500k decode sub-quadratic per layer. (Deviation:
global layers keep RoPE rather than NoPE.)
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    rope_theta=500_000.0,
    attn_chunk=8192,
    layer_pattern="chunked",
    moe=MoEConfig(n_routed=16, top_k=1, n_shared=1,
                  d_expert=8192, d_shared=8192),
    n_vision_tokens=1024,
    d_frontend=1408,
    act="swiglu",
    tie_embeddings=False,
    source="Llama 4 Scout [hf:meta-llama/Llama-4-Scout-17B-16E]",
)
