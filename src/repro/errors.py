"""Failure taxonomy of the swap pipeline (store -> loader -> scheduler).

SwapNet re-reads weight blocks from storage on EVERY inference pass, so a
slow, torn, or corrupted read on a worn flash card / network filesystem
lands directly in the serving critical path. This module names the failure
classes every tier agrees on; ``docs/ARCHITECTURE.md`` ("Failure handling")
has the degradation matrix saying which layer absorbs which class.

  * :class:`SwapIOError`         — the storage channel failed outright
    (``EIO``, missing file, short read the backend could not assemble).
    Subclasses :class:`IOError` so pre-taxonomy ``except IOError`` callers
    keep working.
  * :class:`SwapCorruptionError` — the bytes arrived but the per-unit CRC32
    recorded at store-build time does not match (bit rot, a torn write, an
    injected flip). NEVER retried silently into wrong weights: the loader
    re-reads, and only a clean read is handed to the executor.
  * :class:`SwapTimeoutError`    — a read (or a whole unit swap-in) blew its
    deadline; the data, even if it eventually arrived, is treated as failed
    so tail latency stays bounded. Subclasses :class:`TimeoutError`.

All three are retryable at the loader tier (bounded exponential backoff,
``SwapEngine.read_retries``); what escapes the retries carries ``unit`` /
``attempts`` context and surfaces at the next block boundary, where the
serving tier decides between retry-at-request-granularity and fail-fast
(per-model circuit breaker in ``ServingScheduler``).

:class:`RequestCancelled` is the scheduler-tier terminal state for requests
removed via ``ServingScheduler.cancel`` — deliberately NOT a
:class:`SwapError`: cancellation is a caller decision, not a fault, and
must not trip the per-model circuit breaker.
"""
from __future__ import annotations

from typing import Optional

__all__ = ["SwapError", "SwapIOError", "SwapCorruptionError",
           "SwapTimeoutError", "RequestCancelled", "ConfigError"]


class ConfigError(ValueError):
    """A layered serving configuration (``repro.config``) failed to resolve:
    unknown key, uncoercible value, missing profile, or a cross-field
    invariant violation. Raised at STARTUP (or at the control-plane request
    that carried the bad overlay) — never from the serving hot path."""


class SwapError(Exception):
    """Base of the swap-pipeline failure taxonomy.

    ``unit`` is the swap-unit name the failure is attributable to (None for
    model-level failures), ``model`` the owning model where known, and
    ``attempts`` how many read attempts were burned before the error
    escaped the loader's retry loop (0 = never retried).
    """

    def __init__(self, msg: str, *, unit: Optional[str] = None,
                 model: Optional[str] = None, attempts: int = 0):
        super().__init__(msg)
        self.unit = unit
        self.model = model
        self.attempts = attempts


class SwapIOError(SwapError, IOError):
    """The storage channel failed: raised I/O error, missing file, or a
    short/torn read the backend could not assemble into a unit."""


class SwapCorruptionError(SwapError):
    """Unit bytes failed their build-time CRC32 integrity check — the read
    'succeeded' but the payload cannot be trusted."""


class SwapTimeoutError(SwapError, TimeoutError):
    """A read exceeded its per-read deadline (``SwapEngine.read_deadline_s``)
    or a request was shed at its deadline instead of being left to hang."""


class RequestCancelled(Exception):
    """The caller removed a queued request via ``ServingScheduler.cancel``
    (e.g. after its own ``wait(timeout)`` expired) — a decision, not a
    fault, so it never counts against a model's failure breaker."""
