"""Synthetic data pipeline: deterministic, host-shardable, prefetching.

The LM stream mixes a learnable affine next-token pattern with noise so
training loss visibly decreases below the unigram entropy floor (used by the
end-to-end example and integration tests). Audio/VLM variants produce the
frontend-stub tensors described in DESIGN.md §4.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class SyntheticLM:
    cfg: ModelConfig
    seq_len: int
    batch: int
    seed: int = 0
    pattern_frac: float = 0.85   # fraction of learnable transitions

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, step))

    def sample(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        B, S, V = self.batch, self.seq_len, cfg.vocab_size
        # learnable stream: an affine next-token rule over a SMALL active
        # symbol set (a full-vocab permutation would need V memorized
        # transitions — unlearnable in a few hundred steps)
        A = min(V, 256)
        a, c = 31, 17                      # affine rule (mod A), gcd(a, A)=1
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, A, B)
        noise = rng.random((B, S)) > self.pattern_frac
        rand = rng.integers(0, A, (B, S))
        for t in range(S):
            nxt = (toks[:, t] * a + c) % A
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                 "targets": jnp.asarray(toks[:, 1:], jnp.int32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jnp.asarray(
                rng.normal(0, 0.5, (B, cfg.n_vision_tokens, cfg.d_frontend)),
                jnp.dtype(cfg.dtype))
            pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3))
            batch["positions"] = jnp.asarray(pos, jnp.int32)
        if not cfg.embed_inputs:           # audio: features + mask
            feats = rng.normal(0, 0.5, (B, S, cfg.d_frontend))
            batch = {"features": jnp.asarray(feats, jnp.dtype(cfg.dtype)),
                     "mask": jnp.asarray(rng.random((B, S)) < 0.3),
                     "targets": jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)}
        return batch

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.sample(step)
            step += 1

    def prefetch(self, depth: int = 2) -> Iterator[Dict[str, jnp.ndarray]]:
        """Background-thread prefetch (the data-pipeline analogue of the
        paper's double-buffered swap-in)."""
        q: "queue.Queue" = queue.Queue(maxsize=depth)

        def worker():
            for i, b in enumerate(self):
                q.put(b)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            yield q.get()


def make_batch_for(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    """One batch matching input_specs(cfg, shape) — used by benches/examples."""
    ds = SyntheticLM(cfg, shape.seq_len, shape.global_batch, seed)
    if shape.mode == "train":
        return ds.sample(0)
    b = ds.sample(0)
    if shape.mode == "prefill":
        b.pop("targets", None)
        b.pop("mask", None)
        return b
    out = {"token": b.get("tokens", jnp.zeros((shape.global_batch, 1), jnp.int32))[:, :1],
           "pos": jnp.zeros((shape.global_batch,), jnp.int32)}
    if cfg.rope_type == "mrope":
        out["positions"] = jnp.zeros((shape.global_batch, 1, 3), jnp.int32)
    return out
