"""Version shims for jax APIs that moved between releases.

The seed targets the `jax.tree.*` convenience namespace, but
`jax.tree.flatten_with_path` / `jax.tree.unflatten` only exist on newer
jax releases; older ones (e.g. 0.4.37) expose the same functionality under
`jax.tree_util`. Import from here instead of feature-testing at call sites.
"""
from __future__ import annotations

import jax
import jax.tree_util as _tu

if hasattr(jax.tree, "flatten_with_path"):
    tree_flatten_with_path = jax.tree.flatten_with_path
else:
    tree_flatten_with_path = _tu.tree_flatten_with_path

if hasattr(jax.tree, "unflatten"):
    tree_unflatten = jax.tree.unflatten
else:
    tree_unflatten = _tu.tree_unflatten

if hasattr(jax.tree, "structure"):
    tree_structure = jax.tree.structure
else:
    tree_structure = _tu.tree_structure

keystr = _tu.keystr


def make_mesh(axis_shapes, axis_names):
    """jax.make_mesh with Auto axis types where the release supports them
    (jax.sharding.AxisType landed after 0.4.37; older releases only build
    Auto meshes, so omitting the argument is equivalent)."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         axis_types=(AxisType.Auto,) * len(tuple(axis_names)))
