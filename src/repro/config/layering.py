"""Layered config resolution: defaults -> profile -> env -> CLI.

The merge semantics follow the layered-config pattern (SNIPPETS.md
Snippet 3, comfyui-remote's ``config/layering.py``):

  * **dicts recurse** — a profile that sets ``runtime.budget_mb`` does not
    clobber the default ``runtime.store`` next to it;
  * **scalars AND lists are last-wins** — a layer that sets
    ``workload.priorities`` REPLACES the list wholesale (element-wise
    merging of positional lists produces franken-configs nobody wrote).

The env layer reads ``SWAPNET_<SECTION>_<KEY>`` variables
(``SWAPNET_RUNTIME_BUDGET_MB=24``, ``SWAPNET_HTTP_PORT=9000``; top-level
keys drop the section: ``SWAPNET_ARCH``, ``SWAPNET_MODELS=a,b``,
``SWAPNET_REDUCE``). Values are coerced onto the declared field types —
``"2"`` becomes the int 2 for ``runtime.executors``, ``"1,8"`` becomes
``[1.0, 8.0]`` for ``workload.priorities`` — and an unknown ``SWAPNET_*``
variable is an error with a did-you-mean hint, not a silent no-op
(a typo'd env override that falls back to the default is invisible
exactly when you depend on it).

``resolve_config`` is the one entry point; ``explain_layers`` returns the
per-layer overlays for debugging (``repro.launch.serve --print-config``).
"""
from __future__ import annotations

import copy
import difflib
import os
from typing import Dict, List, Mapping, Optional, Tuple

from repro.config.profiles import profile_overlay
from repro.config.schema import ServeConfig, config_fields
from repro.errors import ConfigError

__all__ = ["deep_merge", "env_overlay", "resolve_config", "explain_layers",
           "ENV_PREFIX"]

ENV_PREFIX = "SWAPNET_"


def deep_merge(base: Dict, overlay: Mapping) -> Dict:
    """Merge ``overlay`` onto ``base`` (returns a new dict; inputs are not
    mutated): dicts recurse, scalars and lists last-wins."""
    out = copy.deepcopy(dict(base))
    for key, value in overlay.items():
        if (key in out and isinstance(out[key], dict)
                and isinstance(value, Mapping)):
            out[key] = deep_merge(out[key], value)
        else:
            out[key] = copy.deepcopy(value)
    return out


def _env_key_map() -> Dict[str, Tuple[str, ...]]:
    """``SWAPNET_RUNTIME_BUDGET_MB`` -> ('runtime', 'budget_mb') for every
    field in the schema (top-level fields drop the section)."""
    mapping: Dict[str, Tuple[str, ...]] = {}
    for path in config_fields():
        parts = tuple(path.split("."))
        mapping[ENV_PREFIX + "_".join(p.upper() for p in parts)] = parts
    return mapping


def env_overlay(env: Optional[Mapping[str, str]] = None) -> Dict:
    """The env layer as a nested overlay dict. ``env=None`` reads
    ``os.environ``; pass ``{}`` for hermetic resolution (tests)."""
    env = os.environ if env is None else env
    mapping = _env_key_map()
    # SWAPNET_PROFILE selects the profile layer (handled by resolve_config)
    # and SWAPNET_ vars owned by other subsystems are not config keys
    ignored = {ENV_PREFIX + "PROFILE"}
    overlay: Dict = {}
    for name, raw in env.items():
        if not name.startswith(ENV_PREFIX) or name in ignored:
            continue
        if name not in mapping:
            close = difflib.get_close_matches(name, mapping, n=2, cutoff=0.5)
            hint = (f" — did you mean {' or '.join(close)}?" if close
                    else f" (known: {sorted(mapping)})")
            raise ConfigError(f"unknown config env var {name}{hint}")
        node = overlay
        *parents, leaf = mapping[name]
        for p in parents:
            node = node.setdefault(p, {})
        node[leaf] = raw          # coerced by ServeConfig.from_dict
    return overlay


def explain_layers(profile: Optional[str] = None,
                   env: Optional[Mapping[str, str]] = None,
                   cli: Optional[Mapping] = None) -> List[Tuple[str, Dict]]:
    """The ordered ``(layer_name, overlay_dict)`` stack resolve_config
    merges, for debugging/printing. Defaults layer is the full dict."""
    env_map = os.environ if env is None else env
    profile = profile or env_map.get(ENV_PREFIX + "PROFILE") or None
    layers: List[Tuple[str, Dict]] = [
        ("defaults", ServeConfig().to_dict()),
    ]
    if profile:
        layers.append((f"profile:{profile}",
                       deep_merge({"profile": profile},
                                  profile_overlay(profile))))
    layers.append(("env", env_overlay(env)))
    if cli:
        layers.append(("cli", dict(cli)))
    return layers


def resolve_config(profile: Optional[str] = None,
                   env: Optional[Mapping[str, str]] = None,
                   cli: Optional[Mapping] = None) -> ServeConfig:
    """Resolve the full layered configuration into a validated
    :class:`ServeConfig`.

    ``profile`` — device-class profile name (CLI ``--profile``; falls back
    to ``$SWAPNET_PROFILE``); ``env`` — environment mapping (None = the
    real ``os.environ``; pass ``{}`` to resolve hermetically); ``cli`` —
    the nested overlay built from explicitly-passed CLI flags (the
    highest-precedence layer).
    """
    merged: Dict = {}
    for _name, overlay in explain_layers(profile, env, cli):
        merged = deep_merge(merged, overlay)
    return ServeConfig.from_dict(merged).validate()
