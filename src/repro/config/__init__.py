"""Layered serving configuration (defaults -> profile -> env -> CLI).

``repro.config`` owns HOW a serving process is assembled; ``repro.configs``
(plural) owns the model architecture registry. The split is deliberate:
an arch config describes a network, a ServeConfig describes a deployment.

    from repro.config import resolve_config
    cfg = resolve_config(profile="edge-tpu")         # + env + CLI overlays
    rt = MultiModelRuntime.from_config(cfg)
"""
from repro.config.layering import (ENV_PREFIX, deep_merge, env_overlay,
                                   explain_layers, resolve_config)
from repro.config.profiles import PROFILES, profile_names, profile_overlay
from repro.config.schema import (PRECISIONS, REDUCE_PRESETS, SERVE_STORES,
                                 ConfigError, HttpConfig, RuntimeConfig,
                                 SchedulerConfig, ServeConfig,
                                 WorkloadConfig, config_fields)

__all__ = [
    "ServeConfig", "WorkloadConfig", "RuntimeConfig", "SchedulerConfig",
    "HttpConfig", "ConfigError", "resolve_config", "explain_layers",
    "deep_merge", "env_overlay", "config_fields", "PROFILES",
    "profile_names", "profile_overlay", "ENV_PREFIX", "REDUCE_PRESETS",
    "SERVE_STORES", "PRECISIONS",
]
