"""Device-class deployment profiles, shipped as DATA.

A profile is a partial overlay onto the config defaults (see
``layering.resolve_config``: defaults -> profile -> env -> CLI), capturing
the memory/storage envelope of a device class — budget, store backend, swap
precision, executor count, cache/KV fractions — plus a reference workload
so ``python -m repro.launch.serve --profile <name>`` runs end-to-end with
zero other flags. Everything here is overridable by the env
(``SWAPNET_*``) and CLI layers above it.

All three profiles default to ``reduce="smoke"`` models so they run on any
dev machine; on a real deployment pass ``--reduce full`` (or
``SWAPNET_REDUCE=full``) on top — the profile describes the DEVICE, the
reduce preset describes the model scale.
"""
from __future__ import annotations

from typing import Dict

from repro.errors import ConfigError

__all__ = ["PROFILES", "profile_overlay", "profile_names"]

# name -> {"description": one-liner for --help/docs, "overlay": config dict}
PROFILES: Dict[str, dict] = {
    # Microcontroller-scale (the arxiv 2101.08744 extreme): single tenant,
    # single executor, a budget far below the model, every byte fought for —
    # a calibrated MIXED-precision store (per-unit int4/int8/fp from the
    # sensitivity pass, repro/calibrate/) streams through the fused
    # dequant-matmul, a serial (m=1) pipeline (no RAM for a second in-flight
    # block), and a minimal hot cache.
    "mcu": {
        "description": "MCU-scale: one tenant, 8 MB budget, calibrated "
                       "mixed-precision quantized store, serial (m=1) "
                       "pipeline",
        "overlay": {
            "arch": "qwen2.5-3b",
            "workload": {"requests": 2, "prompt_len": 16, "rounds": 2},
            "runtime": {
                "budget_mb": 8.0,
                "store": "quant",
                "precision": "mixed",
                "fidelity": 2e-2,
                "prefetch_depth": 1,
                "cache_frac": 0.1,
                "executors": 1,
            },
        },
    },
    # Edge-TPU-class accelerator board: two co-resident tenants under one
    # shared budget, two executors with priority classes + preemption — the
    # paper's §6 multi-DNN scenario as a deployable default.
    "edge-tpu": {
        "description": "edge accelerator: two tenants, 24 MB shared budget, "
                       "2 executors, priority classes 1/8 with preemption",
        "overlay": {
            "models": ["qwen2.5-3b", "gemma2-9b"],
            "workload": {"requests": 2, "prompt_len": 32, "rounds": 2,
                         "priorities": [1.0, 8.0]},
            "runtime": {
                "budget_mb": 24.0,
                "store": "mmap",
                "executors": 2,
                "cache_frac": 0.25,
                "prefetch_depth": 2,
            },
            "scheduler": {"preempt": True},
        },
    },
    # Workstation / edge server: roomy budget, O_DIRECT storage so swap
    # traffic stops thrashing the page cache, paged-KV continuous-batching
    # decode enabled alongside prefill tenants.
    "workstation": {
        "description": "workstation: two tenants, 64 MB budget, O_DIRECT "
                       "store, paged-KV continuous-batching decode enabled",
        "overlay": {
            "models": ["qwen2.5-3b", "gemma2-9b"],
            "workload": {"requests": 4, "prompt_len": 32, "rounds": 2,
                         "priorities": [1.0, 8.0]},
            "runtime": {
                "budget_mb": 64.0,
                "store": "directio",
                "executors": 2,
                "cache_frac": 0.2,
                "prefetch_depth": 3,
                "paged": True,
                "kv_frac": 0.2,
                "page_tokens": 16,
                "max_batch": 8,
            },
            "scheduler": {"preempt": True},
        },
    },
}


def profile_names() -> list:
    return sorted(PROFILES)


def profile_overlay(name: str) -> dict:
    """The named profile's config overlay; unknown name -> ConfigError."""
    if name not in PROFILES:
        import difflib
        close = difflib.get_close_matches(name, PROFILES, n=2, cutoff=0.4)
        hint = (f" — did you mean {' or '.join(repr(c) for c in close)}?"
                if close else "")
        raise ConfigError(f"unknown profile {name!r} "
                          f"(known: {profile_names()}){hint}")
    return PROFILES[name]["overlay"]
