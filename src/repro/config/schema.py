"""Typed serving configuration: the validated object every layer merges into.

``ServeConfig`` is the single source of truth for how a serving process is
assembled — what was ~15 interacting CLI flags on ``repro.launch.serve``
(``--multi/--store/--precision/--executors/--priorities/--rebalance/
--paged/--kv-frac/...``) is now one dataclass tree with four sections:

  * top level   — what to serve (``arch`` / ``models``, ``reduce``);
  * ``workload``  — the reference request mix (requests, prompt/new tokens,
    rounds, priority classes);
  * ``runtime``   — the memory/storage envelope
    (:class:`~repro.core.multi_model.MultiModelRuntime` construction:
    budget, store backend, precision, executors, prefetch depth,
    cache/KV fractions, paging);
  * ``scheduler`` — :class:`~repro.core.serving_scheduler.ServingScheduler`
    policy (preemption, rebalance, slack, degradation knobs);
  * ``http``      — the control plane (serving/control_plane.py).

Construction goes through :func:`ServeConfig.from_dict`, which REJECTS
unknown keys with a did-you-mean hint instead of silently ignoring a typo'd
``budjet_mb`` (a mis-spelled override that falls back to a default is the
worst failure mode a layered config can have), and coerces string values
(env vars arrive as strings) onto the declared field types.
``validate()`` then checks cross-field invariants the type system can't.
"""
from __future__ import annotations

import dataclasses
import difflib
import typing
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.errors import ConfigError

__all__ = ["ServeConfig", "WorkloadConfig", "RuntimeConfig",
           "SchedulerConfig", "HttpConfig", "ConfigError",
           "REDUCE_PRESETS", "SERVE_STORES", "PRECISIONS"]

REDUCE_PRESETS = ("smoke", "100m", "full")
# the servable subset of repro.store.STORE_BACKENDS: `faulty` is a test
# wrapper (it needs an inner backend + fault schedule), not a deployment tier
SERVE_STORES = ("mmap", "rawio", "quant", "directio")
# `mixed` = per-unit precision from a calibration pass (repro/calibrate/):
# requires the quant store plus a runtime.fidelity target
PRECISIONS = (None, "int8", "int4", "mixed")


@dataclass
class WorkloadConfig:
    """The reference request mix a profile run (or warmup) drives."""
    requests: int = 8          # prompts per submitted batch
    prompt_len: int = 32
    new_tokens: int = 16       # generation length (decode paths)
    max_len: int = 128         # decode cache capacity (plain engine)
    rounds: int = 3            # round-robin passes over the tenant set
    priorities: List[float] = field(default_factory=lambda: [1.0])


@dataclass
class RuntimeConfig:
    """Memory/storage envelope: MultiModelRuntime construction knobs."""
    budget_mb: Optional[float] = None   # None = unswapped (no budget)
    prefetch_depth: int = 2
    cache_frac: float = 0.25
    executors: int = 1
    store: str = "mmap"
    precision: Optional[str] = None     # None = the arch's swap_precision
    fidelity: Optional[float] = None    # max rel-L2 output error (mixed)
    paged: bool = False
    kv_frac: float = 0.3
    page_tokens: int = 16
    max_batch: int = 8


@dataclass
class SchedulerConfig:
    """ServingScheduler policy knobs."""
    preempt: bool = True
    rebalance: bool = False
    default_slack: float = 1.0
    fail_fast_after: int = 3
    shed_deadlines: bool = False


@dataclass
class HttpConfig:
    """Control-plane endpoint (serving/control_plane.py)."""
    enabled: bool = False
    host: str = "127.0.0.1"
    port: int = 8799            # 0 = ephemeral (the bound port is printed)


@dataclass
class ServeConfig:
    """The resolved, validated serving configuration (all layers merged)."""
    profile: Optional[str] = None       # which profile resolved this, if any
    arch: Optional[str] = None          # single-model serving
    models: List[str] = field(default_factory=list)   # multi-tenant set
    reduce: str = "smoke"
    workload: WorkloadConfig = field(default_factory=WorkloadConfig)
    runtime: RuntimeConfig = field(default_factory=RuntimeConfig)
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    http: HttpConfig = field(default_factory=HttpConfig)

    # ------------------------------------------------------------ dict I/O
    @classmethod
    def from_dict(cls, data: Dict) -> "ServeConfig":
        """Build (and coerce) from a plain nested dict, rejecting unknown
        keys at every level with a did-you-mean hint."""
        return _build_dataclass(cls, data, path="")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def model_names(self) -> List[str]:
        """The tenant set: ``models`` if given, else the single ``arch``."""
        if self.models:
            return list(self.models)
        return [self.arch] if self.arch else []

    # ---------------------------------------------------------- validation
    def validate(self) -> "ServeConfig":
        """Cross-field invariants; returns self so calls chain."""
        if self.reduce not in REDUCE_PRESETS:
            raise ConfigError(f"reduce={self.reduce!r} is not one of "
                              f"{list(REDUCE_PRESETS)}")
        rt = self.runtime
        if rt.store not in SERVE_STORES:
            raise ConfigError(f"runtime.store={rt.store!r} is not one of "
                              f"{list(SERVE_STORES)}")
        if rt.precision not in PRECISIONS:
            raise ConfigError(f"runtime.precision={rt.precision!r} is not "
                              f"one of {[p for p in PRECISIONS if p]} (or "
                              f"unset)")
        if rt.fidelity is not None and rt.fidelity <= 0:
            raise ConfigError(f"runtime.fidelity={rt.fidelity} must be > 0")
        if rt.precision == "mixed":
            if rt.store != "quant":
                raise ConfigError("runtime.precision='mixed' requires "
                                  "runtime.store='quant' (the plan "
                                  "parameterizes the quantized store)")
            if rt.fidelity is None:
                raise ConfigError("runtime.precision='mixed' requires a "
                                  "runtime.fidelity target (max rel-L2 "
                                  "output error, e.g. 1e-2)")
        if rt.executors < 1:
            raise ConfigError(f"runtime.executors={rt.executors} must be >= 1")
        if rt.prefetch_depth < 1:
            raise ConfigError(f"runtime.prefetch_depth={rt.prefetch_depth} "
                              f"must be >= 1")
        if not 0.0 <= rt.cache_frac < 1.0:
            raise ConfigError(f"runtime.cache_frac={rt.cache_frac} must be "
                              f"in [0, 1)")
        if not 0.0 <= rt.kv_frac < 1.0:
            raise ConfigError(f"runtime.kv_frac={rt.kv_frac} must be in [0, 1)")
        if rt.paged and rt.cache_frac + rt.kv_frac >= 1.0:
            raise ConfigError(
                f"runtime.cache_frac + runtime.kv_frac = "
                f"{rt.cache_frac + rt.kv_frac:g} leaves no block budget")
        if rt.budget_mb is not None and rt.budget_mb <= 0:
            raise ConfigError(f"runtime.budget_mb={rt.budget_mb} must be > 0")
        if self.scheduler.fail_fast_after < 1:
            raise ConfigError(
                f"scheduler.fail_fast_after={self.scheduler.fail_fast_after} "
                f"must be >= 1")
        if self.workload.requests < 1 or self.workload.prompt_len < 1:
            raise ConfigError("workload.requests and workload.prompt_len "
                              "must be >= 1")
        if not self.workload.priorities:
            raise ConfigError("workload.priorities must not be empty")
        if self.arch and self.models:
            raise ConfigError("set either arch (single model) or models "
                              "(multi-tenant), not both")
        names = self.model_names()
        if names:
            from repro.configs import ARCHS      # lazy: keep import light
            for name in names:
                if name not in ARCHS:
                    hint = _did_you_mean(name, ARCHS)
                    raise ConfigError(f"unknown arch {name!r}{hint}")
        return self


# --------------------------------------------------------------- internals
def _did_you_mean(key: str, known) -> str:
    close = difflib.get_close_matches(key, list(known), n=2, cutoff=0.5)
    return f" — did you mean {' or '.join(repr(c) for c in close)}?" \
        if close else f" (known: {sorted(known)})"


def _hints(cls) -> Dict[str, type]:
    """Resolved field types (``from __future__ import annotations`` makes
    ``dataclasses.fields(...)[i].type`` a STRING; resolve to real types)."""
    return typing.get_type_hints(cls)


def config_fields(cls=ServeConfig, prefix: str = "") -> Dict[str, type]:
    """Flat ``section.key -> declared type`` map over the dataclass tree —
    the schema surface the env-var layer and the docs-drift checker walk."""
    out: Dict[str, type] = {}
    hints = _hints(cls)
    for f in dataclasses.fields(cls):
        t = hints[f.name]
        if dataclasses.is_dataclass(t):
            out.update(config_fields(t, prefix=f"{prefix}{f.name}."))
        else:
            out[f"{prefix}{f.name}"] = t
    return out


def coerce_value(value, target_type, path: str):
    """Coerce ``value`` (possibly a string from an env var) onto the
    declared field type. Raises ConfigError on a value that cannot be
    represented, instead of letting a stringly-typed '8' poison an int
    comparison three layers down."""
    origin = typing.get_origin(target_type)
    if origin is typing.Union:                  # Optional[x]
        args = [a for a in typing.get_args(target_type) if a is not type(None)]
        if value is None or (isinstance(value, str)
                             and value.lower() in ("", "none", "null")):
            return None
        return coerce_value(value, args[0], path)
    if origin in (list, List):
        (elem,) = typing.get_args(target_type) or (str,)
        if isinstance(value, str):              # "1,8" -> [1.0, 8.0]
            value = [v.strip() for v in value.split(",") if v.strip()]
        if not isinstance(value, (list, tuple)):
            raise ConfigError(f"{path}: expected a list, got {value!r}")
        return [coerce_value(v, elem, f"{path}[]") for v in value]
    if target_type is bool:
        if isinstance(value, bool):
            return value
        if isinstance(value, str):
            low = value.strip().lower()
            if low in ("1", "true", "yes", "on"):
                return True
            if low in ("0", "false", "no", "off"):
                return False
        raise ConfigError(f"{path}: expected a bool, got {value!r}")
    if target_type is int:
        if isinstance(value, bool) or not isinstance(value, (int, str)):
            raise ConfigError(f"{path}: expected an int, got {value!r}")
        try:
            return int(value)
        except ValueError:
            raise ConfigError(f"{path}: expected an int, got {value!r}") \
                from None
    if target_type is float:
        if isinstance(value, bool) or not isinstance(value, (int, float, str)):
            raise ConfigError(f"{path}: expected a float, got {value!r}")
        try:
            return float(value)
        except ValueError:
            raise ConfigError(f"{path}: expected a float, got {value!r}") \
                from None
    if target_type is str:
        if not isinstance(value, str):
            raise ConfigError(f"{path}: expected a string, got {value!r}")
        return value
    return value


def _build_dataclass(cls, data: Dict, path: str):
    if not isinstance(data, dict):
        raise ConfigError(f"{path or cls.__name__}: expected a mapping, "
                          f"got {data!r}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    hints = _hints(cls)
    kwargs = {}
    for key, value in data.items():
        if key not in fields:
            where = f"{path}{key}" if path else key
            raise ConfigError(f"unknown config key {where!r}"
                              f"{_did_you_mean(key, fields)}")
        sub = f"{path}{key}"
        t = hints[key]
        if dataclasses.is_dataclass(t):
            kwargs[key] = _build_dataclass(t, value or {}, f"{sub}.")
        else:
            kwargs[key] = coerce_value(value, t, sub)
    return cls(**kwargs)
