from repro.training.optimizer import OptConfig, adamw_init, adamw_update, lr_at
from repro.training.train_loop import TrainState, make_train_step, train_state_specs
