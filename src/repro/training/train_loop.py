"""Training step + state (used by launch/train.py and the dry-run)."""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.transformer import Model
from repro.training.optimizer import OptConfig, adamw_init, adamw_update


def TrainState(params) -> dict:
    mu, nu = adamw_init(params)
    return {"params": params, "mu": mu, "nu": nu,
            "step": jnp.zeros((), jnp.int32)}


def train_state_specs(model: Model) -> dict:
    ps = model.param_specs()
    return {"params": ps, "mu": ps, "nu": ps, "step": P()}


def make_train_step(model: Model, opt: OptConfig) -> Callable:
    def train_step(state: dict, batch: dict) -> Tuple[dict, dict]:
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state["params"], batch)
        p, mu, nu, om = adamw_update(state["params"], grads, state["mu"],
                                     state["nu"], state["step"], opt)
        new_state = {"params": p, "mu": mu, "nu": nu,
                     "step": state["step"] + 1}
        return new_state, {**metrics, **om}
    return train_step
