"""AdamW + cosine schedule, pure JAX (no optax dependency)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(step: jax.Array, cfg: OptConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> Tuple[dict, dict]:
    zeros = lambda t: jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), t)
    return zeros(params), zeros(params)


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adamw_update(params, grads, mu, nu, step: jax.Array, cfg: OptConfig):
    """Returns (params, mu, nu, metrics). Decoupled weight decay; global-norm
    clipping; bias-corrected moments kept in fp32."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(step, cfg)
    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (u + decay)
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, mu, nu)
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    p_new = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    mu_new = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    nu_new = jax.tree.unflatten(treedef, [l[2] for l in leaves])
    return p_new, mu_new, nu_new, {"grad_norm": gnorm, "lr": lr}
