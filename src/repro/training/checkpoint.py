"""Checkpointing on the SwapNet flat store: the checkpoint IS a flat block
buffer + skeleton meta, so restore-by-reference (mmap) needs no per-tensor
deserialization — the paper's Fil{pars}/Obj{sket} split reused verbatim."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

from repro.core.skeleton import Ref, Skeleton, assemble_np, flatten_params


def save(path: str, tree: Any) -> None:
    os.makedirs(path, exist_ok=True)
    buf, skel = flatten_params(tree)
    with open(os.path.join(path, "params.bin"), "wb") as fh:
        fh.write(buf.tobytes())
    meta = {"refs": [[r.offset, list(r.shape), r.dtype] for r in skel.refs],
            "nbytes": skel.nbytes}
    with open(os.path.join(path, "meta.json"), "w") as fh:
        json.dump(meta, fh)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape-validated), reading the
    flat buffer through a memmap (zero staging copies)."""
    with open(os.path.join(path, "meta.json")) as fh:
        meta = json.load(fh)
    refs = [Ref(o, tuple(s), d) for o, s, d in meta["refs"]]
    leaves_like, treedef = jax.tree.flatten(like)
    assert len(refs) == len(leaves_like), \
        f"checkpoint has {len(refs)} tensors, tree expects {len(leaves_like)}"
    for r, l in zip(refs, leaves_like):
        assert tuple(r.shape) == tuple(l.shape), (r.shape, l.shape)
    buf = np.memmap(os.path.join(path, "params.bin"), dtype=np.uint8, mode="r")
    skel = Skeleton(treedef, refs, meta["nbytes"])
    host = assemble_np(skel, buf)
    return jax.tree.map(jax.numpy.asarray, host)
