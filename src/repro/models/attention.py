"""Attention: GQA/MHA with chunked online-softmax (flash-style in XLA),
causal / sliding-window / softcap / encoder variants, and MLA (DeepSeek-V2)
with an absorbed decode path.

The chunked implementation is the portable oracle for kernels/flash_attention
and the path used under jit on CPU and in the dry-run: KV is scanned in blocks
with running (m, l, acc) statistics, so the [Sq, Skv] score matrix never
materializes at full sequence length — the KV-block swap-through-a-window
structure mirrors the paper's block swapping one level down (see DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamDef
from repro.models.layers import apply_rope, linear, rope_angles, softcap

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
LARGE_WINDOW = 1 << 30

# §Perf (beyond-paper): explicit flash-decoding over the sequence-sharded KV
# cache. When set to a mesh axis name (and a mesh is installed via
# distributed.sharding.set_mesh), single-token decode updates the cache shard
# LOCALLY and combines per-shard online-softmax statistics with psum instead
# of letting SPMD all-gather the cache every layer. Enabled by the dry-run /
# serving launcher; None keeps the portable jit path (smoke tests).
SHARDED_DECODE_AXIS = None


def _flash_decode_sharded(q, cache_k, cache_v, k_new, v_new, decode_pos,
                          *, axis, batch_axes, scale, window, logit_cap,
                          block_local=None):
    """q [B,1,H,hd]; cache [B,S,KV,hd] sharded on S over ``axis``; k/v_new
    [B,1,KV,hd]. Returns (out [B,1,H,hd], new_cache_k, new_cache_v).

    Inside shard_map each device owns S_loc = S/axis_size cache rows:
      1. write k/v_new into the local shard iff decode_pos lands in it;
      2. compute partial (m, l, acc) over the local rows;
      3. combine with pmax/psum (flash-decoding) — bytes moved per layer are
         O(B*H*hd), not O(B*S*KV*hd).
    """
    try:
        from jax.shard_map import shard_map
    except ImportError:  # jax 0.8: still under experimental
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from jax.experimental.shard_map import shard_map
    from repro.distributed.sharding import get_mesh
    mesh = get_mesh()
    B, _, H, hd = q.shape
    S = cache_k.shape[1]
    KV = cache_k.shape[2]
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    import numpy as _np
    n_shards = int(_np.prod([mesh.shape[a] for a in axes]))
    S_loc = S // n_shards
    bax = tuple(a for a in batch_axes if a in mesh.axis_names)

    def local_fn(qv, ck, cv, kn, vn, pos):
        Bl = qv.shape[0]                     # batch may be data-sharded
        idx = jnp.zeros((), jnp.int32)
        for a in axes:                       # row-major over the seq axes
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        # --- local cache update (no resharding of the DUS) ---
        local = pos - idx * S_loc                       # [B]
        inb = (local >= 0) & (local < S_loc)
        safe = jnp.clip(local, 0, S_loc - 1)

        def upd(c, u, i, ok):
            c2 = jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
            return jnp.where(ok, c2, c)
        ck = jax.vmap(upd)(ck, kn, safe, inb)
        cv = jax.vmap(upd)(cv, vn, safe, inb)

        # --- partial online softmax over the local rows ---
        G = H // KV
        qf = qv.reshape(Bl, KV, G, hd).astype(jnp.float32)
        s = jnp.einsum("bkgh,bskh->bkgs", qf, ck.astype(jnp.float32)) * scale
        s = softcap(s, logit_cap)
        kv_pos = idx * S_loc + jnp.arange(S_loc)
        qp = pos[:, None, None, None]
        kvp = kv_pos[None, None, None, :]
        mask = kvp <= qp
        mask &= (qp - kvp) < window
        if block_local is not None:
            mask &= (qp // block_local) == (kvp // block_local)
        s = jnp.where(mask, s, NEG_INF)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bkgs,bskh->bkgh", p, cv.astype(jnp.float32))
        # --- combine across shards ---
        m_g = jax.lax.pmax(m, axes)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axes)
        acc_g = jax.lax.psum(acc * corr[..., None], axes)
        out = (acc_g / jnp.maximum(l_g[..., None], 1e-30))
        return out.reshape(Bl, 1, H, hd).astype(qv.dtype), ck, cv

    from jax.sharding import PartitionSpec as P
    cache_spec = P(bax if bax else None, axes, None, None)
    rep = P(bax if bax else None, None, None, None)
    pos_spec = P(bax if bax else None)
    out, ck, cv = shard_map(
        local_fn, mesh=mesh,
        in_specs=(rep, cache_spec, cache_spec, rep, rep, pos_spec),
        out_specs=(rep, cache_spec, cache_spec),
        check_rep=False,
    )(q, cache_k, cache_v, k_new, v_new, decode_pos)
    return out, ck, cv


def online_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     q_pos: jax.Array, kv_valid_len: Optional[jax.Array],
                     *, causal: bool, window, scale: float,
                     logit_cap: Optional[float], chunk: int = 1024,
                     block_local=None) -> jax.Array:
    """q: [B,Sq,H,hd], k/v: [B,Skv,KV,hd], q_pos: [B,Sq] absolute positions.

    ``window`` may be a python int/None or a traced scalar (scanned local/global
    flag); masking is positional: kv position j attends iff
        j <= q_pos (causal)  and  q_pos - j < window  and  j < kv_valid_len.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    vd = v.shape[-1]          # v head dim may differ (MLA absorbed decode)
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    window = LARGE_WINDOW if window is None else window

    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, vd).transpose(1, 0, 2, 3, 4)
    kv_pos = jnp.arange(n_chunks * chunk).reshape(n_chunks, chunk)

    qp = q_pos[:, :, None, None, None]                       # [B,Sq,1,1,1]
    if kv_valid_len is not None:
        valid_len = kv_valid_len[:, None, None, None, None]  # [B,1,1,1,1]
    else:
        valid_len = None

    def body(carry, xs):
        m, l, acc = carry
        kci, vci, pci = xs                                   # [B,c,KV,hd], [c]
        s = jnp.einsum("bqkgh,bckh->bqkgc", q, kci.astype(jnp.float32)) * scale
        s = softcap(s, logit_cap)
        pc = pci[None, None, None, None, :]                  # [1,1,1,1,c]
        mask = pc < Skv
        if causal:
            mask &= pc <= qp
            mask &= (qp - pc) < window
        if block_local is not None:     # llama4 iRoPE: block-local attention
            mask &= (qp // block_local) == (pc // block_local)
        if valid_len is not None:
            mask &= pc < valid_len
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p, vci.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, vd), jnp.float32)
    if n_chunks == 1:
        (m, l, acc), _ = body((m0, l0, a0), (kc[0], vc[0], kv_pos[0]))
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, kv_pos))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, vd)


# ------------------------------------------------------------------ GQA layer
def gqa_defs(cfg: ModelConfig) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    d = {
        "wq": ParamDef((D, H * hd), ("residual", "tp")),
        "wk": ParamDef((D, KV * hd), ("residual", "tp")),
        "wv": ParamDef((D, KV * hd), ("residual", "tp")),
        "wo": ParamDef((H * hd, D), ("tp", "residual")),
    }
    if cfg.attn_bias:
        d["bq"] = ParamDef((H * hd,), ("tp",), init="zeros")
        d["bk"] = ParamDef((KV * hd,), ("tp",), init="zeros")
        d["bv"] = ParamDef((KV * hd,), ("tp",), init="zeros")
    return d


def _attn_scale(cfg: ModelConfig) -> float:
    if cfg.query_pre_attn_scalar is not None:
        return cfg.query_pre_attn_scalar ** -0.5
    return cfg.resolved_head_dim ** -0.5


def gqa_apply(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
              is_local, cache: Optional[dict], decode_pos: Optional[jax.Array],
              chunk: int = 1024) -> Tuple[jax.Array, Optional[dict]]:
    """x: [B,S,D]. Train/prefill: cache=None in, returns new cache (k, v).
    Decode: cache={'k','v'} of [B,Smax,KV,hd], decode_pos [B] write index."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(B, S, H, hd)
    k = linear(x, p["wk"], p.get("bk")).reshape(B, S, KV, hd)
    v = linear(x, p["wv"], p.get("bv")).reshape(B, S, KV, hd)

    if cfg.rope_type != "none":
        sections = cfg.mrope_sections if cfg.rope_type == "mrope" else None
        ang = rope_angles(positions, hd, cfg.rope_theta, sections)
        q, k = apply_rope(q, ang), apply_rope(k, ang)

    context_parallel = False
    if decode_pos is None:
        from jax.sharding import PartitionSpec as _P
        from repro.distributed.sharding import (MODEL_AXIS, PROD_AXIS_SIZES,
                                                maybe_constrain)
        if H % PROD_AXIS_SIZES[MODEL_AXIS] != 0:
            context_parallel = True
            # Heads don't divide the TP axis (llama4: 40 vs 16). Left alone,
            # SPMD shards the head_dim CONTRACTION and all-reduces the fp32
            # score tensor every KV chunk (measured 21 GB per reduce). Use
            # context parallelism instead: q sharded over sequence on the
            # model axis, the (small, GQA) k/v gathered per device.
            q = maybe_constrain(q, _P(("pod", "data"), "model", None, None))
            k = maybe_constrain(k, _P(("pod", "data"), None, None, None))
            v = maybe_constrain(v, _P(("pod", "data"), None, None, None))

    window = None
    if cfg.sliding_window is not None:
        if cfg.layer_pattern == "swa":
            window = cfg.sliding_window
        else:  # alternating local/global: is_local is a (possibly traced) bool
            window = jnp.where(is_local, cfg.sliding_window, LARGE_WINDOW)
    block_local = None
    if cfg.attn_chunk is not None and cfg.layer_pattern == "chunked":
        # llama4 iRoPE: 3/4 layers attend within attn_chunk-sized blocks
        block_local = jnp.where(is_local, cfg.attn_chunk, LARGE_WINDOW)

    q_pos = positions[..., 0] if cfg.rope_type == "mrope" else positions
    if (cache is not None and decode_pos is not None
            and cfg.layer_pattern == "swa" and cfg.sliding_window is not None
            and cache["k"].shape[1] <= cfg.sliding_window):
        # ring-buffer (windowed) cache: slot = pos % W (§Perf, beyond-paper)
        out, cache = _windowed_decode(q, cache, k, v, decode_pos,
                                      scale=_attn_scale(cfg),
                                      logit_cap=cfg.attn_logit_softcap)
        out = linear(out.reshape(B, S, H * hd).astype(x.dtype), p["wo"])
        return out, cache
    if cache is not None and decode_pos is not None:
        if SHARDED_DECODE_AXIS is not None:
            # flash-decoding over the sequence-sharded cache (§Perf)
            from repro.distributed.sharding import get_mesh
            if get_mesh() is not None:
                w = window if window is not None else LARGE_WINDOW
                bl = None
                if cfg.attn_chunk is not None and cfg.layer_pattern == "chunked":
                    bl = jnp.where(is_local, cfg.attn_chunk, LARGE_WINDOW)
                out, ck, cv = _flash_decode_sharded(
                    q, cache["k"], cache["v"], k, v, decode_pos,
                    axis=SHARDED_DECODE_AXIS, batch_axes=("pod", "data"),
                    scale=_attn_scale(cfg), window=w,
                    logit_cap=cfg.attn_logit_softcap, block_local=bl)
                out = linear(out.reshape(B, S, H * hd).astype(x.dtype), p["wo"])
                return out, {"k": ck, "v": cv}
        # single-token decode: write k/v at decode_pos, attend over the cache
        upd = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0, 0)))
        cache = {"k": upd(cache["k"], k, decode_pos),
                 "v": upd(cache["v"], v, decode_pos)}
        k_all, v_all = cache["k"], cache["v"]
        valid = decode_pos + 1
    else:
        k_all, v_all, valid = k, v, None

    out = online_attention(q, k_all, v_all, q_pos, valid, causal=not cfg.is_encoder,
                           window=window, scale=_attn_scale(cfg),
                           logit_cap=cfg.attn_logit_softcap, chunk=chunk,
                           block_local=block_local)
    out = linear(out.reshape(B, S, H * hd).astype(x.dtype), p["wo"])
    # NOTE (§Perf iteration B3, REFUTED): constraining the attention output
    # back to batch-only sharding here was hypothesized to stop the shared
    # expert's D-contraction all-reduces, but measured 2331 GB of collectives
    # (vs 692 GB without) — the per-layer re-gather cost more than it saved.
    # Kept out; see EXPERIMENTS.md §Perf.
    new_cache = cache if cache is not None else {"k": k, "v": v}
    return out, new_cache


def gqa_apply_paged(cfg: ModelConfig, p: dict, x: jax.Array,
                    positions: jax.Array, is_local, paged) -> jax.Array:
    """Single-token batched decode through the paged KV cache
    (serving/paged_kv.py): q/k/v projections + rope exactly as
    :func:`gqa_apply`, then the new K/V are appended to each sequence's
    pages and attention gathers through the page table
    (kernels/paged_attention via the ops auto-dispatch).

    ``paged`` is a layer-bound attend hook (``PagedBatchView.bind``); the
    engine path applies units eagerly, so ``is_local`` is a concrete bool
    and the window resolves to a STATIC int the kernel can specialize on.
    """
    B, S, D = x.shape
    assert S == 1, "paged attention is the single-token decode path"
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(B, S, H, hd)
    k = linear(x, p["wk"], p.get("bk")).reshape(B, S, KV, hd)
    v = linear(x, p["wv"], p.get("bv")).reshape(B, S, KV, hd)
    if cfg.rope_type != "none":
        sections = cfg.mrope_sections if cfg.rope_type == "mrope" else None
        ang = rope_angles(positions, hd, cfg.rope_theta, sections)
        q, k = apply_rope(q, ang), apply_rope(k, ang)
    window = None
    if cfg.sliding_window is not None and (cfg.layer_pattern == "swa"
                                           or bool(is_local)):
        window = int(cfg.sliding_window)
    out = paged.attend(q[:, 0], k[:, 0], v[:, 0], scale=_attn_scale(cfg),
                       window=window, softcap=cfg.attn_logit_softcap)
    return linear(out.reshape(B, S, H * hd).astype(x.dtype), p["wo"])


def _windowed_decode(q, cache, k_new, v_new, pos, *, scale, logit_cap):
    """Single-token decode against a ring-buffer cache of length W.

    Slot i holds absolute position kv_pos = i + floor((pos - i)/W)*W — the
    newest position congruent to i (negative = not yet written -> masked).
    """
    B, _, H, hd = q.shape
    W, KV = cache["k"].shape[1], cache["k"].shape[2]
    G = H // KV
    slot = pos % W
    upd = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0)))
    ck = upd(cache["k"], k_new, slot)
    cv = upd(cache["v"], v_new, slot)

    slots = jnp.arange(W)
    kv_pos = slots[None, :] + ((pos[:, None] - slots[None, :]) // W) * W  # [B,W]
    qf = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, ck.astype(jnp.float32)) * scale
    s = softcap(s, logit_cap)
    mask = (kv_pos >= 0)[:, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, cv.astype(jnp.float32))
    return out.reshape(B, 1, H, hd), {"k": ck, "v": cv}


# ------------------------------------------------------------------ MLA layer
def mla_defs(cfg: ModelConfig) -> dict:
    m, D, H = cfg.mla, cfg.d_model, cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": ParamDef((D, H * qd), ("residual", "tp")),
        "w_dkv": ParamDef((D, m.kv_lora_rank), ("residual", None)),
        "w_krope": ParamDef((D, m.qk_rope_head_dim), ("residual", None)),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), init="ones"),
        "w_uk": ParamDef((m.kv_lora_rank, H * m.qk_nope_head_dim), (None, "tp")),
        "w_uv": ParamDef((m.kv_lora_rank, H * m.v_head_dim), (None, "tp")),
        "wo": ParamDef((H * m.v_head_dim, D), ("tp", "residual")),
    }


def mla_apply(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
              cache: Optional[dict], decode_pos: Optional[jax.Array],
              chunk: int = 1024) -> Tuple[jax.Array, Optional[dict]]:
    """MLA. Cache holds the COMPRESSED latents (c_kv, k_rope) — the memory win.
    Prefill: up-project per block. Decode: absorbed attention in latent space
    (W_uk folded into q, W_uv applied after) so per-step FLOPs stay O(r)."""
    from repro.models.layers import rms_norm
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    nd, rd, vd, r = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim, m.kv_lora_rank
    scale = (nd + rd) ** -0.5

    q = linear(x, p["wq"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)    # [B,S,r]
    k_rope = (x @ p["w_krope"]).reshape(B, S, 1, rd)

    ang = rope_angles(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, ang)
    k_rope = apply_rope(k_rope, ang)

    if cache is not None and decode_pos is not None:
        upd2 = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0)))
        cache = {"c_kv": upd2(cache["c_kv"], c_kv, decode_pos),
                 "k_rope": upd2(cache["k_rope"], k_rope[:, :, 0, :], decode_pos)}
        # absorbed decode: q_nope' = q_nope @ W_uk^T  -> latent space
        w_uk = p["w_uk"].reshape(r, H, nd)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)          # [B,1,H,r]
        q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)           # [B,1,H,r+rd]
        k_cat = jnp.concatenate([cache["c_kv"][:, :, None, :].astype(q_cat.dtype),
                                 cache["k_rope"][:, :, None, :].astype(q_cat.dtype)],
                                axis=-1)
        q_pos = positions
        out_lat = online_attention(
            q_cat, k_cat, cache["c_kv"][:, :, None, :], q_pos,
            decode_pos + 1, causal=True, window=None, scale=scale,
            logit_cap=None, chunk=chunk)                            # [B,1,H,r]
        w_uv = p["w_uv"].reshape(r, H, vd)
        out = jnp.einsum("bshr,rhv->bshv", out_lat, w_uv)
        out = linear(out.reshape(B, S, H * vd).astype(x.dtype), p["wo"])
        return out, cache

    # train / prefill: materialize k, v from latents for this block
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, nd)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, vd)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pad v to qk dim for the shared kernel? no — online_attention is dim-agnostic
    out = online_attention(qf, k, v, positions, None, causal=not cfg.is_encoder,
                           window=None, scale=scale, logit_cap=None, chunk=chunk)
    out = linear(out.reshape(B, S, H * vd).astype(x.dtype), p["wo"])
    return out, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}
