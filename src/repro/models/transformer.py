"""Config-driven model: one stack covering all assigned families.

Structure
---------
The layer list (``cfg.layer_kinds()``) is grouped into *segments* of
consecutive identical kinds; each segment's params are stacked [n, ...] and
executed with ``lax.scan`` (keeps HLO size O(1) in depth — essential for the
512-device dry-run). Per-layer variation that only changes masking (gemma2
local/global) rides through the scan as a scanned boolean. zamba2's shared
attention block is a single param tree applied at every occurrence (never
stacked, never swapped more than once — see DESIGN.md §4).

Modes: "train"/"prefill" run full sequences (SSM chunked forms, chunked
online-softmax attention); "decode" runs one token against a cache.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (
    ParamDef, init_from_defs, specs_from_defs, stack_specs, pspec,
    maybe_constrain)
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp_apply, mlp_defs, rms_norm, softcap

LOSS_CHUNK = 512        # token chunk for the logsumexp loss (never [T, V] at once)

# Dry-run accounting: XLA HLO cost analysis counts a while-loop body ONCE, so
# with scan-over-layers the reported FLOPs/bytes are ~n_layers too small. The
# dry-run sets this flag to fully unroll LAYER scans (trip count 1) so
# cost_analysis() reflects the whole model. Inner chunk scans (attention KV
# blocks, SSM chunks, the loss) remain rolled — the residual undercount is the
# attention-score term, reported analytically in the roofline (see
# benchmarks/bench_roofline.py).
LAYER_SCAN_UNROLL = False

# §Perf (beyond-paper): ring-buffer KV cache for uniformly sliding-window
# architectures (h2o-danube). The decode cache holds only the last `window`
# positions (slot = pos % window) instead of the full sequence — the SwapNet
# idea applied to the KV cache: the resident working set is the window, not
# the stream. Enabled by the dry-run / serving launcher.
WINDOWED_KV_CACHE = False

# §Perf (beyond-paper): Megatron-style sequence parallelism on the residual
# stream — the per-layer saved activation (the remat carry) is sharded over
# the "model" axis along sequence, cutting saved-residual memory by the TP
# width at the cost of per-layer gathers. Enabled by the dry-run launcher.
SEQ_PARALLEL_RESIDUAL = False


def _windowed_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if WINDOWED_KV_CACHE and cfg.layer_pattern == "swa" \
            and cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


# ------------------------------------------------------------------ plan
@dataclass(frozen=True)
class Segment:
    kind: str            # dense | moe | mamba2 | rwkv6 | shared_attn
    n: int
    layer_ids: Tuple[int, ...]

    @property
    def scanned(self) -> bool:
        return self.kind != "shared_attn"


def build_plan(cfg: ModelConfig) -> List[Segment]:
    kinds = cfg.layer_kinds()
    plan: List[Segment] = []
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        plan.append(Segment(kinds[i], j - i, tuple(range(i, j))))
        i = j
    return plan


# ------------------------------------------------------------------ defs
def layer_defs(cfg: ModelConfig, kind: str) -> dict:
    D = cfg.d_model
    if kind == "mamba2":
        return ssm_mod.mamba2_defs(cfg)
    if kind == "rwkv6":
        return ssm_mod.rwkv6_defs(cfg)
    d: Dict[str, Any] = {
        "ln1": ParamDef((D,), (None,), init="zeros" if cfg.post_norms else "ones"),
        "ln2": ParamDef((D,), (None,), init="zeros" if cfg.post_norms else "ones"),
        "attn": attn_mod.mla_defs(cfg) if cfg.mla else attn_mod.gqa_defs(cfg),
    }
    if cfg.post_norms:
        d["post_ln1"] = ParamDef((D,), (None,), init="zeros")
        d["post_ln2"] = ParamDef((D,), (None,), init="zeros")
    if kind == "moe":
        d["ffn"] = moe_mod.moe_defs(cfg)
    else:
        d["ffn"] = mlp_defs(cfg, D, cfg.d_ff)
    return d


def model_defs(cfg: ModelConfig) -> Tuple[dict, List[Segment]]:
    plan = build_plan(cfg)
    D, V = cfg.d_model, cfg.vocab_size
    defs: Dict[str, Any] = {"final_norm": ParamDef(
        (D,), (None,), init="zeros" if cfg.post_norms else "ones")}
    if cfg.embed_inputs:
        defs["embed"] = ParamDef((V, D), ("vocab", "residual"), init="small")
    if cfg.d_frontend:
        defs["frontend"] = ParamDef((cfg.d_frontend, D), (None, "residual"))
    if cfg.is_encoder:
        defs["mask_emb"] = ParamDef((D,), (None,), init="small")
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        defs["lm_head"] = ParamDef((D, V), ("residual", "vocab"), init="small")
    if any(s.kind == "shared_attn" for s in plan):
        defs["shared_attn"] = layer_defs(cfg, "dense")
    defs["segments"] = [
        layer_defs(cfg, s.kind) if s.scanned else {} for s in plan]
    return defs, plan


# ------------------------------------------------------------------ layer
def apply_layer(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                positions: jax.Array, is_local, cache, decode_pos,
                mode: str, paged=None):
    """Returns (x, new_cache, aux). ``paged`` (decode only) is a layer-bound
    paged-attention hook (serving/paged_kv.PagedBatchView.bind): attention
    K/V land in the page pool instead of a contiguous cache, and
    ``new_cache`` is None."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba2":
        h0 = cache["h"] if cache is not None else None
        cs = cache["conv"] if cache is not None else None
        if mode == "decode":
            out, (h, conv) = ssm_mod.mamba2_step(cfg, p, x, h0, cs)
        else:
            out, (h, conv) = ssm_mod.mamba2_chunked(cfg, p, x, h0, cs)
        return x + out, {"h": h, "conv": conv}, aux
    if kind == "rwkv6":
        from repro.models.layers import layer_norm
        S0 = cache["S"] if cache is not None else None
        sh1 = cache["shift1"] if cache is not None else None
        sh2 = cache["shift2"] if cache is not None else None
        xn = layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
        if mode == "decode":
            out, (S, sh1n) = ssm_mod.rwkv6_time_mix_step(cfg, p, xn, S0, sh1)
        else:
            out, (S, sh1n) = ssm_mod.rwkv6_time_mix_chunked(cfg, p, xn, S0, sh1)
        x = x + out
        xn = layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
        out, sh2n = ssm_mod.rwkv6_channel_mix(cfg, p, xn, sh2)
        return x + out, {"S": S, "shift1": sh1n, "shift2": sh2n}, aux

    # dense / moe / shared_attn transformer block
    h = rms_norm(x, p["ln1"], cfg.norm_eps, plus_one=cfg.post_norms)
    if cfg.mla is not None:
        a_out, new_cache = attn_mod.mla_apply(cfg, p["attn"], h, positions,
                                              cache, decode_pos)
    elif paged is not None and mode == "decode":
        a_out = attn_mod.gqa_apply_paged(cfg, p["attn"], h, positions,
                                         is_local, paged)
        new_cache = None
    else:
        a_out, new_cache = attn_mod.gqa_apply(cfg, p["attn"], h, positions,
                                              is_local, cache, decode_pos)
    if cfg.post_norms:
        a_out = rms_norm(a_out, p["post_ln1"], cfg.norm_eps, plus_one=True)
    x = x + a_out
    h = rms_norm(x, p["ln2"], cfg.norm_eps, plus_one=cfg.post_norms)
    if kind == "moe":
        f_out, aux = moe_mod.moe_apply(cfg, p["ffn"], h)
    else:
        f_out = mlp_apply(cfg, p["ffn"], h)
    if cfg.post_norms:
        f_out = rms_norm(f_out, p["post_ln2"], cfg.norm_eps, plus_one=True)
    return x + f_out, new_cache, aux


# ------------------------------------------------------------------ stack
def apply_stack(cfg: ModelConfig, params: dict, plan: List[Segment],
                x: jax.Array, positions: jax.Array, mode: str,
                cache: Optional[list] = None, decode_pos=None,
                remat: bool = False):
    """Returns (x, new_cache_list, aux_sum)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: List[Any] = []
    for si, seg in enumerate(plan):
        seg_cache = cache[si] if cache is not None else None
        if not seg.scanned:
            x, c_new, aux = apply_layer(
                cfg, "dense", params["shared_attn"], x, positions,
                False, seg_cache, decode_pos, mode)
            new_cache.append(c_new)
            aux_total += aux
            continue
        flags = jnp.asarray([cfg.is_local_layer(i) for i in seg.layer_ids])

        def body(carry, xs, _kind=seg.kind):
            xcur = carry
            if SEQ_PARALLEL_RESIDUAL and mode != "decode":
                xcur = maybe_constrain(
                    xcur, P(("pod", "data"), "model", None))
            lp, flag, c = xs
            xcur, c_new, aux = apply_layer(cfg, _kind, lp, xcur, positions,
                                           flag, c, decode_pos, mode)
            return xcur, (c_new, aux)

        if remat:
            body = jax.checkpoint(body)
        xs = (params["segments"][si], flags, seg_cache)
        x, (c_seg, aux_seg) = jax.lax.scan(
            body, x, xs, unroll=seg.n if LAYER_SCAN_UNROLL else 1)
        new_cache.append(c_seg)
        aux_total += jnp.sum(aux_seg)
    return x, new_cache, aux_total


# ------------------------------------------------------------------ model
class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.defs, self.plan = model_defs(cfg)

    # ---------------- params
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        parts = dict(self.defs)
        seg_defs = parts.pop("segments")
        params = init_from_defs(parts, key)
        segs = []
        for si, (seg, sdefs) in enumerate(zip(self.plan, seg_defs)):
            if not seg.scanned:
                segs.append({})
                continue
            keys = jax.random.split(jax.random.fold_in(key, 1000 + si), seg.n)
            segs.append(jax.vmap(lambda k, d=sdefs: init_from_defs(d, k))(keys))
        params["segments"] = segs
        return params

    def param_struct(self, dtype: Optional[str] = None) -> dict:
        """ShapeDtypeStruct pytree (no allocation) — dry-run stand-in.
        dtype overrides storage dtype (e.g. 'bfloat16' for serving weights)."""
        is_def = lambda x: isinstance(x, ParamDef)

        def mk(d: ParamDef, lead=()):
            return jax.ShapeDtypeStruct(lead + d.shape,
                                        jnp.dtype(dtype or d.dtype))

        parts = dict(self.defs)
        seg_defs = parts.pop("segments")
        st = jax.tree.map(mk, parts, is_leaf=is_def)
        st["segments"] = [
            jax.tree.map(lambda d, _n=seg.n: mk(d, (_n,)), sdefs, is_leaf=is_def)
            if seg.scanned else {}
            for seg, sdefs in zip(self.plan, seg_defs)]
        return st

    def param_specs(self) -> dict:
        parts = dict(self.defs)
        seg_defs = parts.pop("segments")
        specs = specs_from_defs(parts)
        specs["segments"] = [
            stack_specs(specs_from_defs(d), 1) if s.scanned else {}
            for s, d in zip(self.plan, seg_defs)]
        return specs

    # ---------------- embedding / io
    def _embed(self, params: dict, batch: dict, mode: str) -> Tuple[jax.Array, jax.Array]:
        """Returns (x [B,S,D], positions)."""
        cfg = self.cfg
        if cfg.embed_inputs:
            key = "token" if mode == "decode" else "tokens"
            tokens = batch[key]
            x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
            if cfg.family == "vlm" and mode != "decode" and "vision_embeds" in batch:
                v = (batch["vision_embeds"] @ params["frontend"]).astype(x.dtype)
                nv = v.shape[1]
                x = jnp.concatenate([v, x[:, nv:]], axis=1)
        else:
            x = (batch["features"] @ params["frontend"]).astype(jnp.dtype(cfg.dtype))
            if cfg.is_encoder and mode == "train" and "mask" in batch:
                x = jnp.where(batch["mask"][..., None],
                              params["mask_emb"].astype(x.dtype), x)
        if cfg.final_logit_softcap is not None:   # gemma-style embed scaling
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

        if "positions" in batch:
            positions = batch["positions"]
        else:
            B, S = x.shape[:2]
            if mode == "decode":
                positions = batch["pos"][:, None]          # [B,1]
            else:
                positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            if cfg.rope_type == "mrope":
                positions = jnp.broadcast_to(positions[..., None],
                                             positions.shape + (3,))
        return x, positions

    def _head(self, params: dict, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        w = params.get("lm_head")
        if w is None:
            w = params["embed"].T
        logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
        return softcap(logits, cfg.final_logit_softcap)

    # ---------------- steps
    def cast(self, params: dict) -> dict:
        """Cast float params to the compute dtype (storage stays fp32 in the
        optimizer; fp32-sensitive math upcasts locally)."""
        dt = jnp.dtype(self.cfg.dtype)
        return jax.tree.map(
            lambda a: a.astype(dt) if jnp.issubdtype(a.dtype, jnp.floating) else a,
            params)

    def forward(self, params: dict, batch: dict, mode: str = "prefill",
                cache=None, remat: bool = False):
        """Full-sequence forward. Returns (hidden, cache, aux)."""
        params = self.cast(params)
        x, positions = self._embed(params, batch, mode)
        decode_pos = batch.get("pos") if mode == "decode" else None
        x, new_cache, aux = apply_stack(
            self.cfg, params, self.plan, x, positions, mode,
            cache=cache, decode_pos=decode_pos, remat=remat)
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps,
                     plus_one=self.cfg.post_norms)
        return x, new_cache, aux

    def loss(self, params: dict, batch: dict) -> Tuple[jax.Array, dict]:
        """Token-chunked cross-entropy (never materializes [T, V])."""
        cfg = self.cfg
        h, _, aux = self.forward(params, batch, mode="train", remat=True)
        B, S, D = h.shape
        targets = batch["targets"]
        if cfg.is_encoder:
            weights = batch["mask"].astype(jnp.float32)
        else:
            weights = jnp.ones((B, S), jnp.float32)

        w_head = params.get("lm_head")
        if w_head is None:
            w_head = params["embed"].T
        chunk = min(LOSS_CHUNK, S)
        n_chunks = S // chunk if S % chunk == 0 else 1
        if S % chunk != 0:
            chunk = S
        hc = h.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
        tc = targets.reshape(B, n_chunks, chunk).swapaxes(0, 1)
        wc = weights.reshape(B, n_chunks, chunk).swapaxes(0, 1)

        def body(carry, xs):
            hs, ts, ws = xs
            logits = softcap(hs.astype(jnp.float32) @ w_head.astype(jnp.float32),
                             cfg.final_logit_softcap)
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
            nll = (lse - tgt) * ws
            return carry + jnp.sum(nll), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc, wc))
        denom = jnp.maximum(jnp.sum(weights), 1.0)
        loss = total / denom + aux
        return loss, {"loss": loss, "aux": aux, "tokens": denom}

    def prefill(self, params: dict, batch: dict):
        h, cache, _ = self.forward(params, batch, mode="prefill")
        logits = self._head(params, h[:, -1:])
        return logits, cache

    def decode_step(self, params: dict, cache, batch: dict):
        """batch: {'token': [B,1], 'pos': [B]} (+ 'positions' [B,1,3] for mrope)."""
        h, cache, _ = self.forward(params, batch, mode="decode", cache=cache)
        logits = self._head(params, h)
        return logits, cache

    # ---------------- specs (ShapeDtypeStructs for dry-run / engine alloc)
    def cache_struct(self, shape: ShapeConfig) -> list:
        cfg = self.cfg
        B, L = shape.global_batch, _windowed_cache_len(cfg, shape.seq_len)
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        out = []
        for seg in self.plan:
            lead = (seg.n,) if seg.scanned else ()
            kind = "dense" if seg.kind == "shared_attn" else seg.kind
            if kind == "mamba2":
                d_inner, nh, ds = ssm_mod.mamba2_dims(cfg)
                conv_c = d_inner + 2 * ds
                out.append({
                    "h": jax.ShapeDtypeStruct(lead + (B, nh, cfg.ssm.head_dim, ds), jnp.float32),
                    "conv": jax.ShapeDtypeStruct(lead + (B, cfg.ssm.d_conv - 1, conv_c), dt)})
            elif kind == "rwkv6":
                nh, rhd = ssm_mod.rwkv6_dims(cfg)
                out.append({
                    "S": jax.ShapeDtypeStruct(lead + (B, nh, rhd, rhd), jnp.float32),
                    "shift1": jax.ShapeDtypeStruct(lead + (B, 1, cfg.d_model), dt),
                    "shift2": jax.ShapeDtypeStruct(lead + (B, 1, cfg.d_model), dt)})
            elif cfg.mla is not None:
                m = cfg.mla
                out.append({
                    "c_kv": jax.ShapeDtypeStruct(lead + (B, L, m.kv_lora_rank), dt),
                    "k_rope": jax.ShapeDtypeStruct(lead + (B, L, m.qk_rope_head_dim), dt)})
            else:
                out.append({
                    "k": jax.ShapeDtypeStruct(lead + (B, L, KV, hd), dt),
                    "v": jax.ShapeDtypeStruct(lead + (B, L, KV, hd), dt)})
        return out

    def cache_specs(self, shape: ShapeConfig, mesh=None) -> list:
        """PartitionSpecs matching cache_struct. Batch over (pod, data) where
        divisible; the cache sequence dim is sharded over 'model'
        (flash-decoding style) — and over every remaining axis when batch=1
        (long_500k) so no axis idles."""
        cfg = self.cfg
        B = shape.global_batch
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh \
            else {"data": 16, "model": 16}
        cand = tuple(a for a in ("pod", "data") if a in axis_sizes)
        bsz = int(np.prod([axis_sizes[a] for a in cand])) if cand else 1
        if cand and B % bsz == 0 and B > 1:
            batch_ax, seq_extra = cand, ()
        elif B % axis_sizes.get("data", 16) == 0 and B > 1:
            batch_ax, seq_extra = "data", ()
        else:
            batch_ax = None
            seq_extra = tuple(a for a in ("pod", "data") if a in axis_sizes)
        seq_ax = seq_extra + ("model",) if batch_ax is None else "model"
        out = []
        for seg in self.plan:
            lead = (None,) if seg.scanned else ()
            kind = "dense" if seg.kind == "shared_attn" else seg.kind
            if kind == "mamba2":
                nh = ssm_mod.mamba2_dims(cfg)[1]
                hax = "model" if nh % 16 == 0 else None
                out.append({"h": P(*lead, batch_ax, hax, None, None),
                            "conv": P(*lead, batch_ax, None, None)})
            elif kind == "rwkv6":
                nh = ssm_mod.rwkv6_dims(cfg)[0]
                hax = "model" if nh % 16 == 0 else None
                out.append({"S": P(*lead, batch_ax, hax, None, None),
                            "shift1": P(*lead, batch_ax, None, None),
                            "shift2": P(*lead, batch_ax, None, None)})
            elif cfg.mla is not None:
                out.append({"c_kv": P(*lead, batch_ax, seq_ax, None),
                            "k_rope": P(*lead, batch_ax, seq_ax, None)})
            else:
                out.append({"k": P(*lead, batch_ax, seq_ax, None, None),
                            "v": P(*lead, batch_ax, seq_ax, None, None)})
        return out


def alloc_cache(model: "Model", shape: ShapeConfig) -> list:
    """Materialize a zero-filled decode cache matching cache_struct."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        model.cache_struct(shape))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if shape.mode == "decode":
        d = {"token": jax.ShapeDtypeStruct((B, 1), i32),
             "pos": jax.ShapeDtypeStruct((B,), i32)}
        if cfg.rope_type == "mrope":
            d["positions"] = jax.ShapeDtypeStruct((B, 1, 3), i32)
        return d
    d = {}
    if cfg.embed_inputs:
        d["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:
        d["features"] = jax.ShapeDtypeStruct((B, S, cfg.d_frontend), dt)
    if shape.mode == "train":
        d["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.is_encoder:
            d["mask"] = jax.ShapeDtypeStruct((B, S), jnp.bool_)
    if cfg.family == "vlm":
        d["vision_embeds"] = jax.ShapeDtypeStruct((B, cfg.n_vision_tokens,
                                                   cfg.d_frontend), dt)
        d["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
    return d


def input_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """PartitionSpecs matching input_specs (batch over (pod, data))."""
    from repro.distributed.sharding import batch_axes, filter_spec
    ba = batch_axes(mesh)
    specs = {}
    for k, v in input_specs(cfg, shape).items():
        trailing = (None,) * (len(v.shape) - 1)
        b = ba if v.shape[0] % int(np.prod([mesh.shape[a] for a in ba])) == 0 else None
        specs[k] = P(b, *trailing)
    return specs
