"""Shared layer primitives: norms, RoPE / M-RoPE, MLPs.

:func:`linear` is the precision routing point of the swap path: when a
weight arrives as a :class:`~repro.kernels.qtensor.QuantizedTensor` (the
quant store's fused/lazy mode), the matmul streams the quantized tiles
through the fused dequant-matmul kernel — fp for that weight never exists
in device memory. Plain arrays take the exact jnp path, bit-identical to
the seed.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamDef
from repro.kernels.qtensor import QuantizedTensor


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w) if plus_one else w
    return (x * scale).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


# ------------------------------------------------------------------ rotary
def rope_angles(positions: jax.Array, head_dim: int, theta: float,
                mrope_sections: Optional[Tuple[int, int, int]] = None) -> jax.Array:
    """positions: [B, S] (rope) or [B, S, 3] (mrope) -> angles [B, S, head_dim/2]."""
    half = head_dim // 2
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if mrope_sections is None:
        pos = positions.astype(jnp.float32)
        return pos[..., None] * inv_freq
    # M-RoPE (Qwen2-VL): frequency slots are split into (t, h, w) sections,
    # each driven by its own position stream. Static numpy: never traced.
    import numpy as np
    sec = np.asarray(mrope_sections)
    assert int(sec.sum()) == half, (mrope_sections, half)
    section_id = jnp.asarray(np.repeat(np.arange(3), sec))  # [half]
    pos = positions.astype(jnp.float32)[..., section_id]   # [B, S, half]
    return pos * inv_freq


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: [B, S, H, head_dim]; angles: [B, S, head_dim/2] (neox half-rotation)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(dt)


# ------------------------------------------------------------------ linear
def linear(x: jax.Array, w, b: Optional[jax.Array] = None,
           act: str = "none") -> jax.Array:
    """y = act(x @ w + b), routed by weight representation.

    QuantizedTensor w -> the fused dequant-matmul (``swap_linear_q``): int8
    or packed-int4 tiles are dequantized inside the weight-stream k-loop,
    bias and activation fused at the fp32 flush. Array w -> the plain jnp
    ops the seed used (kept verbatim so exact-store paths stay
    bit-identical). Leading x axes beyond the last are flattened for the
    kernel and restored after.
    """
    if isinstance(w, QuantizedTensor):
        from repro.kernels.ops import swap_linear_q
        lead = x.shape[:-1]
        y = swap_linear_q(x.reshape(-1, x.shape[-1]), w.q, w.scales, b,
                          bits=w.bits, act=act)
        return y.reshape(*lead, y.shape[-1])
    r = x @ w
    if b is not None:
        r = r + b
    if act == "silu":
        r = jax.nn.silu(r)
    elif act == "gelu":
        r = jax.nn.gelu(r, approximate=True)
    return r


# ------------------------------------------------------------------ MLP
def mlp_defs(cfg: ModelConfig, d_in: int, d_hidden: int) -> dict:
    if cfg.act in ("swiglu", "gelu_glu"):
        return {
            "wi0": ParamDef((d_in, d_hidden), ("residual", "tp")),
            "wi1": ParamDef((d_in, d_hidden), ("residual", "tp")),
            "wo": ParamDef((d_hidden, d_in), ("tp", "residual")),
        }
    return {
        "wi": ParamDef((d_in, d_hidden), ("residual", "tp")),
        "wo": ParamDef((d_hidden, d_in), ("tp", "residual")),
    }


def mlp_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.act in ("swiglu", "gelu_glu"):
        gate = linear(x, p["wi0"],
                      act="silu" if cfg.act == "swiglu" else "gelu")
        return linear(gate * linear(x, p["wi1"]), p["wo"])
    h = linear(x, p["wi"])
    if cfg.act == "relu_sq":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h, approximate=True)
    return linear(h, p["wo"])


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
