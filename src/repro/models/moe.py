"""Mixture-of-Experts: top-k router + capacity-based sort/gather dispatch.

Expert-parallel friendly: the [E, C, D] expert buffer is sharded over the
"model" (experts) axis; the scatter/gather across token- and expert-sharded
layouts lowers to all-to-all under SPMD. No dense [T, E, C] dispatch tensor is
ever built (that is the naive formulation that blows up memory).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamDef, maybe_constrain


def moe_defs(cfg: ModelConfig) -> dict:
    e = cfg.moe
    D = cfg.d_model
    # Expert weights: EP over "model" on E; the FSDP ("data") shard lives on
    # the EXPERT-HIDDEN dim (F), not on D — contracting over an FSDP-sharded
    # D would force an [E,C,F]-sized activation all-reduce per matmul (§Perf
    # hypothesis B, confirmed: 1.5 TB/step on llama4 prefill). With F sharded,
    # wi0/wi1 contract over a whole D, the gated product stays F-sharded, and
    # only wo's output pays one (much smaller) [E,C,D] reduction.
    d = {
        "router": ParamDef((D, e.n_routed), ("residual", None), init="small",
                           dtype="float32"),
        "wi0": ParamDef((e.n_routed, D, e.d_expert), ("experts", None, "residual")),
        "wi1": ParamDef((e.n_routed, D, e.d_expert), ("experts", None, "residual")),
        "wo": ParamDef((e.n_routed, e.d_expert, D), ("experts", "residual", None)),
    }
    if e.n_shared:
        ds = e.d_shared or e.d_expert * e.n_shared
        d["shared"] = {
            "wi0": ParamDef((D, ds), ("residual", "tp")),
            "wi1": ParamDef((D, ds), ("residual", "tp")),
            "wo": ParamDef((ds, D), ("tp", "residual")),
        }
    return d


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,D] -> (y [B,S,D], aux_loss scalar)."""
    e = cfg.moe
    capacity_factor = e.capacity_factor
    B, S, D = x.shape
    T = B * S
    E, K = e.n_routed, e.top_k
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"])            # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                     # [T,K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    one_hot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)      # [T,K,E]
    f = one_hot.sum((0, 1)) / (T * K)
    pbar = probs.mean(0)
    aux = e.aux_loss_weight * E * jnp.sum(f * pbar)

    C = max(8, int(-(-T * K // E) * capacity_factor) // 8 * 8)  # per-expert slots
    flat_tok = jnp.repeat(jnp.arange(T), K)                    # [T*K]
    flat_e = top_e.reshape(-1)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    s_tok, s_e, s_w = flat_tok[order], flat_e[order], flat_w[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * K) - starts[s_e]
    ok = pos < C
    slot = jnp.where(ok, s_e * C + pos, E * C)                 # OOB -> dropped

    buf = jnp.zeros((E * C, D), x.dtype).at[slot].set(
        xf[s_tok], mode="drop")
    h = buf.reshape(E, C, D)
    h = maybe_constrain(h, P("model", None, None))
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["wi0"]))
    up = jnp.einsum("ecd,edf->ecf", h, p["wi1"])
    out = jnp.einsum("ecf,efd->ecd", gate * up, p["wo"])
    out = maybe_constrain(out, P("model", None, None))

    y_sorted = out.reshape(E * C, D)[jnp.minimum(slot, E * C - 1)]
    contrib = y_sorted * (s_w * ok)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[s_tok].add(contrib.astype(x.dtype))

    if e.n_shared:
        # shared experts are dense 2-D matmuls: routed through linear() so
        # quantized-resident weights stream through the fused kernel (the
        # 3-D routed stacks above are einsum consumers — dequant fallback)
        from repro.models.layers import linear
        sp = p["shared"]
        y = y + linear(linear(xf, sp["wi0"], act="silu")
                       * linear(xf, sp["wi1"]), sp["wo"])
    return y.reshape(B, S, D), aux
