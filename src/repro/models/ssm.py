"""State-space / linear-attention blocks: Mamba2 (chunked SSD) and RWKV6.

Both ship two forms:
  * chunked (train / prefill): matmul-heavy chunk-parallel scan — the
    TPU-idiomatic MXU-friendly formulation (decay ratios kept <= 1 inside a
    chunk so no log-space renormalization is needed for mamba2; rwkv6 bounds
    per-step log-decay so chunk-local ratios stay in fp32 range);
  * step (decode): single-token state update.

Naive per-timestep references live in tests (and kernels/ref) to validate the
chunked math.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamDef
from repro.models.layers import linear, rms_norm

# rwkv6: per-step log-decay clamped to [W_LOG_MIN, W_LOG_MAX]; with chunk
# size Q, |cumulative| <= Q*|W_LOG_MIN| must stay < log(float32 max) ~ 88.
RWKV_CHUNK = 16
W_LOG_MIN = -5.0
W_LOG_MAX = -1e-4


def conv1d_causal(x: jax.Array, w: jax.Array,
                  state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x [B,S,C], w [K,C]; state [B,K-1,C] (prev tail).
    Returns (y [B,S,C], new_state [B,K-1,C])."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # [B, S+K-1, C]
    y = sum(xp[:, k:k + S] * w[k] for k in range(K))
    return y, xp[:, -(K - 1):] if K > 1 else jnp.zeros((B, 0, C), x.dtype)


# ==========================================================================
# Mamba2
# ==========================================================================
def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.d_state


def mamba2_defs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    s = cfg.ssm
    d_inner, nh, ds = mamba2_dims(cfg)
    return {
        "norm": ParamDef((D,), (None,), init="ones"),
        "wz": ParamDef((D, d_inner), ("residual", "tp")),
        "wx": ParamDef((D, d_inner), ("residual", "tp")),
        "wB": ParamDef((D, ds), ("residual", None)),
        "wC": ParamDef((D, ds), ("residual", None)),
        "wdt": ParamDef((D, nh), ("residual", "tp")),
        "conv_w": ParamDef((s.d_conv, d_inner + 2 * ds), (None, None), scale=0.5),
        "A_log": ParamDef((nh,), ("tp",), init="zeros"),
        "dt_bias": ParamDef((nh,), ("tp",), init="zeros"),
        "D_skip": ParamDef((nh,), ("tp",), init="ones"),
        "norm_y": ParamDef((d_inner,), (None,), init="ones"),
        "wo": ParamDef((d_inner, D), ("tp", "residual")),
    }


def _mamba2_inputs(cfg: ModelConfig, p: dict, x: jax.Array,
                   conv_state: Optional[jax.Array]):
    """Common projections + causal conv. x [B,S,D]."""
    d_inner, nh, ds = mamba2_dims(cfg)
    B, S, D = x.shape
    z = x @ p["wz"]
    xbc = jnp.concatenate([x @ p["wx"], x @ p["wB"], x @ p["wC"]], axis=-1)
    xbc, new_conv = conv1d_causal(xbc, p["conv_w"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_inner].reshape(B, S, nh, cfg.ssm.head_dim)
    Bv = xbc[..., d_inner:d_inner + ds]
    Cv = xbc[..., d_inner + ds:]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(dt * (-jnp.exp(p["A_log"].astype(jnp.float32))))  # [B,S,nh] in (0,1)
    return z, xs, Bv, Cv, dt, a, new_conv


def mamba2_chunked(cfg: ModelConfig, p: dict, x: jax.Array,
                   h0: Optional[jax.Array] = None,
                   conv_state: Optional[jax.Array] = None):
    """Chunked SSD. x [B,S,D] -> (y [B,S,D], (h [B,nh,hd,ds], conv_state))."""
    d_inner, nh, ds = mamba2_dims(cfg)
    hd = cfg.ssm.head_dim
    B, S, D = x.shape
    Q = min(cfg.ssm.chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    z, xs, Bv, Cv, dt, a, new_conv = _mamba2_inputs(cfg, p, x, conv_state)

    # chunk views: [B, nc, Q, ...] -> scan over nc
    def ch(t):
        return t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    # inputs stay in the compute dtype through the chunk reshape (§Perf:
    # halves the full-sequence staging bytes); upcast happens per chunk
    xs_c, B_c, C_c, dt_c, a_c = map(ch, (xs, Bv, Cv, dt, a))
    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)

    idx = jnp.arange(Q)
    causal = idx[:, None] >= idx[None, :]          # i<=t

    def body(h, xs_i):
        xq, Bq, Cq, dtq, aq = xs_i                 # [B,Q,nh,hd],[B,Q,ds],...,[B,Q,nh]
        xq = xq.astype(jnp.float32)
        Bq = Bq.astype(jnp.float32)
        Cq = Cq.astype(jnp.float32)
        l = jnp.cumsum(jnp.log(jnp.maximum(aq, 1e-37)), axis=1)   # [B,Q,nh]
        # intra-chunk: M[t,i,h] = (C_t.B_i) * exp(l_t - l_i) * dt_i, i<=t
        cb = jnp.einsum("btd,bid->bti", Cq, Bq)
        ratio = jnp.exp(l[:, :, None, :] - l[:, None, :, :])      # [B,Q,Q,nh]
        M = cb[..., None] * ratio * dtq[:, None, :, :]
        M = jnp.where(causal[None, :, :, None], M, 0.0)
        y_intra = jnp.einsum("btin,binh->btnh", M, xq)
        # inter-chunk: y_t += exp(l_t) * C_t . h
        y_inter = jnp.einsum("btd,bnhd,btn->btnh", Cq, h, jnp.exp(l))
        # state update: h' = exp(l_Q) h + sum_i exp(l_Q - l_i) dt_i x_i B_i^T
        w_state = jnp.exp(l[:, -1:, :] - l) * dtq                 # [B,Q,nh] <=1
        h_new = (jnp.exp(l[:, -1])[:, :, None, None] * h
                 + jnp.einsum("btnh,btd,btn->bnhd", xq, Bq, w_state))
        return h_new, (y_intra + y_inter).astype(x.dtype)

    # remat the chunk body: the [B,Q,Q,nh] decay/score intermediates are
    # recomputed in backward instead of being saved for all S/Q chunks
    h_final, y = jax.lax.scan(jax.checkpoint(body), h0,
                              (xs_c, B_c, C_c, dt_c, a_c))
    y = y.swapaxes(0, 1).reshape(B, S, nh, hd)
    y = (y.astype(jnp.float32)
         + xs.astype(jnp.float32) * p["D_skip"][:, None]).reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_y"], cfg.norm_eps)
    return linear(y.astype(x.dtype), p["wo"]), (h_final, new_conv)


def mamba2_step(cfg: ModelConfig, p: dict, x: jax.Array,
                h: jax.Array, conv_state: jax.Array):
    """Single-token decode. x [B,1,D], h [B,nh,hd,ds], conv_state [B,K-1,C]."""
    d_inner, nh, ds = mamba2_dims(cfg)
    hd = cfg.ssm.head_dim
    B = x.shape[0]
    z, xs, Bv, Cv, dt, a, new_conv = _mamba2_inputs(cfg, p, x, conv_state)
    xq = xs[:, 0].astype(jnp.float32)              # [B,nh,hd]
    Bq = Bv[:, 0].astype(jnp.float32)              # [B,ds]
    Cq = Cv[:, 0].astype(jnp.float32)
    dtq, aq = dt[:, 0], a[:, 0]                    # [B,nh]
    h = aq[:, :, None, None] * h + jnp.einsum(
        "bnh,bd,bn->bnhd", xq, Bq, dtq)
    y = jnp.einsum("bnhd,bd->bnh", h, Cq) + xq * p["D_skip"][:, None]
    y = y.reshape(B, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_y"], cfg.norm_eps)
    return linear(y.astype(x.dtype), p["wo"]), (h, new_conv)


# ==========================================================================
# RWKV6 ("Finch") — data-dependent decay, token shift
# ==========================================================================
def rwkv6_dims(cfg: ModelConfig) -> Tuple[int, int]:
    hd = cfg.ssm.head_dim
    return cfg.d_model // hd, hd                   # (n_heads, head_dim)


def rwkv6_defs(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    nh, hd = rwkv6_dims(cfg)
    lora = 64
    return {
        "ln1_w": ParamDef((D,), (None,), init="ones"),
        "ln1_b": ParamDef((D,), (None,), init="zeros"),
        "ln2_w": ParamDef((D,), (None,), init="ones"),
        "ln2_b": ParamDef((D,), (None,), init="zeros"),
        # time-mix token-shift interpolators
        "mu_r": ParamDef((D,), (None,), init="small"),
        "mu_k": ParamDef((D,), (None,), init="small"),
        "mu_v": ParamDef((D,), (None,), init="small"),
        "mu_g": ParamDef((D,), (None,), init="small"),
        "mu_w": ParamDef((D,), (None,), init="small"),
        # data-dependent decay lora (the Finch contribution)
        "w_base": ParamDef((D,), (None,), init="zeros"),
        "w_lora_a": ParamDef((D, lora), ("residual", None), init="small"),
        "w_lora_b": ParamDef((lora, D), (None, None), init="small"),
        "wr": ParamDef((D, D), ("residual", "tp")),
        "wk": ParamDef((D, D), ("residual", "tp")),
        "wv": ParamDef((D, D), ("residual", "tp")),
        "wg": ParamDef((D, D), ("residual", "tp")),
        "u": ParamDef((nh, hd), (None, None), init="small"),
        "ln_x_w": ParamDef((D,), (None,), init="ones"),
        "ln_x_b": ParamDef((D,), (None,), init="zeros"),
        "wo": ParamDef((D, D), ("tp", "residual")),
        # channel mix
        "mu_ck": ParamDef((D,), (None,), init="small"),
        "mu_cr": ParamDef((D,), (None,), init="small"),
        "ck": ParamDef((D, F), ("residual", "tp")),
        "cv": ParamDef((F, D), ("tp", "residual")),
        "cr": ParamDef((D, D), ("residual", "tp")),
    }


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """Token shift: x[t] -> x[t-1]; prev [B,1,D] seeds position 0."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _rwkv_time_inputs(cfg, p, xn, shift_prev):
    """Projections for the time-mix half. xn is post-ln1. Returns fp32."""
    nh, hd = rwkv6_dims(cfg)
    B, S, D = xn.shape
    xp = _shift(xn, shift_prev)
    def lerp(mu):
        return xn + (xp - xn) * mu
    r = (lerp(p["mu_r"]) @ p["wr"]).reshape(B, S, nh, hd).astype(jnp.float32)
    k = (lerp(p["mu_k"]) @ p["wk"]).reshape(B, S, nh, hd).astype(jnp.float32)
    v = (lerp(p["mu_v"]) @ p["wv"]).reshape(B, S, nh, hd).astype(jnp.float32)
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["wg"])
    w_log = (p["w_base"]
             + jnp.tanh(lerp(p["mu_w"]) @ p["w_lora_a"]) @ p["w_lora_b"])
    logw = jnp.clip(-jnp.exp(w_log.astype(jnp.float32)), W_LOG_MIN, W_LOG_MAX)
    logw = logw.reshape(B, S, nh, hd)
    return r, k, v, g, logw, xn[:, -1:]


def rwkv6_time_mix_chunked(cfg: ModelConfig, p: dict, xn: jax.Array,
                           S0: Optional[jax.Array] = None,
                           shift_prev: Optional[jax.Array] = None):
    """xn [B,S,D] (post-ln1). Returns (out [B,S,D], (S [B,nh,hd,hd], shift))."""
    nh, hd = rwkv6_dims(cfg)
    B, S, D = xn.shape
    Q = min(RWKV_CHUNK, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    r, k, v, g, logw, shift_out = _rwkv_time_inputs(cfg, p, xn, shift_prev)
    if S0 is None:
        S0 = jnp.zeros((B, nh, hd, hd), jnp.float32)

    def ch(t):
        return t.reshape(B, nc, Q, nh, hd).swapaxes(0, 1)
    rc, kc, vc, wc = map(ch, (r, k, v, logw))
    idx = jnp.arange(Q)
    strict = idx[:, None] > idx[None, :]           # i < t

    def body(Scur, xs):
        rq, kq, vq, lw = xs                        # [B,Q,nh,hd]
        l = jnp.cumsum(lw, axis=1)                 # [B,Q,nh,hd] (<=0, >= Q*W_LOG_MIN)
        lprev = l - lw                             # l_{t-1} (0 at t=0)
        r_dec = rq * jnp.exp(lprev)                # bounded <= |r|
        k_inv = kq * jnp.exp(-l)                   # bounded by exp(Q*|W_LOG_MIN|)
        A = jnp.einsum("btnh,binh->btin", r_dec, k_inv)
        A = jnp.where(strict[None, :, :, None], A, 0.0)
        bonus = jnp.einsum("btnh,btnh->btn", rq, p["u"][None, None] * kq)
        y = (jnp.einsum("btin,binh->btnh", A, vq)
             + bonus[..., None] * vq
             + jnp.einsum("btnh,bnhv->btnv", r_dec, Scur))
        k_tail = kq * jnp.exp(l[:, -1:] - l)       # ratios <= 1
        S_new = jnp.exp(l[:, -1])[..., None] * Scur + jnp.einsum(
            "btnh,btnv->bnhv", k_tail, vq)
        return S_new, y

    S_fin, y = jax.lax.scan(body, S0, (rc, kc, vc, wc))
    y = y.swapaxes(0, 1).reshape(B, S, D)
    from repro.models.layers import layer_norm
    y = layer_norm(y, p["ln_x_w"], p["ln_x_b"], eps=1e-5)
    out = linear(y.astype(xn.dtype) * g, p["wo"])
    return out, (S_fin, shift_out)


def rwkv6_time_mix_step(cfg: ModelConfig, p: dict, xn: jax.Array,
                        Scur: jax.Array, shift_prev: jax.Array):
    """Single token. xn [B,1,D]; Scur [B,nh,hd,hd]; shift_prev [B,1,D]."""
    nh, hd = rwkv6_dims(cfg)
    B = xn.shape[0]
    r, k, v, g, logw, shift_out = _rwkv_time_inputs(cfg, p, xn, shift_prev)
    rq, kq, vq, lw = r[:, 0], k[:, 0], v[:, 0], logw[:, 0]   # [B,nh,hd]
    bonus = jnp.einsum("bnh,bnh->bn", rq, p["u"][None] * kq)
    y = (jnp.einsum("bnh,bnhv->bnv", rq, Scur) + bonus[..., None] * vq)
    S_new = jnp.exp(lw)[..., None] * Scur + kq[..., None] * vq[..., None, :]
    y = y.reshape(B, 1, cfg.d_model)
    from repro.models.layers import layer_norm
    y = layer_norm(y, p["ln_x_w"], p["ln_x_b"], eps=1e-5)
    out = linear(y.astype(xn.dtype) * g, p["wo"])
    return out, (S_new, shift_out)


def rwkv6_channel_mix(cfg: ModelConfig, p: dict, xn: jax.Array,
                      shift_prev: Optional[jax.Array] = None):
    """xn [B,S,D] (post-ln2). Returns (out, shift_state)."""
    xp = _shift(xn, shift_prev)
    xk = xn + (xp - xn) * p["mu_ck"]
    xr = xn + (xp - xn) * p["mu_cr"]
    h = jnp.square(jax.nn.relu(xk @ p["ck"]))
    return jax.nn.sigmoid(xr @ p["cr"]) * (h @ p["cv"]), xn[:, -1:]
