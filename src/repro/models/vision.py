"""Scaled-down versions of the paper's workloads (VGG / ResNet / YOLO / FCN)
for the scenario benchmarks (Figs. 11-13). Pure JAX conv nets described as
layer lists so they slot straight into the SwapNet unit/partition machinery.

Scaled ~20x from the paper's sizes (CPU container) but keeping the structural
traits the paper leans on: VGG's huge unbalanced fc layer, ResNet's many thin
layers, conv-only YOLO/FCN.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qtensor import materialize
from repro.models.layers import linear


@dataclass(frozen=True)
class Layer:
    kind: str                 # conv | res | pool | gap | fc
    cin: int = 0
    cout: int = 0
    k: int = 3
    stride: int = 1


def vgg_sim() -> Tuple[str, List[Layer], int]:
    """VGG-ish: conv stack + dominant fc (the paper's 'largest layer 392MB')."""
    chans = [(3, 32), (32, 64), (64, 128), (128, 128), (128, 256), (256, 256)]
    layers = []
    for i, (a, b) in enumerate(chans):
        layers.append(Layer("conv", a, b, 3, 1))
        if i % 2 == 1:
            layers.append(Layer("pool"))
    layers.append(Layer("gap"))
    layers += [Layer("fc", 256, 4096), Layer("fc", 4096, 1024),
               Layer("fc", 1024, 100)]
    return "vgg_sim", layers, 32


def resnet_sim(depth: int = 34) -> Tuple[str, List[Layer], int]:
    """ResNet-ish: many thin residual layers (hard to partition, paper §6.2)."""
    layers = [Layer("conv", 3, 32, 3, 1)]
    c = 32
    for stage, blocks in enumerate([3, 4, 6, 3][:max(2, depth // 10)]):
        for b in range(blocks):
            layers.append(Layer("res", c, c, 3, 1))
        if stage < 3:
            layers.append(Layer("conv", c, c * 2, 3, 2))
            c *= 2
    layers += [Layer("gap"), Layer("fc", c, 100)]
    return "resnet_sim", layers, 32


def yolo_sim() -> Tuple[str, List[Layer], int]:
    layers = [Layer("conv", 3, 32, 3, 1)]
    c = 32
    for _ in range(4):
        layers.append(Layer("conv", c, c * 2, 3, 2))
        layers.append(Layer("res", c * 2, c * 2, 3, 1))
        c *= 2
    layers.append(Layer("conv", c, 255, 1, 1))      # detection head
    return "yolo_sim", layers, 64

def fcn_sim() -> Tuple[str, List[Layer], int]:
    layers = []
    c = 3
    for nc in (32, 64, 128):
        layers.append(Layer("conv", c, nc, 3, 2))
        c = nc
    for nc in (128, 64):
        layers.append(Layer("conv", c, nc, 3, 1))
        c = nc
    layers.append(Layer("conv", c, 21, 1, 1))       # seg classes
    return "fcn_sim", layers, 64


MODELS: Dict[str, Callable] = {"vgg": vgg_sim, "resnet": resnet_sim,
                               "yolo": yolo_sim, "fcn": fcn_sim}


# ------------------------------------------------------------------ init/apply
def init_layer(l: Layer, key) -> dict:
    if l.kind in ("conv", "res"):
        w = jax.random.normal(key, (l.k, l.k, l.cin, l.cout)) \
            * (l.k * l.k * l.cin) ** -0.5
        return {"w": w, "b": jnp.zeros((l.cout,))}
    if l.kind == "fc":
        w = jax.random.normal(key, (l.cin, l.cout)) * l.cin ** -0.5
        return {"w": w, "b": jnp.zeros((l.cout,))}
    return {}


def init_convnet(layers: Sequence[Layer], key) -> List[dict]:
    return [init_layer(l, jax.random.fold_in(key, i))
            for i, l in enumerate(layers)]


def _conv(x, w, b, stride):
    # conv weights are HWIO einsum-style consumers: quantized-resident
    # units dequantize on device at use (the fused path covers fc below)
    y = jax.lax.conv_general_dilated(
        x, materialize(w), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def apply_layer(l: Layer, p: dict, x: jax.Array) -> jax.Array:
    if l.kind == "conv":
        return jax.nn.relu(_conv(x, p["w"], p["b"], l.stride))
    if l.kind == "res":
        return jax.nn.relu(x + _conv(x, p["w"], p["b"], 1))
    if l.kind == "pool":
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    if l.kind == "gap":
        return jnp.mean(x, axis=(1, 2))
    if l.kind == "fc":
        return linear(x, p["w"], p["b"])
    raise ValueError(l.kind)


def apply_convnet(layers, params, x):
    for l, p in zip(layers, params):
        x = apply_layer(l, p, x)
    return x


def layer_flops_conv(l: Layer, hw: int, batch: int) -> float:
    if l.kind in ("conv", "res"):
        out_hw = hw // l.stride
        return 2.0 * batch * out_hw * out_hw * l.k * l.k * l.cin * l.cout
    if l.kind == "fc":
        return 2.0 * batch * l.cin * l.cout
    return 1.0 * batch * hw * hw


def trace_hw(layers: Sequence[Layer], hw: int) -> List[int]:
    """Input spatial size seen by each layer."""
    out, cur = [], hw
    for l in layers:
        out.append(cur)
        if l.kind == "pool" or (l.kind == "conv" and l.stride == 2):
            cur = cur // 2
        if l.kind == "gap":
            cur = 1
    return out


# ------------------------------------------------------------------ baselines
def prune_convnet(layers: Sequence[Layer], params: List[dict],
                  keep_frac: float) -> Tuple[List[Layer], List[dict]]:
    """Torch-Pruning-style structured magnitude pruning: keep the top
    ``keep_frac`` output channels by L2 norm (lossy — the paper's TPrg arm)."""
    new_layers, new_params = [], []
    kept_prev: Optional[np.ndarray] = None
    for l, p in zip(layers, params):
        if l.kind == "conv":
            w = np.asarray(p["w"])
            if kept_prev is not None:
                w = w[:, :, kept_prev, :]
            norms = np.linalg.norm(w.reshape(-1, w.shape[-1]), axis=0)
            k = max(1, int(round(l.cout * keep_frac)))
            keep = np.sort(np.argsort(norms)[-k:])
            new_layers.append(dataclasses.replace(
                l, cin=w.shape[2], cout=k))
            new_params.append({"w": jnp.asarray(w[..., keep]),
                               "b": jnp.asarray(np.asarray(p["b"])[keep])})
            kept_prev = keep
        elif l.kind == "res":
            w = np.asarray(p["w"])
            if kept_prev is not None:
                w = w[:, :, kept_prev, :][..., kept_prev]
            c = w.shape[2]
            new_layers.append(dataclasses.replace(l, cin=c, cout=c))
            new_params.append({"w": jnp.asarray(w),
                               "b": jnp.asarray(np.asarray(p["b"])[kept_prev])
                               if kept_prev is not None else p["b"]})
        elif l.kind == "fc":
            w = np.asarray(p["w"])
            if kept_prev is not None:          # first fc after gap: slice cin
                w = w[kept_prev, :]
                kept_prev = None
            new_layers.append(dataclasses.replace(l, cin=w.shape[0]))
            new_params.append({"w": jnp.asarray(w), "b": p["b"]})
        else:
            new_layers.append(l)
            new_params.append(p)
    return new_layers, new_params


def apply_convnet_channel_split(layers, params, x, groups: int = 4):
    """DCha baseline: convolution output channels computed in ``groups``
    sequential slices (1/groups weight memory at a time, combine overhead)."""
    for l, p in zip(layers, params):
        if l.kind == "conv" and l.cout >= groups:
            outs = []
            step = l.cout // groups
            for g in range(groups):
                sl = slice(g * step, (g + 1) * step if g < groups - 1 else l.cout)
                outs.append(_conv(x, p["w"][..., sl], p["b"][sl], l.stride))
            x = jax.nn.relu(jnp.concatenate(outs, axis=-1))
        else:
            x = apply_layer(l, p, x)
    return x
