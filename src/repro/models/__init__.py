from repro.models.transformer import Model
