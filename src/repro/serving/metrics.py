"""Serving metrics registry: one snapshot surface over what already exists.

The runtime and scheduler already track everything an operator needs —
ledger residency/peak, cache hits, per-class latencies, preemptions,
faults/retries, KV-page occupancy — but each lives on a different object
and was only reachable from inside the process. :class:`MetricsRegistry`
SNAPSHOTS those internal counters on demand (it owns no counters of its
own, so the numbers can never drift from what the scheduler reports) and
renders them in two forms:

  * :meth:`snapshot` — a plain nested dict (the control plane's JSON
    surface, the fleet bench's scrape target);
  * :meth:`render_prometheus` — Prometheus text exposition format v0.0.4
    (``# HELP``/``# TYPE`` + samples), served at ``GET /metrics``.

Stdlib only. Latency quantiles use the same ``numpy.percentile`` the
benches and ``serve.py`` report, over ``ServingScheduler.latency_by_class``
— so a scrape and the in-process report agree EXACTLY on the same data.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["MetricsRegistry", "render_prometheus"]

# (metric name, help text, type) — the registry's stable contract; the
# docs-drift checker verifies the documented names against this list.
METRIC_FAMILIES: List[Tuple[str, str, str]] = [
    ("swapnet_ledger_budget_bytes", "Configured memory budget", "gauge"),
    ("swapnet_ledger_resident_bytes", "Bytes currently charged to the "
     "shared ledger", "gauge"),
    ("swapnet_ledger_peak_bytes", "High-water mark of ledger residency",
     "gauge"),
    ("swapnet_ledger_occupancy", "resident/budget (0..1)", "gauge"),
    ("swapnet_cache_capacity_bytes", "Shared block-cache capacity", "gauge"),
    ("swapnet_cache_resident_bytes", "Bytes resident in the block cache",
     "gauge"),
    ("swapnet_cache_hits_total", "Block-cache hits", "counter"),
    ("swapnet_cache_misses_total", "Block-cache misses", "counter"),
    ("swapnet_cache_hit_rate", "hits/(hits+misses) (0..1)", "gauge"),
    ("swapnet_requests_completed_total", "Completed requests by priority "
     "class", "counter"),
    ("swapnet_request_latency_seconds", "Completed-request latency "
     "quantiles by priority class", "gauge"),
    ("swapnet_queue_depth", "Requests waiting in the admission queue",
     "gauge"),
    ("swapnet_preemptions_total", "Block/step-boundary preemptions",
     "counter"),
    ("swapnet_requests_shed_total", "Requests shed past their deadline",
     "counter"),
    ("swapnet_requests_failed_fast_total", "Requests failed by a tripped "
     "per-model breaker", "counter"),
    ("swapnet_model_up", "1 = serving, 0 = circuit breaker tripped",
     "gauge"),
    ("swapnet_swap_retries_total", "Loader read retries by model",
     "counter"),
    ("swapnet_swap_faults_total", "Swap faults by model and taxonomy class",
     "counter"),
    ("swapnet_model_bytes_swapped_total", "Storage->host bytes streamed by "
     "model", "counter"),
    ("swapnet_model_overlap_efficiency", "Fraction of swap-in hidden "
     "behind compute", "gauge"),
    ("swapnet_kv_pages_in_use", "KV pages currently allocated by model",
     "gauge"),
    ("swapnet_kv_pages_capacity", "KV page-pool capacity by model", "gauge"),
    ("swapnet_kv_page_occupancy", "in_use/capacity (0..1) by model",
     "gauge"),
    ("swapnet_http_requests_total", "Control-plane HTTP requests by "
     "endpoint", "counter"),
]

_HELP = {name: (help_, type_) for name, help_, type_ in METRIC_FAMILIES}


def _fmt_labels(labels: Dict[str, object]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(value: float) -> str:
    """Integers render bare; floats keep ROUND-TRIP precision (``repr``,
    not ``%g`` — a scrape must equal the in-process number exactly, and
    ``%g`` silently truncates to 6 significant digits)."""
    value = float(value)
    if value == int(value):
        return str(int(value))
    return repr(value)


def render_prometheus(samples: List[Tuple[str, Dict, float]]) -> str:
    """Render ``(name, labels, value)`` samples as Prometheus text,
    grouping samples under one HELP/TYPE header per family."""
    by_family: Dict[str, List[Tuple[Dict, float]]] = {}
    order: List[str] = []
    for name, labels, value in samples:
        if name not in by_family:
            by_family[name] = []
            order.append(name)
        by_family[name].append((labels, value))
    lines: List[str] = []
    for name in order:
        help_, type_ = _HELP.get(name, ("", "gauge"))
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {type_}")
        for labels, value in by_family[name]:
            lines.append(f"{name}{_fmt_labels(labels)} {_fmt_value(value)}")
    return "\n".join(lines) + "\n"


class MetricsRegistry:
    """Snapshot view over a runtime + scheduler (+ control-plane counters).

    Attach whatever exists — every source is optional, and a missing one
    simply contributes no samples (the registry works for a bare runtime
    without a scheduler, and for tests that fake either)."""

    def __init__(self, runtime=None, scheduler=None):
        self.runtime = runtime
        self.scheduler = scheduler
        self.http_requests: Dict[str, int] = {}   # endpoint -> count

    # ------------------------------------------------------------- sources
    def attach(self, runtime=None, scheduler=None) -> "MetricsRegistry":
        if runtime is not None:
            self.runtime = runtime
        if scheduler is not None:
            self.scheduler = scheduler
        return self

    def count_http(self, endpoint: str) -> None:
        self.http_requests[endpoint] = self.http_requests.get(endpoint, 0) + 1

    # ------------------------------------------------------------ snapshot
    def latency_quantiles(self) -> Dict[float, Dict[str, float]]:
        """Per-priority-class p50/p99 (seconds) over completed requests —
        ``np.percentile`` over ``ServingScheduler.latency_by_class``, the
        exact computation ``serve.py`` and the benches print."""
        if self.scheduler is None:
            return {}
        out: Dict[float, Dict[str, float]] = {}
        for prio, lats in self.scheduler.latency_by_class().items():
            arr = np.asarray(lats, float)
            out[prio] = {
                "n": len(lats),
                "p50_s": float(np.percentile(arr, 50)) if lats else 0.0,
                "p99_s": float(np.percentile(arr, 99)) if lats else 0.0,
            }
        return out

    def collect(self) -> List[Tuple[str, Dict, float]]:
        """Live ``(name, labels, value)`` samples from every source."""
        samples: List[Tuple[str, Dict, float]] = []
        rt = self.runtime
        if rt is not None:
            ledger = rt.ledger
            budget = float(ledger.budget or 0)
            resident = float(ledger.resident)
            samples += [
                ("swapnet_ledger_budget_bytes", {}, budget),
                ("swapnet_ledger_resident_bytes", {}, resident),
                ("swapnet_ledger_peak_bytes", {}, float(ledger.peak)),
                ("swapnet_ledger_occupancy", {},
                 resident / budget if budget else 0.0),
                ("swapnet_cache_capacity_bytes", {},
                 float(rt.cache.capacity)),
                ("swapnet_cache_resident_bytes", {},
                 float(rt.cache.resident_bytes)),
                ("swapnet_cache_hits_total", {}, float(rt.cache.hits)),
                ("swapnet_cache_misses_total", {}, float(rt.cache.misses)),
                ("swapnet_cache_hit_rate", {}, float(rt.cache.hit_rate())),
            ]
            for name, sm in rt.models.items():
                st = sm.engine.stats
                labels = {"model": name}
                samples += [
                    ("swapnet_swap_retries_total", labels, float(st.retries)),
                    ("swapnet_model_bytes_swapped_total", labels,
                     float(st.bytes_swapped)),
                    ("swapnet_model_overlap_efficiency", labels,
                     float(st.overlap_efficiency())),
                ]
                for kind, n in sorted(st.faults.items()):
                    samples.append(("swapnet_swap_faults_total",
                                    {"model": name, "kind": kind}, float(n)))
            for name, engine in getattr(rt, "_batch_engines", {}).items():
                kv = engine.kv
                labels = {"model": name}
                samples += [
                    ("swapnet_kv_pages_in_use", labels,
                     float(kv.pages_in_use)),
                    ("swapnet_kv_pages_capacity", labels,
                     float(kv.max_pages)),
                    ("swapnet_kv_page_occupancy", labels,
                     float(kv.pages_in_use) / max(kv.max_pages, 1)),
                ]
        sched = self.scheduler
        if sched is not None:
            samples += [
                ("swapnet_queue_depth", {}, float(len(sched.queue))),
                ("swapnet_preemptions_total", {}, float(sched.preemptions)),
                ("swapnet_requests_shed_total", {}, float(sched.shed)),
                ("swapnet_requests_failed_fast_total", {},
                 float(sched.failed_fast)),
            ]
            for prio, q in sorted(self.latency_quantiles().items()):
                cls = {"priority": f"{prio:g}"}
                samples.append(("swapnet_requests_completed_total",
                                cls, float(q["n"])))
                for quant, key in (("0.5", "p50_s"), ("0.99", "p99_s")):
                    samples.append(("swapnet_request_latency_seconds",
                                    {**cls, "quantile": quant}, q[key]))
            if rt is not None:
                for name in rt.models:
                    samples.append(
                        ("swapnet_model_up", {"model": name},
                         0.0 if sched.model_down(name) is not None else 1.0))
        for endpoint, n in sorted(self.http_requests.items()):
            samples.append(("swapnet_http_requests_total",
                            {"endpoint": endpoint}, float(n)))
        return samples

    def snapshot(self) -> Dict:
        """Nested-dict view (the control plane's JSON status surface)."""
        out: Dict = {}
        for name, labels, value in self.collect():
            if labels:
                key = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
                out.setdefault(name, {})[key] = value
            else:
                out[name] = value
        return out

    def render_prometheus(self) -> str:
        return render_prometheus(self.collect())
