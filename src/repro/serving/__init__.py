from repro.serving.batch_engine import BatchDecodeEngine, StepTrace
from repro.serving.control_plane import ENDPOINTS, ControlPlane
from repro.serving.engine import (MultiModelServingEngine, Request,
                                  ServingEngine, pad_prompts)
from repro.serving.kv_cache import gather_cache_rows, pad_prefill_cache
from repro.serving.metrics import (METRIC_FAMILIES, MetricsRegistry,
                                   render_prometheus)
from repro.serving.paged_kv import (PagedBatchView, PagedKVCache,
                                    page_bytes_for)
