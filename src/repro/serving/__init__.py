from repro.serving.engine import (MultiModelServingEngine, Request,
                                  ServingEngine, pad_prompts)
