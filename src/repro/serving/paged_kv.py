"""Paged KV cache: fixed-size token pages charged to the shared MemoryLedger.

The SwapNet idea applied to the KV cache (the PIE/vLLM page-table layout):
instead of one contiguous [B, max_len, KV, hd] allocation per batch slot —
whose padding makes batch size a compile-time memory decision — K/V live in
a shared pool of PAGES of ``page_tokens`` tokens each, and every sequence
owns an ordered page list. A page spans ALL layers (one alloc decision per
``page_tokens`` of context, like PIE's NUM_TOKENS_IN_BLOCK blocks), so

    page_bytes = 2 (K+V) * n_layers * page_tokens * KV * hd * itemsize.

Pages are charged to the same :class:`~repro.core.swap_engine.MemoryLedger`
as weight blocks, under one per-sequence key whose value is re-charged with
delta semantics as the sequence grows — KV pages and weight-block residency
compete under ONE budget, so the planner genuinely trades cache-resident
layers against decode batch size. ``alloc``/``extend`` NEVER block and never
partially commit: a rejection (pool exhausted or ledger over budget) leaves
both the free list and the ledger untouched, and the batch engine answers it
with preemption-by-recomputation (free the youngest sequence's pages,
requeue it; greedy decode recomputes bit-identically).

Pools are host numpy buffers mutated in place (the decode loop is eager, one
host->device upload per layer per batched step); the pool capacity is
preallocated but the ledger only carries LOGICALLY allocated pages, mirroring
how the weight ledger carries resident blocks, not the store file.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.swap_engine import MemoryLedger
from repro.kernels import ops

__all__ = ["PagedKVCache", "PagedBatchView", "page_bytes_for"]


def page_bytes_for(cfg: ModelConfig, page_tokens: int) -> int:
    """Ledger cost of one page: K+V for every layer's slice of the page."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    return (2 * cfg.n_layers * page_tokens
            * cfg.n_kv_heads * cfg.resolved_head_dim * itemsize)


class PagedKVCache:
    """Page-table KV cache for one model, accounted on a shared ledger.

    Thread-safe: the batch engine allocates/frees from its driver thread
    while the scheduler admits new sequences from executor threads.
    """

    def __init__(self, cfg: ModelConfig, ledger: MemoryLedger, *,
                 page_tokens: int = 16, max_pages: int = 64,
                 name: str = "kv"):
        if cfg.mla is not None or any(
                k not in ("dense", "moe") for k in cfg.layer_kinds()):
            raise ValueError(
                f"{cfg.name}: paged KV serving covers uniform GQA/MHA "
                f"attention stacks (MLA and SSM/shift state layers keep the "
                f"contiguous legacy path)")
        self.cfg = cfg
        self.ledger = ledger
        self.page_tokens = int(page_tokens)
        self.max_pages = int(max_pages)
        self.name = name
        self.page_bytes = page_bytes_for(cfg, self.page_tokens)
        KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        # page 0 is a permanently-zero SENTINEL: page tables are padded with
        # it past a sequence's pages, so the kernel's gather always lands on
        # a real (masked) page
        shape = (self.max_pages + 1, self.page_tokens, KV, hd)
        self.k_pools = [np.zeros(shape, dt) for _ in range(cfg.n_layers)]
        self.v_pools = [np.zeros(shape, dt) for _ in range(cfg.n_layers)]
        self._free: List[int] = list(range(self.max_pages, 0, -1))
        self._pages: Dict[object, List[int]] = {}
        self._len: Dict[object, int] = {}
        self._lock = threading.Lock()
        self._dirty = [True] * cfg.n_layers
        self._dev: List[Optional[Tuple]] = [None] * cfg.n_layers

    @classmethod
    def for_budget(cls, cfg: ModelConfig, ledger: MemoryLedger,
                   kv_bytes: int, *, page_tokens: int = 16,
                   name: str = "kv") -> "PagedKVCache":
        """Size the pool so its pages exactly fill ``kv_bytes`` when all
        allocated (the ledger still arbitrates: weight blocks can squeeze
        the usable page count below capacity at runtime)."""
        pb = page_bytes_for(cfg, page_tokens)
        max_pages = max(int(kv_bytes) // pb, 1)
        return cls(cfg, ledger, page_tokens=page_tokens, max_pages=max_pages,
                   name=name)

    # ------------------------------------------------------------ pages
    def _pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_tokens)

    def _key(self, seq_id) -> tuple:
        return ("kv", self.name, seq_id)

    def alloc(self, seq_id, n_tokens: int) -> bool:
        """Admit a new sequence with ``n_tokens`` of context. False (and no
        state change) if the pool or the ledger cannot take its pages."""
        need = self._pages_for(n_tokens)
        with self._lock:
            assert seq_id not in self._pages, f"sequence {seq_id!r} is live"
            if need > len(self._free):
                return False
            if not self.ledger.try_add(self._key(seq_id),
                                       need * self.page_bytes):
                return False
            self._pages[seq_id] = [self._free.pop() for _ in range(need)]
            self._len[seq_id] = n_tokens
        return True

    def extend(self, seq_id, n_new: int = 1) -> bool:
        """Grow a sequence by ``n_new`` tokens, taking a page at each
        boundary crossing (ledger re-charged with delta semantics). False
        leaves the sequence exactly as it was."""
        with self._lock:
            pages = self._pages[seq_id]
            new_len = self._len[seq_id] + n_new
            need = self._pages_for(new_len) - len(pages)
            if need > 0:
                if need > len(self._free):
                    return False
                if not self.ledger.try_add(
                        self._key(seq_id),
                        (len(pages) + need) * self.page_bytes):
                    return False
                pages.extend(self._free.pop() for _ in range(need))
            self._len[seq_id] = new_len
        return True

    def free(self, seq_id) -> None:
        """Retire a sequence: pages to the free list, ledger released."""
        with self._lock:
            pages = self._pages.pop(seq_id, None)
            if pages is None:
                return
            del self._len[seq_id]
            self._free.extend(reversed(pages))
            self.ledger.drop(self._key(seq_id))

    def seq_len(self, seq_id) -> int:
        with self._lock:
            return self._len[seq_id]

    # ------------------------------------------------------------ tokens
    def write(self, seq_id, layer: int, start: int, k: np.ndarray,
              v: np.ndarray) -> None:
        """Scatter ``k``/``v`` [S, KV, hd] into the sequence's pages at token
        positions ``start .. start+S`` (positions must be allocated)."""
        with self._lock:
            pages = self._pages[seq_id]
            assert start + k.shape[0] <= self._len[seq_id], \
                (start, k.shape, self._len[seq_id])
        T = self.page_tokens
        kp, vp = self.k_pools[layer], self.v_pools[layer]
        t = 0
        while t < k.shape[0]:
            pos = start + t
            pid = pages[pos // T]
            slot = pos % T
            n = min(T - slot, k.shape[0] - t)
            kp[pid, slot:slot + n] = k[t:t + n]
            vp[pid, slot:slot + n] = v[t:t + n]
            t += n
        self._dirty[layer] = True

    def last_slots(self, seq_ids: Sequence) -> Tuple[np.ndarray, np.ndarray]:
        """(page_ids [B], slots [B]) addressing each sequence's LAST token —
        the decode-step write position, computed once and reused by every
        layer's batched scatter (``write_rows``)."""
        T = self.page_tokens
        with self._lock:
            pos = [self._len[s] - 1 for s in seq_ids]
            pids = [self._pages[s][p // T] for s, p in zip(seq_ids, pos)]
        return (np.asarray(pids, np.int32),
                np.asarray([p % T for p in pos], np.int32))

    def write_rows(self, layer: int, pids: np.ndarray, slots: np.ndarray,
                   k: np.ndarray, v: np.ndarray) -> None:
        """Scatter one token per sequence ([B, KV, hd]) into pool rows
        addressed by ``last_slots`` — the vectorized decode-step write (one
        fancy-index assignment instead of B ``write`` calls per layer)."""
        self.k_pools[layer][pids, slots] = k
        self.v_pools[layer][pids, slots] = v
        self._dirty[layer] = True

    # ------------------------------------------------------------ views
    def page_table(self, seq_ids: Sequence) -> Tuple[np.ndarray, np.ndarray]:
        """(page_table [B, NP] int32 padded with the zero page, seq_lens [B]
        int32) for a batch of live sequences."""
        with self._lock:
            lists = [self._pages[s] for s in seq_ids]
            lens = [self._len[s] for s in seq_ids]
        NP = max((len(p) for p in lists), default=1) or 1
        pt = np.zeros((len(lists), NP), np.int32)
        for i, p in enumerate(lists):
            pt[i, :len(p)] = p
        return pt, np.asarray(lens, np.int32)

    def device_pools(self, layer: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """The layer's page pools as device arrays (re-uploaded only after a
        host-side write dirtied the layer)."""
        if self._dirty[layer] or self._dev[layer] is None:
            self._dev[layer] = (jnp.asarray(self.k_pools[layer]),
                                jnp.asarray(self.v_pools[layer]))
            self._dirty[layer] = False
        return self._dev[layer]

    # ------------------------------------------------------------ stats
    @property
    def pages_in_use(self) -> int:
        with self._lock:
            return sum(len(p) for p in self._pages.values())

    @property
    def bytes_in_use(self) -> int:
        return self.pages_in_use * self.page_bytes

    def occupancy(self) -> float:
        return self.pages_in_use / max(self.max_pages, 1)

    def live_sequences(self) -> List:
        with self._lock:
            return list(self._pages)


class _LayerBoundView:
    """``PagedBatchView`` narrowed to one layer — the ``paged`` hook
    ``models.transformer.apply_layer`` hands to ``gqa_apply_paged``."""

    __slots__ = ("_view", "_layer")

    def __init__(self, view: "PagedBatchView", layer: int):
        self._view = view
        self._layer = layer

    def attend(self, q, k_new, v_new, **kw):
        return self._view.attend(self._layer, q, k_new, v_new, **kw)


class PagedBatchView:
    """One decode step's batch, frozen as a page-table snapshot.

    The batch engine extends every active sequence by one token FIRST, then
    builds the view: ``seq_lens`` already counts the token being decoded, so
    each layer's new K/V lands at position ``seq_lens[i] - 1`` and the
    kernel's causal mask (`q_pos = seq_len - 1`) covers exactly the live
    context. The (page_table, seq_lens) device arrays are uploaded once and
    shared by all layers of the step.
    """

    def __init__(self, kv: PagedKVCache, seq_ids: Sequence):
        self.kv = kv
        self.seq_ids = list(seq_ids)
        pt, sl = kv.page_table(self.seq_ids)
        self._host_lens = sl
        # every layer writes the SAME (page, slot) per sequence this step —
        # resolve the addressing once, scatter per layer
        self._w_pids, self._w_slots = kv.last_slots(self.seq_ids)
        self.page_table = jnp.asarray(pt)
        self.seq_lens = jnp.asarray(sl)

    def attend(self, layer: int, q, k_new, v_new, *, scale=None,
               window: Optional[int] = None,
               softcap: Optional[float] = None):
        """Append this layer's new K/V ([B, KV, hd]) to each sequence's
        pages, then attend q ([B, H, hd]) through the page table."""
        self.kv.write_rows(layer, self._w_pids, self._w_slots,
                           np.asarray(k_new), np.asarray(v_new))
        kp, vp = self.kv.device_pools(layer)
        return ops.paged_attention(q, kp, vp, self.page_table, self.seq_lens,
                                   scale=scale, window=window, softcap=softcap)

    def bind(self, layer: int) -> _LayerBoundView:
        return _LayerBoundView(self, layer)
