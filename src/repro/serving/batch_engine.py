"""Continuous-batching decode over the paged KV cache (tentpole of the
serving growth arc; the vLLM/Orca iteration-level scheduling idea composed
with SwapNet weight streaming).

The per-request decode paths (``SwappedModel.decode_loop``, the scheduler's
prefill-only requests) pay the model's full swap-in cost PER SEQUENCE per
token. Here the unit of work is one BATCHED decode step
(:meth:`~repro.core.runtime.SwappedModel.decode_step_paged`): weight blocks
stream through the memory window once and their cost amortizes over every
active sequence, so decode throughput scales with batch size while the
resident set stays one-or-two blocks + the KV page pool.

Batch membership is re-decided EVERY step (continuous batching):

  * admission — pending requests join whenever a batch slot and their KV
    pages are available; a request's prompt is prefilled through the swapped
    pipeline (``forward_partial(collect_cache=True)``) and its K/V seeded
    into the page pool, and the prefill argmax is its first emitted token;
  * retirement — a sequence leaves the instant it hits its own
    ``max_new_tokens`` or EOS (no padding to the batch's longest request),
    returning its pages to the pool mid-flight;
  * preemption-by-recomputation — when the pool or the shared ledger cannot
    grow a sequence (weight blocks and KV pages compete under ONE budget),
    the lowest-priority / youngest sequences are evicted: their pages are
    freed and the request re-queued carrying (prompt, output). Greedy decode
    is deterministic, so re-admission prefills prompt+output and continues
    bit-identically — no snapshot state beyond the token lists.

``run_until`` is the scheduler-facing drive loop: a scheduler driver steps
the WHOLE batch until its own sequence retires, yielding to higher-priority
work only at decode-step boundaries (the decode analogue of block-boundary
preemption for prefill passes).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.runtime import SwappedModel
from repro.errors import SwapError
from repro.serving.engine import Request
from repro.serving.paged_kv import PagedBatchView, PagedKVCache

__all__ = ["BatchDecodeEngine", "StepTrace"]


@dataclass
class StepTrace:
    """What one engine step did — the serving log the tests assert on."""
    step: int
    batch: List[int]                 # rids decoded this step
    admitted: List[int]              # rids admitted (prefilled) this step
    retired: List[int]               # rids retired this step
    preempted: List[int]             # rids evicted (recompute later)
    kv_pages: int                    # pool pages in use after the step
    occupancy: float                 # len(batch) / max_batch
    failed: List[int] = field(default_factory=list)   # rids evicted on an
    #                                  unrecoverable swap failure (not retry)


@dataclass
class _Active:
    req: Request
    admit_step: int

    def sort_key(self, rid_order):
        # eviction victims come from the BACK of this order: lowest
        # priority first, then youngest admission
        return (-self.req.priority, self.admit_step, rid_order)


class BatchDecodeEngine:
    """Swap-aware continuous-batching decode for ONE model.

    ``sm`` must be partitioned; ``kv`` must be built on the same ledger as
    ``sm.engine`` for the weights-vs-KV budget arbitration to mean anything
    (``PagedKVCache.for_budget(cfg, sm.engine.ledger, ...)``).
    """

    def __init__(self, sm: SwappedModel, kv: PagedKVCache, *,
                 max_batch: int = 8):
        self.sm = sm
        self.kv = kv
        self.max_batch = int(max_batch)
        self.trace: List[StepTrace] = []
        self.tokens_emitted = 0
        self.preemptions = 0
        self.failures = 0            # sequences evicted on swap failure
        self.decode_s = 0.0          # wall time inside batched decode steps
        self.prefill_s = 0.0
        self._pending: deque = deque()
        self._active: List[_Active] = []
        self._done: set = set()
        self._known: set = set()
        self._on_retire: Dict[int, Optional[Callable]] = {}
        self._step_no = 0
        self._lock = threading.Lock()        # pending / done / callbacks
        self._drive = threading.Lock()       # one step() at a time

    # ------------------------------------------------------------ intake
    def submit(self, req: Request,
               on_retire: Optional[Callable[[Request], None]] = None) -> None:
        with self._lock:
            assert req.rid not in self._known, f"rid {req.rid} already known"
            self._known.add(req.rid)
            self._on_retire[req.rid] = on_retire
            self._pending.append(req)

    def cancel(self, rid: int) -> bool:
        """Un-submit a still-PENDING request (its retire callback never
        fires; the caller owns completion signalling). False once the
        request was admitted — an active sequence holds KV pages and a
        batch slot that must unwind through retire/evict, not removal."""
        with self._lock:
            for i, req in enumerate(self._pending):
                if req.rid == rid:
                    del self._pending[i]
                    self._known.discard(rid)
                    self._on_retire.pop(rid, None)
                    return True
        return False

    def is_done(self, rid: int) -> bool:
        with self._lock:
            return rid in self._done

    # ------------------------------------------------------------ helpers
    def _emit(self, req: Request, tok: int) -> bool:
        """Record one generated token; True when the request just finished."""
        req.output.append(tok)
        self.tokens_emitted += 1
        if req.eos is not None and tok == req.eos:
            return True
        return len(req.output) >= req.max_new_tokens

    def _retire(self, req: Request) -> None:
        self.kv.free(req.rid)
        with self._lock:
            self._done.add(req.rid)
            cb = self._on_retire.pop(req.rid, None)
        if cb is not None:
            cb(req)

    def _prefill(self, req: Request) -> int:
        """Swapped prefill over prompt + already-emitted output (recompute
        path), K/V seeded into the page pool. Returns the argmax token —
        ALWAYS a new token: an un-preempted request prefills just its
        prompt; a recomputed one replays its emitted tokens teacher-forced,
        so the last position is one past what it had emitted."""
        tokens = list(req.prompt) + list(req.output)
        batch = {"tokens": jnp.asarray([tokens], jnp.int32)}
        state, stats = self.sm.forward_partial(batch, collect_cache=True)
        assert stats is not None
        for lid, c in state.caches.items():
            self.kv.write(req.rid, lid, 0,
                          np.asarray(c["k"][0]), np.asarray(c["v"][0]))
        return int(np.argmax(np.asarray(state.logits)[0, -1]))

    # ------------------------------------------------------------ stepping
    def step(self) -> Optional[StepTrace]:
        """One continuous-batching iteration: admit, (maybe) preempt, decode
        one token for every active sequence, retire finishers. Returns the
        step's trace, or None when there was nothing at all to do."""
        with self._drive:
            return self._step_locked()

    def _step_locked(self) -> Optional[StepTrace]:
        admitted: List[int] = []
        retired: List[int] = []
        preempted: List[int] = []
        failed: List[int] = []

        # -- admission: fill free batch slots while pages are available
        while len(self._active) < self.max_batch:
            with self._lock:
                if not self._pending:
                    break
                req = self._pending.popleft()
            n_ctx = len(req.prompt) + len(req.output)
            if not self.kv.alloc(req.rid, n_ctx):
                with self._lock:
                    self._pending.appendleft(req)
                if not self._active and self.kv.pages_in_use == 0:
                    raise MemoryError(
                        f"request {req.rid}: {n_ctx}-token context needs "
                        f"more KV pages than the budget ever provides "
                        f"({self.kv.max_pages} x {self.kv.page_tokens} tok)")
                break
            t0 = time.perf_counter()
            try:
                tok = self._prefill(req)
            except SwapError as e:
                # unrecoverable prefill failure (the loader's retries are
                # already spent): evict THIS sequence — free its KV pages,
                # surface the error through its own retire callback — and
                # keep admitting; one broken request must not poison the
                # batch or leak pool pages.
                self.prefill_s += time.perf_counter() - t0
                if e.model is None:
                    e.model = self.sm.name
                req.error = e
                self.failures += 1
                failed.append(req.rid)
                self._retire(req)
                continue
            self.prefill_s += time.perf_counter() - t0
            admitted.append(req.rid)
            if self._emit(req, tok):
                self._retire(req)
                retired.append(req.rid)
            else:
                self._active.append(_Active(req, self._step_no))

        if not self._active:
            if not admitted and not failed:
                with self._lock:
                    if not self._pending:
                        return None
            tr = StepTrace(self._step_no, [], admitted, retired, [],
                           self.kv.pages_in_use, 0.0, failed=failed)
            self.trace.append(tr)
            self._step_no += 1
            return tr

        # -- grow every sequence by one token; evict from the back of the
        #    priority order when pages / ledger budget run out
        order = sorted(range(len(self._active)),
                       key=lambda i: self._active[i].sort_key(i))
        ranked = [self._active[i] for i in order]
        survivors: List[_Active] = []
        i = 0
        while i < len(ranked):
            a = ranked[i]
            if self.kv.extend(a.req.rid, 1):
                survivors.append(a)
                i += 1
                continue
            if len(ranked) > i + 1:          # evict the weakest victim
                victim = ranked.pop()
            else:                            # alone and stuck: evict self
                victim = ranked.pop(i)
            self.kv.free(victim.req.rid)
            self.preemptions += 1
            preempted.append(victim.req.rid)
            with self._lock:
                self._pending.appendleft(victim.req)
        self._active = survivors

        # -- one batched decode step for the survivors
        if self._active:
            t0 = time.perf_counter()
            rids = [a.req.rid for a in self._active]
            view = PagedBatchView(self.kv, rids)
            pos = np.asarray([self.kv.seq_len(r) - 1 for r in rids], np.int32)
            batch = {"token": jnp.asarray(
                         [[a.req.output[-1]] for a in self._active],
                         jnp.int32),
                     "pos": jnp.asarray(pos)}
            if self.sm.cfg.rope_type == "mrope":
                batch["positions"] = jnp.asarray(
                    np.broadcast_to(pos[:, None, None],
                                    (len(rids), 1, 3)).copy())
            logits = self.sm.decode_step_paged(batch, view)
            toks = np.argmax(np.asarray(logits)[:, -1], axis=-1)
            self.decode_s += time.perf_counter() - t0
            still: List[_Active] = []
            for a, tok in zip(self._active, toks):
                if self._emit(a.req, int(tok)):
                    self._retire(a.req)
                    retired.append(a.req.rid)
                else:
                    still.append(a)
            self._active = still
        else:
            rids = []

        tr = StepTrace(self._step_no, rids, admitted, retired, preempted,
                       self.kv.pages_in_use, len(rids) / self.max_batch,
                       failed=failed)
        self.trace.append(tr)
        self._step_no += 1
        return tr

    # ------------------------------------------------------------ driving
    def run_until(self, rid: int,
                  should_yield: Optional[Callable[[], bool]] = None) -> bool:
        """Step the WHOLE batch until sequence ``rid`` retires (True) or
        ``should_yield()`` fires at a decode-step boundary (False — the
        caller re-enters later; the batch keeps its state either way)."""
        with self._lock:
            if rid not in self._known:
                raise KeyError(f"rid {rid} was never submitted")
        while True:
            if self.is_done(rid):
                return True
            if should_yield is not None and should_yield():
                return False
            if self.step() is None:
                # queue fully drained without ever seeing rid retire —
                # cannot happen for a known rid unless it already finished
                return self.is_done(rid)

    def run_all(self) -> None:
        """Drain everything (bench/test convenience)."""
        while self.step() is not None:
            pass

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        decoded = [t for t in self.trace if t.batch]
        occ = [t.occupancy for t in decoded]
        return {
            "steps": float(self._step_no),
            "decode_steps": float(len(decoded)),
            "tokens_emitted": float(self.tokens_emitted),
            "preemptions": float(self.preemptions),
            "failures": float(self.failures),
            "mean_occupancy": float(np.mean(occ)) if occ else 0.0,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "tok_per_s": (self.tokens_emitted
                          / max(self.prefill_s + self.decode_s, 1e-9)),
            "kv_pages_peak": float(max((t.kv_pages for t in self.trace),
                                       default=0)),
        }
