"""Batched serving engines.

:class:`ServingEngine` — single in-memory model: request queue -> padded
batch -> prefill -> greedy decode.

:class:`MultiModelServingEngine` — multi-tenant serving on top of
:class:`~repro.core.multi_model.MultiModelRuntime` (the paper's §6 multi-DNN
scenario end-to-end): several models co-reside under ONE weight budget,
requests for different models interleave freely, blocks stream through
memory with each model's depth-m prefetch pipeline, and hot units are served
out of the shared LRU block cache.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.serving.kv_cache import gather_cache_rows, pad_prefill_cache


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos: Optional[int] = None
    output: List[int] = field(default_factory=list)
    # urgency u (paper §6.2): the priority-aware scheduler
    # (core/serving_scheduler.py) admits by urgency-weighted deadline and
    # preempts lower classes at block boundaries; the serialized engines
    # below ignore it (arrival order).
    priority: float = 1.0
    # terminal failure (SwapError taxonomy): set by the batch engine when
    # the sequence is EVICTED on an unrecoverable swap failure instead of
    # retired cleanly — the retire callback fires either way, and the
    # scheduler tier re-raises this from ServingRequest.wait().
    error: Optional[BaseException] = None


def pad_prompts(cfg, reqs: Sequence["Request"]) -> Dict:
    """Left-pad a request batch into a prefill input dict."""
    B = len(reqs)
    L = max(len(r.prompt) for r in reqs)
    toks = np.zeros((B, L), np.int32)
    for i, r in enumerate(reqs):
        toks[i, L - len(r.prompt):] = r.prompt
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.rope_type == "mrope":
        pos = np.broadcast_to(np.arange(L)[None, :, None], (B, L, 3))
        batch["positions"] = jnp.asarray(pos.copy(), jnp.int32)
    return batch


class ServingEngine:
    def __init__(self, model: Model, params: dict, max_len: int = 512):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(model.decode_step)

    def _pad_batch(self, reqs: Sequence[Request]) -> Dict:
        return pad_prompts(self.model.cfg, reqs)

    def generate(self, reqs: Sequence[Request]) -> Dict[str, float]:
        """Greedy generation for a batch of requests (in place).

        Each request retires at ITS OWN ``max_new_tokens`` / EOS: finished
        rows are gathered out of the decode cache (``gather_cache_rows``),
        so a ragged batch never decodes padding for requests that are
        already done — the contiguous-path cousin of the paged engine's
        per-step retirement (serving/batch_engine.py)."""
        assert self.model.cfg.supports_decode(), "encoder-only model"
        B = len(reqs)
        t0 = time.perf_counter()
        batch = self._pad_batch(reqs)
        L = batch["tokens"].shape[1]
        logits, cache = self._prefill(self.params, batch)
        cache = pad_prefill_cache(self.model, cache, self.max_len, B)
        t_prefill = time.perf_counter() - t0

        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        active = list(range(B))         # request index per live cache row
        n_steps = 0
        decoded = 0
        for step in range(self.max_len):
            keep: List[int] = []
            for row, i in enumerate(active):
                r = reqs[i]
                t = int(tok[row])
                r.output.append(t)
                finished = (r.eos is not None and t == r.eos) \
                    or len(r.output) >= r.max_new_tokens
                if not finished:
                    keep.append(row)
            if not keep or L + step >= self.max_len:
                break
            if len(keep) < len(active):         # retire finished rows
                cache = gather_cache_rows(self.model, cache, keep,
                                          self.max_len, len(active))
                tok = tok[jnp.asarray(keep)]
                active = [active[row] for row in keep]
            db = {"token": tok[:, None],
                  "pos": jnp.full((len(active),), L + step, jnp.int32)}
            if self.model.cfg.rope_type == "mrope":
                db["positions"] = jnp.full((len(active), 1, 3), L + step,
                                           jnp.int32)
            logits, cache = self._step(self.params, cache, db)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            n_steps += 1
            decoded += len(active)
        total = time.perf_counter() - t0
        return {"prefill_s": t_prefill, "total_s": total,
                "decode_steps": n_steps,
                "tok_per_s": decoded / max(total - t_prefill, 1e-9)}


class MultiModelServingEngine:
    """Interleaved multi-tenant serving under one shared weight budget.

    Wraps a planned :class:`~repro.core.multi_model.MultiModelRuntime`:
    requests are tagged with the model they target and served in arrival
    order, one at a time (the single-executor edge-device model; for K
    concurrent executors with priority-aware admission and block-boundary
    preemption, see :class:`repro.core.serving_scheduler.ServingScheduler`).
    Every forward streams the target model's blocks through the shared ledger;
    hot units (embeddings, heads, shared blocks) of recently-served models
    stay in the shared cache, so alternating tenants pay the swap-in cost
    only for the cold middle of each model.
    """

    def __init__(self, runtime):
        self.runtime = runtime

    def prefill(self, name: str, reqs: Sequence[Request]) -> jax.Array:
        """Swapped prefill of a same-model request batch; returns the
        last-position logits (bit-identical to the unswapped model)."""
        sm = self.runtime.models[name]
        batch = pad_prompts(sm.model.cfg, reqs)
        logits, _ = self.runtime.forward(name, batch)
        return logits

    def generate(self, tagged_reqs: Sequence[Tuple[str, Request]],
                 max_len: int = 128) -> Dict[str, float]:
        """Serve (model_name, request) pairs in order, greedy decoding each
        under the shared budget. Outputs land in ``request.output``."""
        t0 = time.perf_counter()
        for name, req in tagged_reqs:
            prompt = jnp.asarray([req.prompt], jnp.int32)
            gen, _ = self.runtime.decode(name, prompt,
                                         max_new_tokens=req.max_new_tokens,
                                         max_len=max_len)
            req.output.extend(int(t) for t in np.asarray(gen)[0])
        st = self.runtime.stats()
        st["total_s"] = time.perf_counter() - t0
        st["requests"] = len(tagged_reqs)
        return st
