"""Batched serving engine: request queue -> padded batch -> prefill -> greedy
decode. Supports an HBM weight budget via SwapNet weight-block streaming
(the paper's §10 LLM-on-edge direction): when ``weight_budget`` is set, the
dense forward of each decode step streams layer blocks through memory with
the m=2 pipeline instead of keeping all weights resident.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import Model, alloc_cache
from repro.serving.kv_cache import pad_prefill_cache


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos: Optional[int] = None
    output: List[int] = field(default_factory=list)


class ServingEngine:
    def __init__(self, model: Model, params: dict, max_len: int = 512):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(model.decode_step)

    def _pad_batch(self, reqs: Sequence[Request]) -> Dict:
        B = len(reqs)
        L = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, L), np.int32)
        for i, r in enumerate(reqs):
            toks[i, L - len(r.prompt):] = r.prompt     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.model.cfg.rope_type == "mrope":
            pos = np.broadcast_to(np.arange(L)[None, :, None], (B, L, 3))
            batch["positions"] = jnp.asarray(pos.copy(), jnp.int32)
        return batch

    def generate(self, reqs: Sequence[Request]) -> Dict[str, float]:
        """Greedy generation for a batch of requests (in place)."""
        assert self.model.cfg.supports_decode(), "encoder-only model"
        B = len(reqs)
        t0 = time.perf_counter()
        batch = self._pad_batch(reqs)
        L = batch["tokens"].shape[1]
        logits, cache = self._prefill(self.params, batch)
        cache = pad_prefill_cache(self.model, cache, self.max_len, B)
        t_prefill = time.perf_counter() - t0

        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        done = np.zeros(B, bool)
        max_new = max(r.max_new_tokens for r in reqs)
        n_steps = 0
        for step in range(max_new):
            for i, r in enumerate(reqs):
                if not done[i] and step < r.max_new_tokens:
                    r.output.append(int(tok[i]))
                    if r.eos is not None and int(tok[i]) == r.eos:
                        done[i] = True
                elif step >= r.max_new_tokens:
                    done[i] = True
            if done.all() or L + step >= self.max_len:
                break
            db = {"token": tok[:, None],
                  "pos": jnp.full((B,), L + step, jnp.int32)}
            if self.model.cfg.rope_type == "mrope":
                db["positions"] = jnp.full((B, 1, 3), L + step, jnp.int32)
            logits, cache = self._step(self.params, cache, db)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            n_steps += 1
        total = time.perf_counter() - t0
        return {"prefill_s": t_prefill, "total_s": total,
                "decode_steps": n_steps,
                "tok_per_s": (n_steps * B) / max(total - t_prefill, 1e-9)}
