"""KV/state cache management for the serving engine."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.models.transformer import Model, alloc_cache


def pad_prefill_cache(model: Model, prefill_cache: list, max_len: int,
                      batch: int) -> list:
    """Embed a length-S prefill cache into a zero-padded length-max_len decode
    cache. Sequence-indexed leaves (KV, MLA latents) are padded; state leaves
    (SSM, shifts) are carried as-is."""
    shape = ShapeConfig("serve", seq_len=max_len, global_batch=batch,
                        mode="decode")
    target = model.cache_struct(shape)

    def place(pc, tgt):
        if pc.shape == tgt.shape:
            return pc.astype(tgt.dtype)
        pads = [(0, t - s) for s, t in zip(pc.shape, tgt.shape)]
        return jnp.pad(pc.astype(tgt.dtype), pads)

    return jax.tree.map(place, prefill_cache, target)
