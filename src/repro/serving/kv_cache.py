"""KV/state cache management for the LEGACY contiguous serving path.

``pad_prefill_cache`` embeds a prefill cache into one contiguous
``[B, max_len, ...]`` decode cache — simple and exact, but the whole padded
allocation lives for the whole batch: memory scales with the LONGEST
request and a batch slot cannot be reused until its tensor rows are
re-gathered. :class:`~repro.serving.engine.ServingEngine` keeps this path
(it is the in-memory reference the swapped paths are validated against)
and uses ``gather_cache_rows`` to shrink the batch as requests retire.

The swap-aware serving path stores K/V in fixed-size token PAGES instead
(``serving/paged_kv.py`` + ``serving/batch_engine.py``): per-sequence page
lists charged to the shared MemoryLedger, admission/eviction at decode-step
granularity. SSM/shift-state and MLA-latent models stay on the contiguous
path — their recurrent state is O(1) per sequence, so paging buys nothing.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.models.transformer import Model, alloc_cache


def pad_prefill_cache(model: Model, prefill_cache: list, max_len: int,
                      batch: int) -> list:
    """Embed a length-S prefill cache into a zero-padded length-max_len decode
    cache. Sequence-indexed leaves (KV, MLA latents) are padded; state leaves
    (SSM, shifts) are carried as-is."""
    shape = ShapeConfig("serve", seq_len=max_len, global_batch=batch,
                        mode="decode")
    target = model.cache_struct(shape)

    def place(pc, tgt):
        if pc.shape == tgt.shape:
            return pc.astype(tgt.dtype)
        pads = [(0, t - s) for s, t in zip(pc.shape, tgt.shape)]
        return jnp.pad(pc.astype(tgt.dtype), pads)

    return jax.tree.map(place, prefill_cache, target)


def gather_cache_rows(model: Model, cache: list, rows: Sequence[int],
                      max_len: int, batch: int) -> list:
    """Shrink a ``batch``-row decode cache to the surviving ``rows`` (in
    order) — how the contiguous engine retires finished requests mid-batch
    instead of decoding padding until the longest request completes.

    The batch axis is found per leaf by diffing the model's cache structure
    at the old and new batch sizes (scanned segments stack layers LEADING,
    so batch is not a fixed axis index across families)."""
    old = model.cache_struct(ShapeConfig("serve", seq_len=max_len,
                                         global_batch=batch, mode="decode"))
    new = model.cache_struct(ShapeConfig("serve", seq_len=max_len,
                                         global_batch=len(rows),
                                         mode="decode"))
    idx = jnp.asarray(list(rows), jnp.int32)

    def take(leaf, o, n):
        assert leaf.shape == o.shape, (leaf.shape, o.shape)
        diffs = [i for i, (a, b) in enumerate(zip(o.shape, n.shape))
                 if a != b]
        assert len(diffs) == 1, \
            f"expected exactly the batch axis to differ: {o.shape}->{n.shape}"
        return jnp.take(leaf, idx, axis=diffs[0])

    return jax.tree.map(take, cache, old, new)
