"""Stdlib-only HTTP control plane in front of :class:`ServingScheduler`.

Until now the only way to observe or drive the scheduler was from inside
the same Python process — every fleet scenario was a bespoke CLI
invocation frozen at process start. This module puts a small JSON/HTTP
surface (``http.server.ThreadingHTTPServer``; no dependencies) over the
EXISTING request IDs and runtime entry points, so scenarios are scripted
against a running serving process instead of rebuilt per flag combination:

  ==========  ==============================  ====================================
  method      path                            action
  ==========  ==============================  ====================================
  GET         ``/healthz``                    liveness + per-model breaker state
  GET         ``/metrics``                    Prometheus text (MetricsRegistry)
  GET         ``/v1/models``                  registered models + plans
  POST        ``/v1/submit``                  prefill request -> ``{"rid": n}``
  POST        ``/v1/generate``                generation request -> ``{"rid": n}``
  GET         ``/v1/requests/<rid>``          poll status/result
  POST        ``/v1/requests/<rid>/cancel``   queue-removal cancellation
  POST        ``/v1/models``                  RUNTIME model arrival (add + replan)
  POST        ``/v1/models/<name>/reset``     clear the model's circuit breaker
  POST        ``/v1/replan``                  live ``replan_budgets()`` trigger
  POST        ``/v1/shutdown``                graceful stop (drains the server)
  ==========  ==============================  ====================================

``/v1/submit`` accepts either explicit prompts (``{"model": "qwen2.5-3b",
"tokens": [[1,2,3], ...]}``) or a seeded random workload (``{"model": ...,
"requests": 2, "prompt_len": 32, "seed": 0}``) so drivers do not ship
kilobytes of token JSON to reproduce a bench arm. Latency reported on poll
is the scheduler's own ``latency_s`` (arrival -> completion), so HTTP
polling cadence never distorts the serving numbers.

Runtime model arrival (``POST /v1/models``) is the FusedInf-style piece:
the handler builds the arch, registers it on the shared-ledger runtime,
and re-plans the block budgets — co-tenants keep serving; passes already
in flight keep their snapshotted block lists. Mutating routes serialize on
one lock; the data plane (submit/poll) stays lock-free on the scheduler's
own thread-safe queue.
"""
from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.errors import ConfigError, RequestCancelled
from repro.serving.engine import Request, pad_prompts
from repro.serving.metrics import MetricsRegistry

__all__ = ["ControlPlane", "ENDPOINTS"]

# (METHOD, path-template) — the stable HTTP contract; the docs-drift
# checker verifies the documented endpoints against this list.
ENDPOINTS: Tuple[Tuple[str, str], ...] = (
    ("GET", "/healthz"),
    ("GET", "/metrics"),
    ("GET", "/v1/models"),
    ("POST", "/v1/submit"),
    ("POST", "/v1/generate"),
    ("GET", "/v1/requests/<rid>"),
    ("POST", "/v1/requests/<rid>/cancel"),
    ("POST", "/v1/models"),
    ("POST", "/v1/models/<name>/reset"),
    ("POST", "/v1/replan"),
    ("POST", "/v1/shutdown"),
)


class _ApiError(Exception):
    def __init__(self, status: int, msg: str):
        super().__init__(msg)
        self.status = status


def _default_build_model(arch: str, reduce: str, seed: int):
    """Build (model, params) for a runtime arrival from the arch registry —
    the same path ``launch/serve.py`` uses at startup."""
    from repro.configs import get_arch
    from repro.launch.train import scale_config
    from repro.models.transformer import Model
    import jax
    cfg = scale_config(get_arch(arch), reduce)
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    return model, params


class ControlPlane:
    """HTTP front for one (runtime, scheduler) pair.

    ``plan_shape`` is the (batch, seq) the runtime was planned with — model
    arrivals re-plan against the same shape. ``port=0`` binds an ephemeral
    port (read ``self.port`` after :meth:`start`). ``build_model`` is the
    arrival factory, injectable for tests."""

    def __init__(self, runtime, scheduler, metrics: Optional[MetricsRegistry]
                 = None, host: str = "127.0.0.1", port: int = 0,
                 plan_shape: Tuple[int, int] = (2, 32),
                 reduce: str = "smoke", workdir: Optional[str] = None,
                 build_model: Callable = _default_build_model):
        self.runtime = runtime
        self.scheduler = scheduler
        self.metrics = (metrics if metrics is not None
                        else MetricsRegistry(runtime, scheduler))
        self.host = host
        self.port = int(port)
        self.plan_shape = plan_shape
        self.reduce = reduce
        self.workdir = workdir
        self.build_model = build_model
        self._requests: Dict[int, Any] = {}      # rid -> ServingRequest
        self._gen_of: Dict[int, Request] = {}    # rid -> decode Request
        self._mutate = threading.Lock()          # add_model/replan serialize
        self._server: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self.shutdown_requested = threading.Event()

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "ControlPlane":
        handler = _make_handler(self)
        self._server = ThreadingHTTPServer((self.host, self.port), handler)
        self._server.daemon_threads = True
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        name="swapnet-http", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self) -> "ControlPlane":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ handlers
    def _model_or_404(self, name: str):
        if name not in self.runtime.models:
            raise _ApiError(404, f"unknown model {name!r}; registered: "
                                 f"{sorted(self.runtime.models)}")
        return self.runtime.models[name]

    def _build_batch(self, sm, body: Dict) -> Dict:
        cfg = sm.cfg
        if "tokens" in body:
            rows = body["tokens"]
            if (not isinstance(rows, list) or not rows
                    or not all(isinstance(r, list) and r for r in rows)):
                raise _ApiError(400, "tokens must be a non-empty list of "
                                     "non-empty token lists")
            hi = cfg.vocab_size
            if any(not (0 <= int(t) < hi) for r in rows for t in r):
                raise _ApiError(400, f"token id out of range [0, {hi})")
            reqs = [Request(i, [int(t) for t in r])
                    for i, r in enumerate(rows)]
        else:
            n = int(body.get("requests", 1))
            plen = int(body.get("prompt_len", self.plan_shape[1]))
            if n < 1 or plen < 1:
                raise _ApiError(400, "requests and prompt_len must be >= 1")
            rng = np.random.default_rng(int(body.get("seed", 0)))
            reqs = [Request(i, list(map(int, rng.integers(0, cfg.vocab_size,
                                                          plen))))
                    for i in range(n)]
        return pad_prompts(cfg, reqs)

    def h_submit(self, body: Dict) -> Dict:
        name = body.get("model")
        if not name:
            raise _ApiError(400, "missing 'model'")
        sm = self._model_or_404(name)
        batch = self._build_batch(sm, body)
        req = self.scheduler.submit(
            name, batch, priority=float(body.get("priority", 1.0)),
            deadline=(float(body["deadline"]) if body.get("deadline")
                      is not None else None))
        self._requests[req.rid] = req
        return {"rid": req.rid, "model": name,
                "batch_shape": [int(x) for x in batch["tokens"].shape]}

    def h_generate(self, body: Dict) -> Dict:
        name = body.get("model")
        if not name:
            raise _ApiError(400, "missing 'model'")
        sm = self._model_or_404(name)
        prompt = body.get("prompt")
        if not isinstance(prompt, list) or not prompt:
            raise _ApiError(400, "generate wants 'prompt': [token, ...]")
        if any(not (0 <= int(t) < sm.cfg.vocab_size) for t in prompt):
            raise _ApiError(400, f"token id out of range "
                                 f"[0, {sm.cfg.vocab_size})")
        gen = Request(0, [int(t) for t in prompt],
                      max_new_tokens=int(body.get("max_new_tokens", 16)))
        try:
            req = self.scheduler.submit_generate(
                name, gen, priority=float(body.get("priority", 1.0)),
                deadline=(float(body["deadline"]) if body.get("deadline")
                          is not None else None))
        except (ValueError, AssertionError) as e:   # e.g. kv_frac == 0
            raise _ApiError(409, f"generate unavailable for {name!r}: {e}")
        gen.rid = req.rid       # one id namespace for the HTTP client
        self._requests[req.rid] = req
        self._gen_of[req.rid] = gen
        return {"rid": req.rid, "model": name}

    def h_poll(self, rid: int, query: Dict) -> Dict:
        req = self._requests.get(rid)
        if req is None:
            raise _ApiError(404, f"unknown rid {rid}")
        out: Dict[str, Any] = {"rid": rid, "model": req.model,
                               "priority": req.priority, "kind": req.kind}
        if not req.done.is_set():
            out["status"] = "pending"
            return out
        if req.error is not None:
            out["status"] = ("cancelled"
                             if isinstance(req.error, RequestCancelled)
                             else "error")
            out["error"] = {"type": type(req.error).__name__,
                            "message": str(req.error)}
            return out
        out["status"] = "done"
        out["latency_s"] = req.latency_s
        if req.kind == "generate":
            gen = self._gen_of.get(rid)
            if gen is not None:
                out["output"] = [int(t) for t in gen.output]
        elif req.logits is not None:
            arr = np.asarray(req.logits)
            out["logits_shape"] = list(arr.shape)
            if query.get("logits"):        # opt-in: logits payloads are big
                out["logits"] = arr.astype(np.float64).tolist()
        return out

    def h_cancel(self, rid: int) -> Dict:
        if rid not in self._requests:
            raise _ApiError(404, f"unknown rid {rid}")
        return {"rid": rid, "cancelled": bool(self.scheduler.cancel(rid))}

    def h_models_get(self) -> Dict:
        models = {}
        for name, sm in self.runtime.models.items():
            down = self.scheduler.model_down(name)
            models[name] = {
                "arch": sm.cfg.name,
                "store": sm.store_backend,
                "precision": sm.precision,
                "n_blocks": sm.plan.n_blocks if sm.plan else None,
                "m": sm.plan.m if sm.plan else None,
                "up": down is None,
                "down_reason": str(down) if down is not None else None,
            }
        return {"models": models}

    def h_add_model(self, body: Dict) -> Dict:
        arch = body.get("arch")
        if not arch:
            raise _ApiError(400, "missing 'arch'")
        name = body.get("name") or arch
        if self.workdir is None:
            raise _ApiError(409, "this control plane has no workdir for "
                                 "model arrivals")
        with self._mutate:
            if name in self.runtime.models:
                raise _ApiError(409, f"model {name!r} already registered")
            try:
                model, params = self.build_model(
                    arch, body.get("reduce", self.reduce),
                    seed=len(self.runtime.models))
            except KeyError as e:
                raise _ApiError(404, str(e))
            self.runtime.add_model(name, model, params, self.workdir,
                                   store_backend=body.get("store"),
                                   precision=body.get("precision"))
            plans = self.runtime.plan(*self.plan_shape)
        return {"added": name, "arch": arch,
                "n_blocks": plans[name].n_blocks,
                "models": sorted(self.runtime.models)}

    def h_reset_model(self, name: str) -> Dict:
        self._model_or_404(name)
        self.scheduler.reset_model(name)
        return {"reset": name, "up": self.scheduler.model_down(name) is None}

    def h_replan(self, body: Dict) -> Dict:
        urgencies = body.get("urgencies") or self.scheduler.queue.urgency_mix()
        if not urgencies:
            # idle queue, no explicit mix: uniform re-split
            urgencies = {name: 1.0 for name in self.runtime.models}
        try:
            with self._mutate:
                budgets = self.runtime.replan_budgets(
                    {str(k): float(v) for k, v in urgencies.items()})
        except (ValueError, AssertionError) as e:
            raise _ApiError(409, f"replan rejected: {e}")
        return {"budgets_mb": {k: v / 1e6 for k, v in budgets.items()},
                "urgencies": urgencies}

    def h_healthz(self) -> Dict:
        models = {name: self.scheduler.model_down(name) is None
                  for name in self.runtime.models}
        return {"status": "ok" if all(models.values()) else "degraded",
                "models": models,
                "queue_depth": len(self.scheduler.queue)}

    def h_shutdown(self) -> Dict:
        self.shutdown_requested.set()
        return {"shutting_down": True}


# --------------------------------------------------------------- transport
def _make_handler(cp: ControlPlane):
    routes_get = [
        (re.compile(r"^/healthz$"), lambda m, q: cp.h_healthz()),
        (re.compile(r"^/v1/models$"), lambda m, q: cp.h_models_get()),
        (re.compile(r"^/v1/requests/(\d+)$"),
         lambda m, q: cp.h_poll(int(m.group(1)), q)),
    ]
    routes_post = [
        (re.compile(r"^/v1/submit$"), lambda m, b: cp.h_submit(b)),
        (re.compile(r"^/v1/generate$"), lambda m, b: cp.h_generate(b)),
        (re.compile(r"^/v1/requests/(\d+)/cancel$"),
         lambda m, b: cp.h_cancel(int(m.group(1)))),
        (re.compile(r"^/v1/models$"), lambda m, b: cp.h_add_model(b)),
        (re.compile(r"^/v1/models/([^/]+)/reset$"),
         lambda m, b: cp.h_reset_model(m.group(1))),
        (re.compile(r"^/v1/replan$"), lambda m, b: cp.h_replan(b)),
        (re.compile(r"^/v1/shutdown$"), lambda m, b: cp.h_shutdown()),
    ]

    class Handler(BaseHTTPRequestHandler):
        server_version = "swapnet-control/1"
        protocol_version = "HTTP/1.1"

        def log_message(self, fmt, *args):      # noqa: D102 — quiet server
            pass

        def _reply(self, status: int, payload, content_type="application/json"):
            body = (payload if isinstance(payload, bytes)
                    else json.dumps(payload, sort_keys=True).encode())
            self.send_response(status)
            self.send_header("Content-Type", content_type)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _dispatch(self, routes, payload):
            path, _, rawq = self.path.partition("?")
            query = dict(p.partition("=")[::2] for p in rawq.split("&") if p)
            cp.metrics.count_http(path)
            for pattern, fn in routes:
                m = pattern.match(path)
                if m:
                    try:
                        arg = query if payload is None else payload
                        return self._reply(200, fn(m, arg))
                    except _ApiError as e:
                        return self._reply(e.status, {"error": str(e)})
                    except ConfigError as e:
                        return self._reply(400, {"error": str(e)})
                    except Exception as e:      # noqa: BLE001 — API boundary
                        return self._reply(
                            500, {"error": f"{type(e).__name__}: {e}"})
            return self._reply(404, {"error": f"no route for {path}"})

        def do_GET(self):                       # noqa: N802 — http.server API
            path = self.path.partition("?")[0]
            if path == "/metrics":
                cp.metrics.count_http("/metrics")
                return self._reply(200, cp.metrics.render_prometheus().encode(),
                                   content_type="text/plain; version=0.0.4")
            return self._dispatch(routes_get, None)

        def do_POST(self):                      # noqa: N802 — http.server API
            length = int(self.headers.get("Content-Length") or 0)
            raw = self.rfile.read(length) if length else b""
            try:
                body = json.loads(raw) if raw else {}
            except json.JSONDecodeError as e:
                return self._reply(400, {"error": f"bad JSON body: {e}"})
            if not isinstance(body, dict):
                return self._reply(400, {"error": "body must be a JSON "
                                                  "object"})
            return self._dispatch(routes_post, body)

    return Handler
