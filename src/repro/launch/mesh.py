"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init)."""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips ("data", "model").
    Multi-pod: (2, 16, 16) = 512 chips ("pod", "data", "model") — the pod
    axis is pure data parallelism across pods."""
    import jax

    from repro.compat import make_mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — the "
            f"dry-run must set --xla_force_host_platform_device_count=512 "
            f"before any jax import")
    return make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CI smoke tests)."""
    from repro.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))
