import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any jax import (device count locks at
# first init). 512 placeholder host devices back the production meshes.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, applicable, get_arch, get_shape  # noqa: E402
from repro.distributed.sharding import filter_spec, set_mesh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.transformer import (Model, input_pspecs, input_specs)  # noqa: E402
from repro.training.optimizer import OptConfig  # noqa: E402
from repro.training.train_loop import make_train_step, train_state_specs  # noqa: E402

# --------------------------------------------------------------------------
# HLO collective parsing: cost_analysis() has no collective bytes, so we sum
# operand/result sizes of every collective op in the post-SPMD module.
# --------------------------------------------------------------------------
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: op count and result bytes (per device)."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.match(r"^((?:\([^)]*\)|\S+))\s+([\w\-]+)\(", rhs)
        if not opm:
            continue
        shape_txt, opname = opm.group(1), opm.group(2)
        # normalize fused variants like all-reduce-start
        base = None
        for k in _COLLECTIVES:
            if opname == k or opname.startswith(k + "-start"):
                base = k
                break
        if base is None:
            continue
        out[base]["count"] += 1
        out[base]["bytes"] += _shape_bytes(shape_txt)
    return out


# --------------------------------------------------------------------------
def _shardings(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, filter_spec(s, mesh)),
        tree_specs, is_leaf=lambda x: isinstance(x, P))


from repro.configs.flops import analytic_flops_per_device  # noqa: E402


def build_lowering(arch: str, shape_name: str, mesh, donate: bool = True):
    """Returns (lowered, meta) for the (arch, shape) combination."""
    import repro.models.transformer as tmod
    shape_cfg = get_shape(shape_name)
    # honest HLO accounting for inference; train keeps the rolled scan
    # (see analytic_flops_per_device)
    tmod.LAYER_SCAN_UNROLL = shape_cfg.mode != "train"
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    model = Model(cfg)
    batch_struct = input_specs(cfg, shape)
    batch_shard = _shardings(input_pspecs(cfg, shape, mesh), mesh)

    if shape.mode == "train":
        params = model.param_struct()            # fp32 master
        state = {"params": params,
                 "mu": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
                 "nu": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
        state_shard = _shardings(train_state_specs(model), mesh)
        step = make_train_step(model, OptConfig())
        fn = jax.jit(step, in_shardings=(state_shard, batch_shard),
                     donate_argnums=(0,) if donate else ())
        lowered = fn.lower(state, batch_struct)
    elif shape.mode == "prefill":
        params = model.param_struct(cfg.dtype)   # serving weights bf16
        pshard = _shardings(model.param_specs(), mesh)
        fn = jax.jit(model.prefill, in_shardings=(pshard, batch_shard))
        lowered = fn.lower(params, batch_struct)
    else:  # decode
        params = model.param_struct(cfg.dtype)
        pshard = _shardings(model.param_specs(), mesh)
        cache = model.cache_struct(shape)
        cshard = _shardings(model.cache_specs(shape, mesh), mesh)
        fn = jax.jit(model.decode_step,
                     in_shardings=(pshard, cshard, batch_shard),
                     donate_argnums=(1,) if donate else ())
        lowered = fn.lower(params, cache, batch_struct)
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(model.param_struct()))
    n_dev = int(np.prod(mesh.devices.shape))
    return lowered, {"n_params": n_params, "mode": shape.mode,
                     "n_devices": n_dev,
                     "flops_analytic_per_dev":
                         analytic_flops_per_device(cfg, shape, n_dev),
                     "tokens": shape.global_batch * (1 if shape.mode == "decode"
                                                     else shape.seq_len)}


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: Optional[str] = None, verbose: bool = True,
            flash_decode: bool = False, tag_suffix: str = "") -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh(mesh)
    if flash_decode:
        from repro.models import attention as attn_mod
        shape_cfg = get_shape(shape_name)
        if shape_cfg.global_batch == 1:
            attn_mod.SHARDED_DECODE_AXIS = ("pod", "data", "model")
        else:
            attn_mod.SHARDED_DECODE_AXIS = ("model",)
    t0 = time.time()
    try:
        lowered, meta = build_lowering(arch, shape_name, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        mem_d = {}
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                if hasattr(mem, k):
                    mem_d[k] = int(getattr(mem, k))
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        cost_d = {k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and (
                      k in ("flops", "bytes accessed", "optimal_seconds")
                      or k.startswith("bytes accessed"))}
        coll = parse_collectives(compiled.as_text())
        result = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "ok", "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory_analysis": mem_d, "cost_analysis": cost_d,
            "collectives": coll, **meta,
        }
    except Exception as e:  # noqa: BLE001 — recorded, not swallowed silently
        result = {"arch": arch, "shape": shape_name,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "status": "error", "error": f"{type(e).__name__}: {e}"}
    finally:
        set_mesh(None)
        if flash_decode:
            from repro.models import attention as attn_mod
            attn_mod.SHARDED_DECODE_AXIS = None
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{result['mesh']}{tag_suffix}.json"
        with open(os.path.join(out_dir, tag), "w") as fh:
            json.dump(result, fh, indent=1)
    if verbose:
        if result["status"] == "ok":
            ca = result["cost_analysis"]
            print(f"[dryrun] {arch} x {shape_name} x {result['mesh']}: OK "
                  f"flops/dev={ca.get('flops', 0):.3e} "
                  f"compile={result['compile_s']}s", flush=True)
            print(f"  memory_analysis: {result['memory_analysis']}", flush=True)
        else:
            print(f"[dryrun] {arch} x {shape_name} x {result['mesh']}: "
                  f"FAILED {result['error']}", flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every applicable (arch x shape) on this mesh")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--flash-decode", action="store_true",
                    help="§Perf variant: shard_map flash-decoding over the "
                         "sequence-sharded KV cache")
    ap.add_argument("--windowed-kv", action="store_true",
                    help="§Perf variant: ring-buffer KV cache for SWA archs")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="§Perf variant: sequence-parallel residual stream "
                         "(train memory)")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    args = ap.parse_args()
    if args.windowed_kv:
        import repro.models.transformer as _t
        _t.WINDOWED_KV_CACHE = True
    if args.seq_parallel:
        import repro.models.transformer as _t
        _t.SEQ_PARALLEL_RESIDUAL = True

    combos = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    n_ok = n_skip = n_err = 0
    for a, s in combos:
        if not applicable(ARCHS[a], SHAPES[s]):
            print(f"[dryrun] {a} x {s}: SKIP (per DESIGN.md §5)", flush=True)
            n_skip += 1
            continue
        tag = os.path.join(args.out, f"{a}__{s}__{mesh_tag}.json")
        if args.skip_existing and os.path.exists(tag):
            with open(tag) as fh:
                if json.load(fh).get("status") == "ok":
                    n_ok += 1
                    continue
        r = run_one(a, s, args.multi_pod, args.out,
                    flash_decode=args.flash_decode, tag_suffix=args.tag)
        if r["status"] == "ok":
            n_ok += 1
        else:
            n_err += 1
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} failed",
          flush=True)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
