"""End-to-end training driver.

Examples:
    # laptop-scale: ~100M model, a few hundred steps on synthetic data
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduce 100m \
        --steps 300 --batch 8 --seq 256
    # production lowering check only (mesh + shardings, no real cluster here)
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import SyntheticLM
from repro.models.transformer import Model
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainState, make_train_step
from repro.training import checkpoint


def scale_config(cfg, preset: str):
    """Reduce an assigned arch to a runnable scale, keeping its family traits."""
    if preset == "smoke":
        return cfg.reduced()
    if preset == "100m":
        kw = dict(n_layers=min(cfg.n_layers, 8), d_model=768, n_heads=12,
                  n_kv_heads=min(cfg.n_kv_heads, 4) or 1, head_dim=64,
                  d_ff=2048, vocab_size=min(cfg.vocab_size, 32768))
        if cfg.n_kv_heads == 1:
            kw["n_kv_heads"] = 1
        if cfg.hybrid_attn_every:
            kw["n_layers"] = 8
        if cfg.moe is not None:
            kw["moe"] = dataclasses.replace(cfg.moe, n_routed=8,
                                            d_expert=512, d_shared=1024)
            kw["d_ff"] = 512
        if cfg.rope_type == "mrope":
            kw["mrope_sections"] = (8, 12, 12)
        return dataclasses.replace(cfg, **kw)
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", default="100m", choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = scale_config(get_arch(args.arch), args.reduce)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}", flush=True)

    opt = OptConfig(peak_lr=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
                    total_steps=args.steps)
    state = TrainState(params)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    ds = SyntheticLM(cfg, args.seq, args.batch)

    t0 = time.perf_counter()
    first = last = None
    for i, batch in zip(range(args.steps), ds.prefetch()):
        state, metrics = step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            if first is None:
                first = loss
            last = loss
            dt = time.perf_counter() - t0
            tps = (i + 1) * args.batch * args.seq / dt
            print(f"  step {i:4d} loss={loss:7.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} tok/s={tps:,.0f}",
                  flush=True)
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'no decrease'})", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, state["params"])
        print(f"[train] checkpoint -> {args.ckpt}", flush=True)


if __name__ == "__main__":
    main()
