"""Serving driver over the layered configuration system (``repro.config``).

Configuration resolves defaults -> device-class profile -> env
(``SWAPNET_*``) -> CLI, so a deployment is one flag instead of fifteen:

    PYTHONPATH=src python -m repro.launch.serve --profile edge-tpu
        # two tenants, 24 MB shared budget, 2 executors, priority classes
        # 1/8 with block-boundary preemption — end to end, zero other flags
    PYTHONPATH=src python -m repro.launch.serve --profile mcu
    PYTHONPATH=src python -m repro.launch.serve --profile workstation
    SWAPNET_RUNTIME_BUDGET_MB=48 python -m repro.launch.serve --profile edge-tpu
        # env layer overrides the profile; CLI flags override the env
    PYTHONPATH=src python -m repro.launch.serve --profile edge-tpu --http
        # same serving system behind the HTTP control plane
        # (submit/poll/cancel, /healthz, Prometheus /metrics)
    PYTHONPATH=src python -m repro.launch.serve --profile mcu --print-config
        # show the resolved config + the layers that produced it

Every pre-profile flag still works and now acts as an override onto the
resolved config (the back-compat contract is golden-snapshot-tested in
``tests/test_serve_backcompat.py``):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --reduce smoke \
        --requests 8 --new-tokens 16
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduce 100m \
        --budget-mb 64   # weight-swapped prefill via SwapNet
    PYTHONPATH=src python -m repro.launch.serve --multi qwen2.5-3b,gemma2-9b \
        --reduce smoke --budget-mb 48 --rounds 3   # shared-budget multi-tenant
    PYTHONPATH=src python -m repro.launch.serve --multi qwen2.5-3b,gemma2-9b \
        --reduce smoke --budget-mb 48 --executors 2 --priorities 1,8
        # concurrent priority-aware serving: 2 executor threads, urgency
        # classes 1 and 8, preemption at block boundaries
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduce smoke \
        --budget-mb 16 --store quant --precision int4   # packed int4 units
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduce smoke \
        --budget-mb 24 --paged --kv-frac 0.3 --max-batch 8
        # continuous-batching decode: weight blocks and KV pages share ONE budget
"""
from __future__ import annotations

import argparse
import json
import tempfile

import jax
import numpy as np

from repro.config import (ServeConfig, explain_layers, profile_names,
                          resolve_config)
from repro.configs import get_arch
from repro.core.cost_model import DelayModel
from repro.core.multi_model import MultiModelRuntime
from repro.core.runtime import SwappedModel
from repro.core.serving_scheduler import ServingScheduler
from repro.launch.train import scale_config
from repro.models.transformer import Model
from repro.serving.batch_engine import BatchDecodeEngine
from repro.serving.control_plane import ControlPlane
from repro.serving.engine import (MultiModelServingEngine, Request,
                                  ServingEngine, pad_prompts)
from repro.serving.metrics import MetricsRegistry
from repro.serving.paged_kv import PagedKVCache


def _percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs, float), q)) if xs else 0.0


# ----------------------------------------------------------------- assembly
def _build_runtime(cfg: ServeConfig, workdir: str):
    """Resolved config -> planned MultiModelRuntime + (model, params) refs.
    The ONE construction path every mode shares: the runtime knobs come off
    ``cfg.runtime``, the tenant set off ``cfg.model_names()``."""
    names = cfg.model_names()
    assert names, "config resolved with no arch/models"
    rt = MultiModelRuntime.from_config(cfg)
    refs = {}
    for i, arch in enumerate(names):
        mcfg = scale_config(get_arch(arch), cfg.reduce)
        model = Model(mcfg)
        params = model.init(jax.random.key(i))
        rt.add_model(arch, model, params, workdir)
        refs[arch] = (model, params)
    rt.plan(batch=cfg.workload.requests, seq=cfg.workload.prompt_len)
    return names, rt, refs


def _make_batches(cfg: ServeConfig, refs, seed: int = 0):
    """One padded prefill batch per tenant from the reference workload."""
    rng = np.random.default_rng(seed)
    batches = {}
    for arch, (model, _) in refs.items():
        mcfg = model.cfg
        reqs = [Request(i, list(rng.integers(0, mcfg.vocab_size,
                                             cfg.workload.prompt_len)))
                for i in range(cfg.workload.requests)]
        batches[arch] = pad_prompts(mcfg, reqs)
    return batches


def _build_multi_runtime(cfg: ServeConfig, workdir: str):
    """Legacy --multi setup (>= 2 tenants enforced, as before)."""
    if len(cfg.model_names()) < 2:
        raise SystemExit("--multi wants at least two comma-separated archs")
    return _build_runtime(cfg, workdir)


# ------------------------------------------------------------ profile mode
def serve_profile(cfg: ServeConfig) -> None:
    """The unified config-driven path: any number of tenants through the
    priority-aware scheduler, priorities assigned round-robin from the
    profile's workload; with ``runtime.paged`` also drives one generation
    per tenant per round through the continuous-batching engine."""
    classes = [float(p) for p in cfg.workload.priorities]
    budget = int(cfg.runtime.budget_mb * 1e6)
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as d:
        names, rt, refs = _build_runtime(cfg, d)
        batches = _make_batches(cfg, refs)
        for arch in names:
            rt.forward(arch, batches[arch])     # warm: jit compile per block

        sched = ServingScheduler.from_config(rt, cfg)
        metrics = MetricsRegistry(rt, sched)
        submitted = []
        for round_i in range(cfg.workload.rounds):
            for j, arch in enumerate(names):
                prio = classes[(round_i * len(names) + j) % len(classes)]
                submitted.append(sched.submit(arch, batches[arch],
                                              priority=prio))
                if cfg.runtime.paged:
                    # unique rid per sequence: each model's batch engine
                    # keys admissions by it
                    gen = Request(1000 + round_i * len(names) + j,
                                  list(map(int, rng.integers(
                                      0, refs[arch][0].cfg.vocab_size, 8))),
                                  max_new_tokens=cfg.workload.new_tokens)
                    submitted.append(sched.submit_generate(arch, gen,
                                                           priority=prio))
        for r in submitted:
            r.wait(timeout=600)
        by_class = sched.latency_by_class()
        quantiles = metrics.latency_quantiles()
        sched.shutdown()
        st = rt.stats()
        rt.close()

    print(f"[serve-profile] profile={cfg.profile}: {len(names)} model(s) "
          f"({', '.join(names)}), {cfg.runtime.executors} executor(s), "
          f"store={cfg.runtime.store}"
          f"{'/' + cfg.runtime.precision if cfg.runtime.precision else ''} "
          f"under {cfg.runtime.budget_mb:g} MB: "
          f"{len(submitted)} requests served, "
          f"peak resident {st['peak_resident_mb']:.1f} MB "
          f"({'OK' if st['peak_resident_mb'] * 1e6 <= budget else 'OVER'}), "
          f"preemptions={sched.preemptions}", flush=True)
    print(f"[serve-profile] cache hit rate {st['cache_hit_rate']*100:.1f}% "
          f"({st['cache_hits']} hits / {st['cache_misses']} misses)",
          flush=True)
    for prio in sorted(by_class, reverse=True):
        q = quantiles[prio]
        print(f"[serve-profile]   priority {prio:g}: n={q['n']} "
              f"p50={q['p50_s']*1e3:.1f} ms p99={q['p99_s']*1e3:.1f} ms",
              flush=True)


def serve_http(cfg: ServeConfig) -> None:
    """Profile serving behind the HTTP control plane: build + warm the same
    runtime ``serve_profile`` runs, then serve until ``POST /v1/shutdown``
    (or Ctrl-C). Everything observable in-process is scrapeable at
    ``/metrics``; requests submit/poll/cancel over plain JSON."""
    with tempfile.TemporaryDirectory() as d:
        names, rt, refs = _build_runtime(cfg, d)
        batches = _make_batches(cfg, refs)
        for arch in names:
            rt.forward(arch, batches[arch])     # warm: jit compile per block
        sched = ServingScheduler.from_config(rt, cfg)
        metrics = MetricsRegistry(rt, sched)
        cp = ControlPlane(rt, sched, metrics,
                          host=cfg.http.host, port=cfg.http.port,
                          plan_shape=(cfg.workload.requests,
                                      cfg.workload.prompt_len),
                          reduce=cfg.reduce, workdir=d)
        cp.start()
        # the line drivers parse — keep the format stable
        print(f"[serve-http] listening on {cp.url} "
              f"(models: {', '.join(names)}; profile={cfg.profile}; "
              f"POST /v1/shutdown to stop)", flush=True)
        try:
            cp.shutdown_requested.wait()
        except KeyboardInterrupt:
            pass
        cp.stop()
        sched.shutdown()
        st = rt.stats()
        rt.close()
    print(f"[serve-http] shut down cleanly: peak resident "
          f"{st['peak_resident_mb']:.1f} MB, "
          f"cache hit rate {st['cache_hit_rate']*100:.1f}%", flush=True)


# ------------------------------------------------------------- legacy modes
def serve_multi_scheduled(cfg: ServeConfig) -> None:
    """K concurrent executors + priority-aware preemptive scheduling over
    the shared-budget runtime (`core/serving_scheduler.py`): requests carry
    an urgency class (--priorities, assigned round-robin) and are admitted
    by urgency-weighted deadline; low-priority passes yield at block
    boundaries to high-urgency arrivals. Reports per-class p50/p99 latency,
    preemption count, and the lossless check vs each unswapped model."""
    classes = [float(p) for p in cfg.workload.priorities]
    budget = int(cfg.runtime.budget_mb * 1e6)
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as d:
        archs, rt, refs = _build_multi_runtime(cfg, d)

        batches, ref_logits = {}, {}
        for arch, (model, params) in refs.items():
            mcfg = model.cfg
            reqs = [Request(i, list(rng.integers(0, mcfg.vocab_size,
                                                 cfg.workload.prompt_len)))
                    for i in range(cfg.workload.requests)]
            batches[arch] = pad_prompts(mcfg, reqs)
            out, _ = jax.jit(model.prefill)(params, batches[arch])
            ref_logits[arch] = np.asarray(out[:, -1:])
            rt.forward(arch, batches[arch])      # warm: jit compile per block

        sched = ServingScheduler.from_config(rt, cfg)
        submitted = []
        for round_i in range(cfg.workload.rounds):
            for j, arch in enumerate(archs):
                prio = classes[(round_i * len(archs) + j) % len(classes)]
                submitted.append(sched.submit(arch, batches[arch],
                                              priority=prio))
        for r in submitted:
            r.wait(timeout=600)
        sched.shutdown()
        st = rt.stats()
        rt.close()

    def _tol(arch):
        # the repo's lossless standard (see serve_multi): residual diffs are
        # XLA fusion order of per-unit vs whole-model jit, not the swap path
        return 1e-4 if refs[arch][0].cfg.dtype == "float32" else 2e-2

    exact = all(
        np.allclose(np.asarray(r.logits), ref_logits[r.model],
                    rtol=_tol(r.model), atol=_tol(r.model))
        for r in submitted
        if rt.models[r.model].store_backend != "quant")
    print(f"[serve-sched] {len(archs)} models, {cfg.runtime.executors} "
          f"executors under {cfg.runtime.budget_mb:.0f} MB: peak resident "
          f"{st['peak_resident_mb']:.1f} MB "
          f"({'OK' if st['peak_resident_mb'] * 1e6 <= budget else 'OVER'}), "
          f"lossless={exact}, preemptions={sched.preemptions}", flush=True)
    by_class = sched.latency_by_class()
    for prio in sorted(by_class, reverse=True):
        lat = [x * 1e3 for x in by_class[prio]]
        print(f"[serve-sched]   priority {prio:g}: n={len(lat)} "
              f"p50={_percentile(lat, 50):.1f} ms "
              f"p99={_percentile(lat, 99):.1f} ms", flush=True)


def _mixed_store_options(cfg: ServeConfig, model, params):
    """With ``--precision mixed`` on the quant store, run the calibration
    pass (repro/calibrate/) and return ``{"plan": PrecisionPlan}`` for the
    SwappedModel's store; None when mixed doesn't apply (other precisions,
    other stores, or a quant-ineligible arch that will fall back to mmap).
    The multi-tenant paths don't need this — MultiModelRuntime.add_model
    calibrates arriving models itself."""
    if (cfg.runtime.precision != "mixed" or cfg.runtime.store != "quant"
            or not model.cfg.quant_eligible):
        return None
    from repro.calibrate import calibrate_model
    _, plan = calibrate_model(model, params, fidelity=cfg.runtime.fidelity,
                              prefetch_depth=cfg.runtime.prefetch_depth)
    hist = plan.histogram()
    print(f"[calibrate] {model.cfg.name}: fidelity {cfg.runtime.fidelity:g} "
          f"-> predicted_err {plan.predicted_err:.2e}, "
          f"stored {plan.stored_bytes/1e6:.2f} MB, units "
          f"fp={hist['fp']} int8={hist['int8']} int4={hist['int4']}",
          flush=True)
    return {"plan": plan}


def serve_paged(cfg: ServeConfig, mcfg, model, params) -> None:
    """Swap-aware continuous-batching decode: weight blocks are planned
    against (1 - kv_frac) of the budget and the KV page pool is sized from
    the rest, BOTH charged to one ledger — growing the decode batch
    genuinely competes with weight-block residency, and page pressure
    preempts the youngest/lowest-priority sequences (recompute on
    re-admission)."""
    budget = int(cfg.runtime.budget_mb * 1e6)
    kv_bytes = int(budget * cfg.runtime.kv_frac)
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet", budget=budget,
                          prefetch_depth=cfg.runtime.prefetch_depth,
                          store_backend=cfg.runtime.store,
                          precision=cfg.runtime.precision,
                          store_options=_mixed_store_options(cfg, model,
                                                             params))
        sm.partition(budget - kv_bytes, DelayModel(), 1,
                     cfg.workload.prompt_len)
        kv = PagedKVCache.for_budget(mcfg, sm.engine.ledger, kv_bytes,
                                     page_tokens=cfg.runtime.page_tokens)
        be = BatchDecodeEngine(sm, kv, max_batch=cfg.runtime.max_batch)
        reqs = [Request(i, list(rng.integers(0, mcfg.vocab_size,
                                             cfg.workload.prompt_len)),
                        max_new_tokens=cfg.workload.new_tokens)
                for i in range(cfg.workload.requests)]
        for r in reqs:
            be.submit(r)
        be.run_all()
        st = be.stats()
        peak = sm.engine.ledger.peak
        sm.close()
    print(f"[serve-paged] {cfg.workload.requests} requests x "
          f"{cfg.workload.new_tokens} new "
          f"tokens under {cfg.runtime.budget_mb:.0f} MB "
          f"(kv_frac={cfg.runtime.kv_frac:g}, {kv.max_pages} pages x "
          f"{kv.page_tokens} tok): {st['tok_per_s']:.2f} tok/s, "
          f"occupancy {st['mean_occupancy']*100:.0f}%, "
          f"preemptions {st['preemptions']:.0f}, "
          f"peak resident {peak/1e6:.1f} MB "
          f"({'OK' if peak <= budget else 'OVER'})", flush=True)
    print(f"[serve-paged] sample output: {reqs[0].output[:12]}", flush=True)


def serve_multi(cfg: ServeConfig) -> None:
    """Two or more models interleaved under ONE weight budget: the paper's
    §6 multi-DNN scenario end-to-end. Verifies the swapped prefill logits
    stay bit-identical to each unswapped model, then reports peak residency
    vs the budget, pipeline overlap efficiency, and cache hit rate."""
    budget = int(cfg.runtime.budget_mb * 1e6)
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as d:
        archs, rt, refs = _build_multi_runtime(cfg, d)

        engine = MultiModelServingEngine(rt)
        exact = True
        fidelity = {}
        for round_i in range(cfg.workload.rounds):
            for arch in archs:          # interleave tenants round-robin
                mcfg = refs[arch][0].cfg
                reqs = [Request(i, list(rng.integers(
                            0, mcfg.vocab_size, cfg.workload.prompt_len)))
                        for i in range(cfg.workload.requests)]
                logits = engine.prefill(arch, reqs)
                if round_i == 0:        # lossless vs the unswapped model
                    # (allclose, the repo's standard: swapping itself is
                    # byte-lossless; residual diffs are XLA fusion order of
                    # per-unit vs whole-model jit, not the swap path. The
                    # quant store is NOT lossless — its bounded error is
                    # reported as fidelity instead of asserted exact.)
                    model, params = refs[arch]
                    batch = pad_prompts(model.cfg, reqs)
                    ref, _ = jax.jit(model.prefill)(params, batch)
                    # gate on the model's EFFECTIVE backend: a quant-
                    # ineligible config fell back to the exact mmap store
                    # and must keep its lossless assertion
                    if rt.models[arch].store_backend == "quant":
                        a = np.asarray(logits, np.float64).ravel()
                        b = np.asarray(ref[:, -1:], np.float64).ravel()
                        fidelity[arch] = float(
                            a @ b / max(np.linalg.norm(a)
                                        * np.linalg.norm(b), 1e-30))
                        continue
                    tol = 1e-4 if model.cfg.dtype == "float32" else 2e-2
                    ok = bool(np.allclose(np.asarray(logits),
                                          np.asarray(ref[:, -1:]),
                                          rtol=tol, atol=tol))
                    exact = exact and ok
        st = rt.stats()
        rt.close()

    # mixed backends report BOTH signals: bounded-error fidelity for the
    # quant tenants, the lossless assertion for every exact-store tenant
    parts = []
    if fidelity:
        parts.append(f"fidelity={min(fidelity.values()):.4f}")
    if len(fidelity) < len(archs):
        parts.append(f"lossless={exact}")
    quality = " ".join(parts)
    print(f"[serve-multi] {len(archs)} models under "
          f"{cfg.runtime.budget_mb:.0f} MB "
          f"(store={cfg.runtime.store}): "
          f"peak resident {st['peak_resident_mb']:.1f} MB "
          f"({'OK' if st['peak_resident_mb'] * 1e6 <= budget else 'OVER'}), "
          f"{quality}", flush=True)
    print(f"[serve-multi] cache {st['cache_resident_mb']:.1f}/"
          f"{st['cache_capacity_mb']:.1f} MB, "
          f"hit rate {st['cache_hit_rate']*100:.1f}% "
          f"({st['cache_hits']} hits / {st['cache_misses']} misses)", flush=True)
    for name, ms in st["models"].items():
        print(f"[serve-multi]   {name}: blocks={ms['n_blocks']} m={ms['m']} "
              f"store={ms['store_backend']}/{ms['precision']} "
              f"overlap_eff={ms['overlap_efficiency']*100:.1f}% "
              f"swapped {ms['bytes_swapped_mb']:.1f} MB "
              f"({ms['bytes_logical_mb']:.1f} MB logical)", flush=True)


def serve_single(cfg: ServeConfig) -> None:
    """Single-arch legacy modes: paged decode, swapped prefill, or the
    plain in-memory engine."""
    mcfg = scale_config(get_arch(cfg.arch), cfg.reduce)
    if not mcfg.supports_decode():
        raise SystemExit(f"{mcfg.name} is encoder-only: no decode serving")
    model = Model(mcfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    if cfg.runtime.paged:
        serve_paged(cfg, mcfg, model, params)
        return
    if cfg.runtime.budget_mb is not None:
        budget = int(cfg.runtime.budget_mb * 1e6)
        with tempfile.TemporaryDirectory() as d:
            sm = SwappedModel(model, params, d, mode="snet", budget=None,
                              prefetch_depth=cfg.runtime.prefetch_depth,
                              store_backend=cfg.runtime.store,
                              precision=cfg.runtime.precision,
                              store_options=_mixed_store_options(cfg, model,
                                                                 params))
            sm.partition(budget, DelayModel(), cfg.workload.requests,
                         cfg.workload.prompt_len)
            batch = {"tokens": jax.numpy.asarray(
                rng.integers(0, mcfg.vocab_size,
                             (cfg.workload.requests,
                              cfg.workload.prompt_len)),
                jax.numpy.int32)}
            logits, stats = sm.forward(batch)   # warm
            sm.engine.stats.__init__()
            logits, stats = sm.forward(batch)
            sm.close()
        print(f"[serve] swapped prefill: {stats['latency_s']*1e3:.1f} ms, "
              f"peak resident {stats['peak_resident_mb']:.1f} MB "
              f"(budget {cfg.runtime.budget_mb:g} MB), "
              f"blocks={sm.plan.n_blocks}, "
              f"store={stats['store_backend']}"
              f"/{stats['precision']}, "
              f"swapped {stats['bytes_swapped']/1e6:.1f} MB "
              f"({stats['bytes_logical']/1e6:.1f} MB logical, "
              f"{stats['bytes_resident_quantized']/1e6:.1f} MB "
              f"quantized-resident), "
              f"kernel VMEM {stats['vmem_working_set']/1e6:.2f} MB, "
              f"overlap_eff={stats['overlap_efficiency']*100:.1f}%", flush=True)
        return

    engine = ServingEngine(model, params, max_len=cfg.workload.max_len)
    reqs = [Request(i, list(rng.integers(0, mcfg.vocab_size,
                                         cfg.workload.prompt_len)),
                    max_new_tokens=cfg.workload.new_tokens)
            for i in range(cfg.workload.requests)]
    stats = engine.generate(reqs)   # includes compile
    reqs2 = [Request(100 + i, r.prompt, r.max_new_tokens)
             for i, r in enumerate(reqs)]
    stats = engine.generate(reqs2)  # warm numbers
    print(f"[serve] {cfg.workload.requests} requests x "
          f"{cfg.workload.new_tokens} new tokens: "
          f"prefill {stats['prefill_s']*1e3:.1f} ms, "
          f"{stats['tok_per_s']:.1f} tok/s decode", flush=True)
    print(f"[serve] sample output: {reqs2[0].output[:12]}", flush=True)


# ------------------------------------------------------------- entry point
def build_parser() -> argparse.ArgumentParser:
    """Every value-bearing flag defaults to None: only EXPLICITLY passed
    flags enter the CLI layer, everything else resolves through
    defaults -> profile -> env (see ``repro.config.layering``)."""
    ap = argparse.ArgumentParser(
        description="SwapNet serving driver (layered config: defaults -> "
                    "profile -> SWAPNET_* env -> CLI)")
    ap.add_argument("--profile", default=None,
                    help=f"device-class deployment profile "
                         f"({', '.join(profile_names())}); every other flag "
                         f"overrides on top")
    ap.add_argument("--print-config", action="store_true",
                    help="print the resolved config (and the layers that "
                         "produced it) as JSON, then exit")
    ap.add_argument("--http", action="store_true", default=None,
                    help="serve behind the HTTP control plane "
                         "(submit/poll/cancel, /healthz, /metrics) until "
                         "POST /v1/shutdown")
    ap.add_argument("--http-host", default=None,
                    help="control-plane bind host (default 127.0.0.1)")
    ap.add_argument("--http-port", type=int, default=None,
                    help="control-plane port (0 = ephemeral; the bound "
                         "port is printed on startup)")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--multi", default=None,
                    help="comma-separated archs served interleaved under one "
                         "shared weight budget (requires --budget-mb)")
    ap.add_argument("--reduce", default=None, choices=["smoke", "100m", "full"])
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--new-tokens", type=int, default=None)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None,
                    help="multi-tenant round-robin passes (repeat requests "
                         "exercise the shared block cache)")
    ap.add_argument("--prefetch-depth", type=int, default=None,
                    help="pipeline residency m (1=serial, 2=double buffer)")
    ap.add_argument("--executors", type=int, default=None,
                    help="concurrent executor threads for --multi serving "
                         "(>1 enables the priority-aware preemptive "
                         "scheduler; each model's blocks are planned "
                         "against a 1/K budget slice so K pipelines co-fit)")
    ap.add_argument("--priorities", default=None,
                    help="comma-separated urgency classes assigned "
                         "round-robin to --multi requests (e.g. '1,8'; "
                         "higher = more urgent — admitted earlier and "
                         "preempts lower classes at block boundaries)")
    ap.add_argument("--rebalance", action="store_true", default=None,
                    help="re-split the block budget (MultiDNNScheduler "
                         "Eq. 1) whenever the queued urgency mix changes")
    ap.add_argument("--cache-frac", type=float, default=None,
                    help="fraction of the budget reserved for the shared "
                         "hot-block cache (multi-tenant mode)")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="SwapNet weight budget: stream blocks during prefill")
    ap.add_argument("--paged", action="store_true", default=None,
                    help="continuous-batching decode through the paged KV "
                         "cache (requires --budget-mb): weight blocks and "
                         "KV pages share one ledger, sequences admit/retire "
                         "at every decode step")
    ap.add_argument("--kv-frac", type=float, default=None,
                    help="fraction of --budget-mb reserved for KV pages in "
                         "--paged mode (the rest plans weight blocks)")
    ap.add_argument("--page-tokens", type=int, default=None,
                    help="tokens per KV page (one page spans all layers)")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="decode batch slots for --paged continuous batching")
    ap.add_argument("--store", default=None,
                    choices=["mmap", "rawio", "quant", "directio"],
                    help="block-store backend: mmap (zero-copy, lossless), "
                         "rawio (read()-based ablation arm), quant (per-"
                         "channel quantized swap units kept quantized-"
                         "resident: 2-D matmul weights stream through the "
                         "fused dequant-matmul kernel, 4-8x less swap-in "
                         "I/O, bounded error), directio (O_DIRECT lossless "
                         "reads that bypass the page cache — no hidden "
                         "double-caching of swapped bytes under a tight "
                         "budget; falls back to buffered reads on "
                         "filesystems without O_DIRECT)")
    ap.add_argument("--precision", default=None,
                    choices=["int8", "int4", "mixed"],
                    help="quant-store unit precision override (default: the "
                         "arch config's swap_precision; int4 packs two "
                         "weights per byte — half the swap bytes of int8 "
                         "at a max|w[:,c]|/14 per-channel error bound; "
                         "mixed runs the sensitivity calibration pass "
                         "(repro/calibrate/) and assigns int4/int8/fp PER "
                         "UNIT against the --fidelity target)")
    ap.add_argument("--fidelity", type=float, default=None,
                    help="max rel-L2 model-output error the mixed-precision "
                         "plan may spend (e.g. 1e-2); required with "
                         "--precision mixed")
    return ap


def cli_overrides(args: argparse.Namespace) -> dict:
    """The CLI layer: only flags the user actually passed, mapped onto the
    nested config schema. ``--arch`` and ``--multi`` clear each other so a
    CLI choice cleanly overrides a profile's tenant set."""
    ov: dict = {}

    def put(section, key, value):
        if value is not None:
            ov.setdefault(section, {})[key] = value

    if args.arch is not None:
        ov["arch"] = args.arch
        ov["models"] = []
    if args.multi is not None:
        ov["models"] = [a.strip() for a in args.multi.split(",") if a.strip()]
        ov["arch"] = None
    if args.reduce is not None:
        ov["reduce"] = args.reduce
    put("workload", "requests", args.requests)
    put("workload", "prompt_len", args.prompt_len)
    put("workload", "new_tokens", args.new_tokens)
    put("workload", "max_len", args.max_len)
    put("workload", "rounds", args.rounds)
    if args.priorities is not None:
        ov.setdefault("workload", {})["priorities"] = [
            float(p) for p in args.priorities.split(",")]
    put("runtime", "budget_mb", args.budget_mb)
    put("runtime", "prefetch_depth", args.prefetch_depth)
    put("runtime", "cache_frac", args.cache_frac)
    put("runtime", "executors", args.executors)
    put("runtime", "store", args.store)
    put("runtime", "precision", args.precision)
    put("runtime", "fidelity", args.fidelity)
    put("runtime", "paged", args.paged)
    put("runtime", "kv_frac", args.kv_frac)
    put("runtime", "page_tokens", args.page_tokens)
    put("runtime", "max_batch", args.max_batch)
    put("scheduler", "rebalance", args.rebalance)
    put("http", "enabled", args.http)
    put("http", "host", args.http_host)
    put("http", "port", args.http_port)
    return ov


def dispatch_mode(cfg: ServeConfig) -> str:
    """Which serving path a resolved config takes — pure routing, snapshot-
    tested for back-compat (tests/test_serve_backcompat.py)."""
    if cfg.http.enabled:
        return "http"
    if cfg.profile:
        return "profile"
    if cfg.models:
        if cfg.runtime.budget_mb is None:
            raise SystemExit("--multi requires --budget-mb")
        return "multi-scheduled" if cfg.runtime.executors > 1 else "multi"
    if not cfg.arch:
        raise SystemExit("need --arch (single model), --multi a,b, or "
                         "--profile <name>")
    if cfg.runtime.paged:
        if cfg.runtime.budget_mb is None:
            raise SystemExit("--paged requires --budget-mb")
        return "paged"
    return "swapped-prefill" if cfg.runtime.budget_mb is not None else "plain"


def run_config(cfg: ServeConfig) -> None:
    mode = dispatch_mode(cfg)
    if mode == "http":
        serve_http(cfg)
    elif mode == "profile":
        serve_profile(cfg)
    elif mode == "multi-scheduled":
        serve_multi_scheduled(cfg)
    elif mode == "multi":
        serve_multi(cfg)
    else:                       # paged / swapped-prefill / plain
        serve_single(cfg)


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    overlay = cli_overrides(args)
    cfg = resolve_config(profile=args.profile, cli=overlay)
    if args.print_config:
        layers = [(name, ov) for name, ov in
                  explain_layers(profile=args.profile, cli=overlay)
                  if name != "defaults"]
        print(json.dumps({"resolved": cfg.to_dict(),
                          "mode": dispatch_mode(cfg),
                          "layers": dict(layers)}, indent=2, sort_keys=True))
        return
    run_config(cfg)


if __name__ == "__main__":
    main()
