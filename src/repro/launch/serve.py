"""Serving driver: batched greedy generation, optionally under a SwapNet
weight budget (blocks streamed through memory during inference).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --reduce smoke \
        --requests 8 --new-tokens 16
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduce 100m \
        --budget-mb 64   # weight-swapped prefill via SwapNet
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.cost_model import DelayModel
from repro.core.runtime import SwappedModel
from repro.launch.train import scale_config
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", default="smoke", choices=["smoke", "100m", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="SwapNet weight budget: stream blocks during prefill")
    args = ap.parse_args()

    cfg = scale_config(get_arch(args.arch), args.reduce)
    if not cfg.supports_decode():
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    if args.budget_mb is not None:
        budget = int(args.budget_mb * 1e6)
        with tempfile.TemporaryDirectory() as d:
            sm = SwappedModel(model, params, d, mode="snet", budget=None)
            sm.partition(budget, DelayModel(), args.requests, args.prompt_len)
            batch = {"tokens": jax.numpy.asarray(
                rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len)),
                jax.numpy.int32)}
            logits, stats = sm.forward(batch)   # warm
            sm.engine.stats.__init__()
            logits, stats = sm.forward(batch)
            sm.close()
        print(f"[serve] swapped prefill: {stats['latency_s']*1e3:.1f} ms, "
              f"peak resident {stats['peak_resident_mb']:.1f} MB "
              f"(budget {args.budget_mb} MB), "
              f"blocks={sm.plan.n_blocks}", flush=True)
        return

    engine = ServingEngine(model, params, max_len=args.max_len)
    reqs = [Request(i, list(rng.integers(0, cfg.vocab_size, args.prompt_len)),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    stats = engine.generate(reqs)   # includes compile
    reqs2 = [Request(100 + i, r.prompt, r.max_new_tokens) for i, r in enumerate(reqs)]
    stats = engine.generate(reqs2)  # warm numbers
    print(f"[serve] {args.requests} requests x {args.new_tokens} new tokens: "
          f"prefill {stats['prefill_s']*1e3:.1f} ms, "
          f"{stats['tok_per_s']:.1f} tok/s decode", flush=True)
    print(f"[serve] sample output: {reqs2[0].output[:12]}", flush=True)


if __name__ == "__main__":
    main()
