"""Serving driver: batched greedy generation, optionally under a SwapNet
weight budget (blocks streamed through memory during inference).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --reduce smoke \
        --requests 8 --new-tokens 16
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduce 100m \
        --budget-mb 64   # weight-swapped prefill via SwapNet
    PYTHONPATH=src python -m repro.launch.serve --multi qwen2.5-3b,gemma2-9b \
        --reduce smoke --budget-mb 48 --rounds 3   # shared-budget multi-tenant
    PYTHONPATH=src python -m repro.launch.serve --multi qwen2.5-3b,gemma2-9b \
        --reduce smoke --budget-mb 48 --executors 2 --priorities 1,8
        # concurrent priority-aware serving: 2 executor threads, requests
        # tagged with urgency classes 1 and 8; high-urgency requests are
        # admitted by urgency-weighted deadline and preempt low-priority
        # passes at block boundaries
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduce smoke \
        --budget-mb 16 --store quant   # int8 swap units, ~4x less swap-in I/O
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduce smoke \
        --budget-mb 16 --store quant --precision int4   # packed int4 units:
        # ~8x less swap-in I/O, quantized-resident weights stream through
        # the fused dequant-matmul kernel (swap_linear_q)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduce smoke \
        --budget-mb 24 --paged --kv-frac 0.3 --max-batch 8
        # continuous-batching decode: weight blocks and KV pages share the
        # ONE budget; each decode step streams the blocks once for the
        # whole batch, sequences admit/retire every step, page pressure
        # preempts-by-recomputation
"""
from __future__ import annotations

import argparse
import tempfile

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.cost_model import DelayModel
from repro.core.multi_model import MultiModelRuntime
from repro.core.runtime import SwappedModel
from repro.core.serving_scheduler import ServingScheduler
from repro.launch.train import scale_config
from repro.models.transformer import Model
from repro.serving.batch_engine import BatchDecodeEngine
from repro.serving.engine import (MultiModelServingEngine, Request,
                                  ServingEngine, pad_prompts)
from repro.serving.paged_kv import PagedKVCache


def _percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs, float), q)) if xs else 0.0


def _build_multi_runtime(args, workdir: str, executors: int = 1):
    """Shared --multi setup: parse archs, build + plan the shared-budget
    runtime, keep (model, params) refs for the lossless checks."""
    archs = [a.strip() for a in args.multi.split(",") if a.strip()]
    if len(archs) < 2:
        raise SystemExit("--multi wants at least two comma-separated archs")
    rt = MultiModelRuntime(int(args.budget_mb * 1e6),
                           prefetch_depth=args.prefetch_depth,
                           cache_frac=args.cache_frac,
                           store_backend=args.store,
                           precision=args.precision,
                           executors=executors)
    refs = {}
    for i, arch in enumerate(archs):
        cfg = scale_config(get_arch(arch), args.reduce)
        model = Model(cfg)
        params = model.init(jax.random.key(i))
        rt.add_model(arch, model, params, workdir)
        refs[arch] = (model, params)
    rt.plan(batch=args.requests, seq=args.prompt_len)
    return archs, rt, refs


def serve_multi_scheduled(args) -> None:
    """K concurrent executors + priority-aware preemptive scheduling over
    the shared-budget runtime (`core/serving_scheduler.py`): requests carry
    an urgency class (--priorities, assigned round-robin) and are admitted
    by urgency-weighted deadline; low-priority passes yield at block
    boundaries to high-urgency arrivals. Reports per-class p50/p99 latency,
    preemption count, and the lossless check vs each unswapped model."""
    classes = [float(p) for p in args.priorities.split(",")]
    budget = int(args.budget_mb * 1e6)
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as d:
        archs, rt, refs = _build_multi_runtime(args, d,
                                               executors=args.executors)

        batches, ref_logits = {}, {}
        for arch, (model, params) in refs.items():
            cfg = model.cfg
            reqs = [Request(i, list(rng.integers(0, cfg.vocab_size,
                                                 args.prompt_len)))
                    for i in range(args.requests)]
            batches[arch] = pad_prompts(cfg, reqs)
            out, _ = jax.jit(model.prefill)(params, batches[arch])
            ref_logits[arch] = np.asarray(out[:, -1:])
            rt.forward(arch, batches[arch])      # warm: jit compile per block

        sched = ServingScheduler(rt, preempt=True,
                                 auto_rebalance=args.rebalance)
        submitted = []
        for round_i in range(args.rounds):
            for j, arch in enumerate(archs):
                prio = classes[(round_i * len(archs) + j) % len(classes)]
                submitted.append(sched.submit(arch, batches[arch],
                                              priority=prio))
        for r in submitted:
            r.wait(timeout=600)
        sched.shutdown()
        st = rt.stats()
        rt.close()

    def _tol(arch):
        # the repo's lossless standard (see serve_multi): residual diffs are
        # XLA fusion order of per-unit vs whole-model jit, not the swap path
        return 1e-4 if refs[arch][0].cfg.dtype == "float32" else 2e-2

    exact = all(
        np.allclose(np.asarray(r.logits), ref_logits[r.model],
                    rtol=_tol(r.model), atol=_tol(r.model))
        for r in submitted
        if rt.models[r.model].store_backend != "quant")
    print(f"[serve-sched] {len(archs)} models, {args.executors} executors "
          f"under {args.budget_mb:.0f} MB: peak resident "
          f"{st['peak_resident_mb']:.1f} MB "
          f"({'OK' if st['peak_resident_mb'] * 1e6 <= budget else 'OVER'}), "
          f"lossless={exact}, preemptions={sched.preemptions}", flush=True)
    by_class = sched.latency_by_class()
    for prio in sorted(by_class, reverse=True):
        lat = [x * 1e3 for x in by_class[prio]]
        print(f"[serve-sched]   priority {prio:g}: n={len(lat)} "
              f"p50={_percentile(lat, 50):.1f} ms "
              f"p99={_percentile(lat, 99):.1f} ms", flush=True)


def serve_paged(args, cfg, model, params) -> None:
    """Swap-aware continuous-batching decode: weight blocks are planned
    against (1 - kv_frac) of the budget and the KV page pool is sized from
    the rest, BOTH charged to one ledger — growing the decode batch
    genuinely competes with weight-block residency, and page pressure
    preempts the youngest/lowest-priority sequences (recompute on
    re-admission)."""
    budget = int(args.budget_mb * 1e6)
    kv_bytes = int(budget * args.kv_frac)
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet", budget=budget,
                          prefetch_depth=args.prefetch_depth,
                          store_backend=args.store,
                          precision=args.precision)
        sm.partition(budget - kv_bytes, DelayModel(), 1, args.prompt_len)
        kv = PagedKVCache.for_budget(cfg, sm.engine.ledger, kv_bytes,
                                     page_tokens=args.page_tokens)
        be = BatchDecodeEngine(sm, kv, max_batch=args.max_batch)
        reqs = [Request(i, list(rng.integers(0, cfg.vocab_size,
                                             args.prompt_len)),
                        max_new_tokens=args.new_tokens)
                for i in range(args.requests)]
        for r in reqs:
            be.submit(r)
        be.run_all()
        st = be.stats()
        peak = sm.engine.ledger.peak
        sm.close()
    print(f"[serve-paged] {args.requests} requests x {args.new_tokens} new "
          f"tokens under {args.budget_mb:.0f} MB "
          f"(kv_frac={args.kv_frac:g}, {kv.max_pages} pages x "
          f"{kv.page_tokens} tok): {st['tok_per_s']:.2f} tok/s, "
          f"occupancy {st['mean_occupancy']*100:.0f}%, "
          f"preemptions {st['preemptions']:.0f}, "
          f"peak resident {peak/1e6:.1f} MB "
          f"({'OK' if peak <= budget else 'OVER'})", flush=True)
    print(f"[serve-paged] sample output: {reqs[0].output[:12]}", flush=True)


def serve_multi(args) -> None:
    """Two or more models interleaved under ONE weight budget: the paper's
    §6 multi-DNN scenario end-to-end. Verifies the swapped prefill logits
    stay bit-identical to each unswapped model, then reports peak residency
    vs the budget, pipeline overlap efficiency, and cache hit rate."""
    budget = int(args.budget_mb * 1e6)
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as d:
        archs, rt, refs = _build_multi_runtime(args, d)

        engine = MultiModelServingEngine(rt)
        exact = True
        fidelity = {}
        for round_i in range(args.rounds):
            for arch in archs:          # interleave tenants round-robin
                cfg = refs[arch][0].cfg
                reqs = [Request(i, list(rng.integers(0, cfg.vocab_size,
                                                     args.prompt_len)))
                        for i in range(args.requests)]
                logits = engine.prefill(arch, reqs)
                if round_i == 0:        # lossless vs the unswapped model
                    # (allclose, the repo's standard: swapping itself is
                    # byte-lossless; residual diffs are XLA fusion order of
                    # per-unit vs whole-model jit, not the swap path. The
                    # quant store is NOT lossless — its bounded error is
                    # reported as fidelity instead of asserted exact.)
                    model, params = refs[arch]
                    batch = pad_prompts(model.cfg, reqs)
                    ref, _ = jax.jit(model.prefill)(params, batch)
                    # gate on the model's EFFECTIVE backend: a quant-
                    # ineligible config fell back to the exact mmap store
                    # and must keep its lossless assertion
                    if rt.models[arch].store_backend == "quant":
                        a = np.asarray(logits, np.float64).ravel()
                        b = np.asarray(ref[:, -1:], np.float64).ravel()
                        fidelity[arch] = float(
                            a @ b / max(np.linalg.norm(a)
                                        * np.linalg.norm(b), 1e-30))
                        continue
                    tol = 1e-4 if model.cfg.dtype == "float32" else 2e-2
                    ok = bool(np.allclose(np.asarray(logits),
                                          np.asarray(ref[:, -1:]),
                                          rtol=tol, atol=tol))
                    exact = exact and ok
        st = rt.stats()
        rt.close()

    # mixed backends report BOTH signals: bounded-error fidelity for the
    # quant tenants, the lossless assertion for every exact-store tenant
    parts = []
    if fidelity:
        parts.append(f"fidelity={min(fidelity.values()):.4f}")
    if len(fidelity) < len(archs):
        parts.append(f"lossless={exact}")
    quality = " ".join(parts)
    print(f"[serve-multi] {len(archs)} models under {args.budget_mb:.0f} MB "
          f"(store={args.store}): "
          f"peak resident {st['peak_resident_mb']:.1f} MB "
          f"({'OK' if st['peak_resident_mb'] * 1e6 <= budget else 'OVER'}), "
          f"{quality}", flush=True)
    print(f"[serve-multi] cache {st['cache_resident_mb']:.1f}/"
          f"{st['cache_capacity_mb']:.1f} MB, "
          f"hit rate {st['cache_hit_rate']*100:.1f}% "
          f"({st['cache_hits']} hits / {st['cache_misses']} misses)", flush=True)
    for name, ms in st["models"].items():
        print(f"[serve-multi]   {name}: blocks={ms['n_blocks']} m={ms['m']} "
              f"store={ms['store_backend']}/{ms['precision']} "
              f"overlap_eff={ms['overlap_efficiency']*100:.1f}% "
              f"swapped {ms['bytes_swapped_mb']:.1f} MB "
              f"({ms['bytes_logical_mb']:.1f} MB logical)", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--multi", default=None,
                    help="comma-separated archs served interleaved under one "
                         "shared weight budget (requires --budget-mb)")
    ap.add_argument("--reduce", default="smoke", choices=["smoke", "100m", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--rounds", type=int, default=3,
                    help="multi-tenant round-robin passes (repeat requests "
                         "exercise the shared block cache)")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="pipeline residency m (1=serial, 2=double buffer)")
    ap.add_argument("--executors", type=int, default=1,
                    help="concurrent executor threads for --multi serving "
                         "(>1 enables the priority-aware preemptive "
                         "scheduler; each model's blocks are planned "
                         "against a 1/K budget slice so K pipelines co-fit)")
    ap.add_argument("--priorities", default="1",
                    help="comma-separated urgency classes assigned "
                         "round-robin to --multi requests (e.g. '1,8'; "
                         "higher = more urgent — admitted earlier and "
                         "preempts lower classes at block boundaries)")
    ap.add_argument("--rebalance", action="store_true",
                    help="re-split the block budget (MultiDNNScheduler "
                         "Eq. 1) whenever the queued urgency mix changes")
    ap.add_argument("--cache-frac", type=float, default=0.25,
                    help="fraction of the budget reserved for the shared "
                         "hot-block cache (multi-tenant mode)")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="SwapNet weight budget: stream blocks during prefill")
    ap.add_argument("--paged", action="store_true",
                    help="continuous-batching decode through the paged KV "
                         "cache (requires --budget-mb): weight blocks and "
                         "KV pages share one ledger, sequences admit/retire "
                         "at every decode step")
    ap.add_argument("--kv-frac", type=float, default=0.3,
                    help="fraction of --budget-mb reserved for KV pages in "
                         "--paged mode (the rest plans weight blocks)")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="tokens per KV page (one page spans all layers)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="decode batch slots for --paged continuous batching")
    ap.add_argument("--store", default="mmap",
                    choices=["mmap", "rawio", "quant", "directio"],
                    help="block-store backend: mmap (zero-copy, lossless), "
                         "rawio (read()-based ablation arm), quant (per-"
                         "channel quantized swap units kept quantized-"
                         "resident: 2-D matmul weights stream through the "
                         "fused dequant-matmul kernel, 4-8x less swap-in "
                         "I/O, bounded error), directio (O_DIRECT lossless "
                         "reads that bypass the page cache — no hidden "
                         "double-caching of swapped bytes under a tight "
                         "budget; falls back to buffered reads on "
                         "filesystems without O_DIRECT)")
    ap.add_argument("--precision", default=None, choices=["int8", "int4"],
                    help="quant-store unit precision override (default: the "
                         "arch config's swap_precision; int4 packs two "
                         "weights per byte — half the swap bytes of int8 "
                         "at a max|w[:,c]|/14 per-channel error bound)")
    args = ap.parse_args()

    if args.multi:
        if args.budget_mb is None:
            raise SystemExit("--multi requires --budget-mb")
        if args.executors > 1:
            serve_multi_scheduled(args)
        else:
            serve_multi(args)
        return
    if not args.arch:
        raise SystemExit("need --arch (single model) or --multi a,b")

    cfg = scale_config(get_arch(args.arch), args.reduce)
    if not cfg.supports_decode():
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    if args.paged:
        if args.budget_mb is None:
            raise SystemExit("--paged requires --budget-mb")
        serve_paged(args, cfg, model, params)
        return
    if args.budget_mb is not None:
        budget = int(args.budget_mb * 1e6)
        with tempfile.TemporaryDirectory() as d:
            sm = SwappedModel(model, params, d, mode="snet", budget=None,
                              prefetch_depth=args.prefetch_depth,
                              store_backend=args.store,
                              precision=args.precision)
            sm.partition(budget, DelayModel(), args.requests, args.prompt_len)
            batch = {"tokens": jax.numpy.asarray(
                rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len)),
                jax.numpy.int32)}
            logits, stats = sm.forward(batch)   # warm
            sm.engine.stats.__init__()
            logits, stats = sm.forward(batch)
            sm.close()
        print(f"[serve] swapped prefill: {stats['latency_s']*1e3:.1f} ms, "
              f"peak resident {stats['peak_resident_mb']:.1f} MB "
              f"(budget {args.budget_mb} MB), "
              f"blocks={sm.plan.n_blocks}, "
              f"store={stats['store_backend']}"
              f"/{stats['precision']}, "
              f"swapped {stats['bytes_swapped']/1e6:.1f} MB "
              f"({stats['bytes_logical']/1e6:.1f} MB logical, "
              f"{stats['bytes_resident_quantized']/1e6:.1f} MB "
              f"quantized-resident), "
              f"kernel VMEM {stats['vmem_working_set']/1e6:.2f} MB, "
              f"overlap_eff={stats['overlap_efficiency']*100:.1f}%", flush=True)
        return

    engine = ServingEngine(model, params, max_len=args.max_len)
    reqs = [Request(i, list(rng.integers(0, cfg.vocab_size, args.prompt_len)),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    stats = engine.generate(reqs)   # includes compile
    reqs2 = [Request(100 + i, r.prompt, r.max_new_tokens) for i, r in enumerate(reqs)]
    stats = engine.generate(reqs2)  # warm numbers
    print(f"[serve] {args.requests} requests x {args.new_tokens} new tokens: "
          f"prefill {stats['prefill_s']*1e3:.1f} ms, "
          f"{stats['tok_per_s']:.1f} tok/s decode", flush=True)
    print(f"[serve] sample output: {reqs2[0].output[:12]}", flush=True)


if __name__ == "__main__":
    main()
