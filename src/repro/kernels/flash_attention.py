"""flash_attention: KV-block swapping through VMEM with online softmax.

The second SwapNet-at-VMEM kernel (DESIGN.md §7): the KV cache (which for
32k/500k contexts dwarfs VMEM) is streamed block-by-block through a
double-buffered VMEM window while running (m, l, acc) statistics keep the
softmax exact — swap-in of KV block j+1 overlaps the MXU work on block j.

Supports causal masking, sliding windows (fully out-of-window KV blocks are
skipped without touching the MXU), and gemma-style logit softcap. Prefill
self-attention (Sq == Skv, positions aligned); GQA callers repeat KV heads in
the ops wrapper.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], bq: int, bk: int, n_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = kj * bk
    # block-level skip: entirely above the diagonal, or entirely out of window
    needed = True
    if causal:
        needed = k_start <= q_start + bq - 1
    if window is not None:
        needed = jnp.logical_and(needed,
                                 k_start + bk - 1 >= q_start - (window - 1))

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)

    @pl.when(kj == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: Optional[float] = None, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q,k,v: [BH, S, hd] (batch*heads collapsed). Returns [BH, S, hd]."""
    BH, S, hd = q.shape
    assert k.shape == (BH, S, hd) and v.shape == (BH, S, hd)
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = hd ** -0.5 if scale is None else scale
    n_q, n_k = S // bq, S // bk

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          softcap=softcap, bq=bq, bk=bk, n_k=n_k),
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
