"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def swap_linear_ref(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                    act: str = "none") -> jax.Array:
    r = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        r = r + b.astype(jnp.float32)
    if act == "silu":
        r = r * jax.nn.sigmoid(r)
    elif act == "gelu":
        r = jax.nn.gelu(r, approximate=True)
    return r.astype(x.dtype)


def dequant_int8_ref(values: jax.Array, scales: jax.Array,
                     out_dtype=jnp.float32) -> jax.Array:
    """values [R, C] int8, scales [C] fp32 -> values * scales[None, :]."""
    return (values.astype(jnp.float32)
            * scales.astype(jnp.float32)[None, :]).astype(out_dtype)


def unpack_int4_ref(carrier: jax.Array, rows: int) -> jax.Array:
    """Traceable inverse of dequant.pack_int4: [Rp, C] int8 carrier ->
    [rows, C] sign-extended values (even row = low nibble, odd = high)."""
    qi = carrier.astype(jnp.int32)
    low = jnp.right_shift(jnp.left_shift(qi, 28), 28)   # sign-extend nibble
    high = jnp.right_shift(qi, 4)                       # arithmetic shift
    out = jnp.stack([low, high], axis=1).reshape(2 * carrier.shape[0],
                                                 carrier.shape[1])
    return out[:rows].astype(jnp.int8)


def swap_linear_q_ref(x: jax.Array, qw: jax.Array, scales: jax.Array,
                      b: Optional[jax.Array] = None, act: str = "none",
                      bits: int = 8) -> jax.Array:
    """Oracle for the fused dequant-matmul: dequantize the whole weight,
    then the plain swap_linear math. qw is [K, N] int8 (bits=8) or the
    [ceil(K/2), N] packed carrier (bits=4); scales is [N] fp32."""
    K = x.shape[-1]
    vals = unpack_int4_ref(qw, K) if bits == 4 else qw
    w = vals.astype(jnp.float32) * scales.astype(jnp.float32)[None, :]
    r = jnp.dot(x.astype(jnp.float32), w)
    if b is not None:
        r = r + b.astype(jnp.float32)
    if act == "silu":
        r = r * jax.nn.sigmoid(r)
    elif act == "gelu":
        r = jax.nn.gelu(r, approximate=True)
    return r.astype(x.dtype)


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w_log: jax.Array,
             u: jax.Array) -> jax.Array:
    """Literal per-step WKV6 recurrence. r,k,v,w_log: [BH,S,hd]; u: [BH,hd]."""
    BH, S, hd = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w_log.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(S_state, xs):
        rt, kt, vt, lwt = xs
        bonus = jnp.sum(rt * (uf * kt), axis=-1, keepdims=True)
        y = jnp.einsum("bk,bkv->bv", rt, S_state) + bonus * vt
        S_new = jnp.exp(lwt)[..., None] * S_state + kt[..., None] * vt[:, None, :]
        return S_new, y

    S0 = jnp.zeros((BH, hd, hd), jnp.float32)
    xs = (rf.swapaxes(0, 1), kf.swapaxes(0, 1), vf.swapaxes(0, 1),
          wf.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.swapaxes(0, 1).astype(r.dtype)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        page_table: jax.Array, seq_lens: jax.Array, *,
                        scale: Optional[float] = None,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jax.Array:
    """Gather-then-attend oracle for kernels/paged_attention: materialize
    each sequence's pages contiguously ([B, NP*T, KV, hd]) and run masked
    single-query attention. q: [B, H, hd]; returns [B, H, hd]."""
    B, H, hd = q.shape
    P, T, KV, _ = k_pages.shape
    G = H // KV
    NP = page_table.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    k = k_pages[page_table].reshape(B, NP * T, KV, hd)
    v = v_pages[page_table].reshape(B, NP * T, KV, hd)
    qf = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    tok = jnp.arange(NP * T)[None, :]                     # [1, S]
    q_pos = (seq_lens - 1)[:, None]                       # [B, 1]
    mask = tok < seq_lens[:, None]                        # causal: q is last
    if window is not None:
        mask &= (q_pos - tok) < window
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: Optional[float] = None, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jax.Array:
    BH, S, hd = q.shape
    scale = hd ** -0.5 if scale is None else scale
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)
