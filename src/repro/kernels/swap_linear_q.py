"""swap_linear_q: fused dequant-matmul weight streaming (ROADMAP item (f)).

The quant store's swap-in used to dequantize a unit to fp BEFORE the
weight-streaming matmul ran, so the HBM->VMEM DMA and the double-buffered
VMEM weight window still paid full precision. This kernel moves the dequant
INSIDE the k-loop: the streamed weight tile stays int8 (or int4, packed
two-per-byte into an int8 carrier), each (bk, bn) tile is unpacked /
sign-extended in registers as the MXU consumes it, and the per-channel
scales are applied ONCE to the fp32 accumulator at flush — ``s_n`` factors
out of the k-sum, so the hot loop is a plain integer-valued matmul. The
weight window therefore shrinks 2x (int8) / 4x (int4) vs a bf16 stream and
the DMA moves only quantized bytes (see swap_linear.vmem_bytes /
weight_stream_bytes with ``w_bits``).

int4 carrier layout (kernels/dequant.pack_int4, bit-exact contract): row
pair (2r, 2r+1) of the logical [K, N] weight shares carrier row r — even
row in the low nibble, odd row in the high nibble, two's-complement
sign-extended on unpack. Because packing pairs ADJACENT rows, a
(bk/2, bn) carrier tile at grid row k covers exactly logical rows
[k*bk, (k+1)*bk): tiles unpack independently (bk is forced even).

Error contract (asserted in tests/test_fused_quant.py): the output matches
``swap_linear(dequant(qw))`` up to fp accumulation order — both use an fp32
accumulator; this kernel applies the scale once at flush instead of per
element — i.e. allclose at ~1e-5 for fp32 activations, ~2e-2 for bf16. The
quantization error itself is the store's documented bound vs the original
weight: ``|ŵ - w| <= max|w[:, c]| / 254`` (int8) or ``/ 14`` (int4) per
channel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import unpack_int4_ref
from repro.kernels.swap_linear import _pad2, pad_up


def _qkernel(x_ref, qw_ref, s_ref, b_ref, o_ref, acc_ref, *, n_k: int,
             act: str, bits: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if bits == 4:       # (bk/2, bn) carrier -> (bk, bn), shared unpacker
        q = qw_ref[...]
        w = unpack_int4_ref(q, 2 * q.shape[0])
    else:
        w = qw_ref[...]
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w.astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        # per-channel scale factors out of the k-sum: applied once here
        r = (acc_ref[...] * s_ref[...].astype(jnp.float32)
             + b_ref[...].astype(jnp.float32))
        if act == "silu":
            r = r * jax.nn.sigmoid(r)
        elif act == "gelu":
            r = jax.nn.gelu(r, approximate=True)
        o_ref[...] = r.astype(o_ref.dtype)


def swap_linear_q(x: jax.Array, qw: jax.Array, scales: jax.Array,
                  b: Optional[jax.Array] = None, *, bits: int = 8,
                  act: str = "none", block_m: int = 256, block_n: int = 256,
                  block_k: int = 512, interpret: bool = False) -> jax.Array:
    """y = act(x @ (qw * scales) + b), dequantized inside the k-loop.

    x [M, K]; qw [K, N] int8 values (bits=8) or the [ceil(K/2), N] packed
    int8 carrier (bits=4); scales [N] fp32 per output channel. Shapes pad up
    to block multiples like swap_linear (zero carrier bytes unpack to zero
    weights, so padded K-rows contribute nothing).
    """
    assert bits in (8, 4), bits
    M, K = x.shape
    pack = 2 if bits == 4 else 1
    Kq, N = qw.shape
    assert Kq == -(-K // pack), (x.shape, qw.shape, bits)
    assert scales.shape == (N,), (scales.shape, N)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    if bits == 4:
        bk = max(2, bk - (bk % 2))      # carrier tiles need even bk
    Mp, Np, Kp = pad_up(M, bm), pad_up(N, bn), pad_up(K, bk)
    if b is None:
        b = jnp.zeros((N,), x.dtype)
    x = _pad2(x, Mp, Kp)
    qw = _pad2(qw, Kp // pack, Np)
    s = _pad2(scales.reshape(1, N).astype(jnp.float32), 1, Np)
    b = _pad2(b.reshape(1, N), 1, Np)
    n_m, n_n, n_k = Mp // bm, Np // bn, Kp // bk

    out = pl.pallas_call(
        functools.partial(_qkernel, n_k=n_k, act=act, bits=bits),
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),        # acts
            pl.BlockSpec((bk // pack, bn),
                         lambda i, j, k: (k, j)),                  # q stream
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),         # scales
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),         # bias
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, qw, s, b)
    return out[:M, :N] if (Mp, Np) != (M, N) else out
