"""paged_attention: single-token decode attention through a page table.

The paged companion to kernels/flash_attention: instead of a contiguous
[B, S, KV, hd] cache, K/V live in a shared pool of fixed-size token pages
([P, T, KV, hd], see serving/paged_kv.py) and each sequence owns an ordered
page list. The kernel gathers pages through the SCALAR-PREFETCHED page table
(``pltpu.PrefetchScalarGridSpec``): the index map of the K/V operands reads
``page_table[b, j]`` to pick which physical page the next grid step streams
into VMEM, so the gather costs nothing over the contiguous layout — the DMA
engine simply follows the indirection.

One query token per sequence (decode), grid (B, KV, n_pages) with the page
axis innermost: online (m, l, acc) statistics accumulate across a sequence's
pages exactly like flash_attention accumulates across KV blocks. Slots at or
beyond ``seq_lens[b]`` are masked (pages are zero-padded, the page table is
padded with page 0 — both masked, never read into the softmax), causality is
implicit (the query IS the last cached position), sliding windows skip
fully-out-of-window pages without touching the MXU, and gemma-style logit
softcap is applied pre-masking as in the contiguous kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(pt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, window: Optional[int], softcap: Optional[float],
            page_tokens: int, n_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    T = page_tokens

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = sl_ref[b]
    q_pos = seq_len - 1                      # the query is the newest token
    # page-level skip: entirely past the sequence, or entirely out of window
    needed = j * T < seq_len
    if window is not None:
        needed = jnp.logical_and(needed,
                                 j * T + T - 1 >= q_pos - (window - 1))

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [G, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # [T, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        tok = j * T + jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)
        mask = tok < seq_len                          # causal: q IS the last
        if window is not None:
            mask = jnp.logical_and(mask, (q_pos - tok) < window)
        s = jnp.where(mask, s, NEG_INF)               # [G, T] via broadcast

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v_ref[0, :, 0, :].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(j == n_pages - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, seq_lens: jax.Array, *,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: bool = False) -> jax.Array:
    """q: [B, H, hd] (one decode token per sequence); k/v_pages:
    [P, T, KV, hd] shared page pools; page_table: [B, NP] int32 physical page
    ids (pad with 0 past a sequence's pages); seq_lens: [B] int32 tokens
    valid per sequence (the query token included). Returns [B, H, hd]."""
    B, H, hd = q.shape
    P, T, KV, hd_k = k_pages.shape
    assert v_pages.shape == (P, T, KV, hd_k) and hd == hd_k, \
        (q.shape, k_pages.shape, v_pages.shape)
    assert H % KV == 0, (H, KV)
    G = H // KV
    NP = page_table.shape[1]
    assert page_table.shape == (B, NP) and seq_lens.shape == (B,)
    scale = hd ** -0.5 if scale is None else scale

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window,
                          softcap=softcap, page_tokens=T, n_pages=NP),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, NP),
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, kv, j, pt, sl: (b, kv, 0, 0)),
                pl.BlockSpec((1, T, 1, hd),
                             lambda b, kv, j, pt, sl: (pt[b, j], 0, kv, 0)),
                pl.BlockSpec((1, T, 1, hd),
                             lambda b, kv, j, pt, sl: (pt[b, j], 0, kv, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, kv, j, pt, sl: (b, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),     # running max
                pltpu.VMEM((G, 1), jnp.float32),     # running sum
                pltpu.VMEM((G, hd), jnp.float32),    # output accumulator
            ]),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      q.reshape(B, KV, G, hd), k_pages, v_pages)
    return out.reshape(B, H, hd)
