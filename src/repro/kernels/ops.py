"""jit'd public wrappers for the Pallas kernels.

On TPU the real kernels run; on CPU (this container, and any host-only test
run) the wrappers run the kernels in interpret mode for small shapes or fall
back to the jnp oracle — dry-run lowering for the host platform never embeds
a Mosaic custom-call.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dequant import dequant_int8 as _dequant_int8
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.swap_linear import swap_linear as _swap_linear
from repro.kernels.swap_linear_q import swap_linear_q as _swap_linear_q


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


@functools.partial(jax.jit, static_argnames=("act", "interpret"))
def swap_linear(x, w, b=None, *, act: str = "none",
                interpret: Optional[bool] = None):
    """Weight-streaming linear; interpret=None -> auto (TPU real, CPU ref)."""
    if interpret is None:
        if _on_tpu():
            return _swap_linear(x, w, b, act=act, interpret=False)
        return _ref.swap_linear_ref(x, w, b, act=act)
    return _swap_linear(x, w, b, act=act, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "act", "interpret"))
def swap_linear_q(x, qw, scales, b=None, *, bits: int = 8,
                  act: str = "none", interpret: Optional[bool] = None):
    """Fused dequant-matmul weight stream (int8 / packed int4);
    interpret=None -> auto (TPU real, CPU ref)."""
    if interpret is None:
        if _on_tpu():
            return _swap_linear_q(x, qw, scales, b, bits=bits, act=act,
                                  interpret=False)
        return _ref.swap_linear_q_ref(x, qw, scales, b, act=act, bits=bits)
    return _swap_linear_q(x, qw, scales, b, bits=bits, act=act,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def dequant_int8(values, scales, out_dtype=jnp.float32, *,
                 interpret: Optional[bool] = None):
    """Dequant-on-swap-in; interpret=None -> auto (TPU real, CPU ref)."""
    if interpret is None:
        if _on_tpu():
            return _dequant_int8(values, scales, out_dtype, interpret=False)
        return _ref.dequant_int8_ref(values, scales, out_dtype)
    return _dequant_int8(values, scales, out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "softcap", "interpret"))
def flash_attention(q, k, v, *, scale=None, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: Optional[bool] = None):
    if interpret is None:
        if _on_tpu():
            return _flash(q, k, v, scale=scale, causal=causal, window=window,
                          softcap=softcap, interpret=False)
        return _ref.flash_attention_ref(q, k, v, scale=scale, causal=causal,
                                        window=window, softcap=softcap)
    return _flash(q, k, v, scale=scale, causal=causal, window=window,
                  softcap=softcap, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "scale", "window", "softcap", "interpret"))
def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                    scale=None, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: Optional[bool] = None):
    """Single-token decode attention through a page table (the paged KV
    serving path); interpret=None -> auto (TPU real, CPU ref)."""
    if interpret is None:
        if _on_tpu():
            return _paged(q, k_pages, v_pages, page_table, seq_lens,
                          scale=scale, window=window, softcap=softcap,
                          interpret=False)
        return _ref.paged_attention_ref(q, k_pages, v_pages, page_table,
                                        seq_lens, scale=scale, window=window,
                                        softcap=softcap)
    return _paged(q, k_pages, v_pages, page_table, seq_lens, scale=scale,
                  window=window, softcap=softcap, interpret=interpret)
