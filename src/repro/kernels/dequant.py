"""Per-channel quantizers (int8 + packed int4) and the device dequant kernel.

The QuantizedStore backend writes swap units as quantized values + one fp32
scale per output channel (~4x fewer stored bytes than fp32 at int8, ~8x at
int4). Swap-in then transfers only the quantized payload host->device and
reconstructs the fp parameters THERE — the dequant multiply rides the H2D
DMA the swap-in pays anyway, so the host-side critical path does no extra
work per byte saved. (The fused path, kernels/swap_linear_q.py, goes one
step further and never reconstructs fp at all.)

Layout: values are [R, C] int8 where C is the channel (last) axis of the
original tensor and R the flattened rest; ``scales`` is [C] fp32. Output is
``out[r, c] = values[r, c] * scales[c]`` cast to the target dtype — a pure
VPU elementwise kernel, gridded over row blocks so one block of the unit
streams through VMEM while the next transfers (same double-buffered shape as
swap_linear's weight stream).

int4 carrier layout (``pack_int4`` / ``unpack_int4``, bit-exact contract
asserted in tests): two 4-bit two's-complement values share one int8 carrier
byte — row pair (2r, 2r+1) of the logical [R, C] value grid maps to carrier
row r with the EVEN row in the low nibble and the ODD row in the high
nibble. Odd R pads one zero row. Packing along rows (not channels) keeps the
per-channel scales axis intact and lets a (bk/2, bn) carrier tile of the
fused matmul unpack independently of its neighbours.

Error bounds (documented contract, asserted in tests): quantization is
symmetric round-to-nearest, so round-tripping a tensor x reproduces it
within ``|x̂ - x| <= scale_c / 2`` elementwise — ``max|x[:, c]| / 254`` per
channel at int8 (127 steps), ``max|x[:, c]| / 14`` at int4 (7 steps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# int8 VMEM tiling is (32, 128); keep row blocks a multiple of 32.
_BLOCK_R = 256


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def dequant_int8(values: jax.Array, scales: jax.Array,
                 out_dtype=jnp.float32, *, block_r: int = _BLOCK_R,
                 interpret: bool = False) -> jax.Array:
    """values [R, C] int8, scales [C] fp32 -> [R, C] out_dtype."""
    R, C = values.shape
    assert scales.shape == (C,), (values.shape, scales.shape)
    br = min(block_r, R)
    pad = (-R) % br
    if pad:                       # ragged tail: pad rows, slice after
        values = jnp.concatenate(
            [values, jnp.zeros((pad, C), values.dtype)], axis=0)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=((R + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, C), lambda i: (i, 0)),          # quantized rows
            pl.BlockSpec((1, C), lambda i: (0, 0)),           # channel scales
        ],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R + pad, C), out_dtype),
        interpret=interpret,
    )(values, scales.reshape(1, C))
    return out[:R] if pad else out


def _channel_grid(arr: np.ndarray) -> np.ndarray:
    x = np.asarray(arr, np.float32)
    return x.reshape(-1, x.shape[-1]) if x.ndim >= 2 else x.reshape(1, -1)


def quantize_int8(arr: np.ndarray):
    """Build-time host quantizer: symmetric per-channel int8.

    Channels are the LAST axis (output features of (in, out) matmuls and of
    HWIO convs); the rest flattens to rows. Returns (values int8 [R, C],
    scales fp32 [C]). Zero channels get scale 1.0 so dequant is exact there.
    """
    x2 = _channel_grid(arr)
    amax = np.max(np.abs(x2), axis=0)
    scales = np.where(amax > 0.0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(x2 / scales[None, :]), -127, 127).astype(np.int8)
    return q, scales


def quantize_int4(arr: np.ndarray):
    """Build-time host quantizer: symmetric per-channel int4, packed.

    Same channel convention as :func:`quantize_int8` but 7 steps per side,
    and the values come back packed two-per-byte (see module docstring for
    the carrier layout). Returns (carrier int8 [ceil(R/2), C], scales fp32
    [C]). Round-trip error bound: ``max|x[:, c]| / 14`` per channel.
    """
    x2 = _channel_grid(arr)
    amax = np.max(np.abs(x2), axis=0)
    scales = np.where(amax > 0.0, amax / 7.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(x2 / scales[None, :]), -7, 7).astype(np.int8)
    return pack_int4(q), scales


def pack_int4(q: np.ndarray) -> np.ndarray:
    """[R, C] int4-valued int8 -> [ceil(R/2), C] int8 carrier (two's
    complement nibbles: even row -> low, odd row -> high; odd R pads 0)."""
    R, C = q.shape
    if R % 2:
        q = np.concatenate([q, np.zeros((1, C), np.int8)], axis=0)
    u = q.view(np.uint8) & 0xF
    return ((u[1::2] << 4) | u[0::2]).view(np.int8)


def unpack_int4(carrier: np.ndarray, rows: int) -> np.ndarray:
    """Host-side inverse of :func:`pack_int4`: [Rp, C] carrier -> [rows, C]
    sign-extended int8 values (the zero pad row, if any, is sliced off).

    This runs on the swap-in loader thread for every lazily-dequantized
    leaf (see quantized_store), so it is written to touch the carrier a
    minimal number of times: arithmetic right-shift sign-extends the high
    nibble directly, and ``(u << 4) >> 4`` sign-extends the low one — two
    strided writes into the output instead of mask/compare temporaries.
    """
    s = carrier.view(np.int8)
    out = np.empty((2 * s.shape[0], s.shape[1]), np.int8)
    np.right_shift(s, 4, out=out[1::2])                     # high nibble
    low = (carrier.view(np.uint8) << 4).view(np.int8)
    np.right_shift(low, 4, out=out[0::2])                   # low nibble
    return out[:rows]
