"""dequant_int8: per-channel int8 -> float dequantization on device.

The QuantizedStore backend writes swap units as int8 values + one fp32 scale
per output channel (~4x fewer stored bytes than fp32). Swap-in then transfers
only the quantized payload host->device and reconstructs the fp parameters
THERE — the dequant multiply rides the H2D DMA the swap-in pays anyway, so
the host-side critical path does no extra work per byte saved.

Layout: values are [R, C] int8 where C is the channel (last) axis of the
original tensor and R the flattened rest; ``scales`` is [C] fp32. Output is
``out[r, c] = values[r, c] * scales[c]`` cast to the target dtype — a pure
VPU elementwise kernel, gridded over row blocks so one block of the unit
streams through VMEM while the next transfers (same double-buffered shape as
swap_linear's weight stream).

Error bound (documented contract, asserted in tests): quantization is
symmetric round-to-nearest at 127 steps per channel, so round-tripping a
tensor x reproduces it within ``|x̂ - x| <= scale_c / 2`` elementwise, i.e.
``max|x[:, c]| / 254`` per channel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# int8 VMEM tiling is (32, 128); keep row blocks a multiple of 32.
_BLOCK_R = 256


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def dequant_int8(values: jax.Array, scales: jax.Array,
                 out_dtype=jnp.float32, *, block_r: int = _BLOCK_R,
                 interpret: bool = False) -> jax.Array:
    """values [R, C] int8, scales [C] fp32 -> [R, C] out_dtype."""
    R, C = values.shape
    assert scales.shape == (C,), (values.shape, scales.shape)
    br = min(block_r, R)
    pad = (-R) % br
    if pad:                       # ragged tail: pad rows, slice after
        values = jnp.concatenate(
            [values, jnp.zeros((pad, C), values.dtype)], axis=0)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=((R + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, C), lambda i: (i, 0)),          # quantized rows
            pl.BlockSpec((1, C), lambda i: (0, 0)),           # channel scales
        ],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R + pad, C), out_dtype),
        interpret=interpret,
    )(values, scales.reshape(1, C))
    return out[:R] if pad else out


def quantize_int8(arr: np.ndarray):
    """Build-time host quantizer: symmetric per-channel int8.

    Channels are the LAST axis (output features of (in, out) matmuls and of
    HWIO convs); the rest flattens to rows. Returns (values int8 [R, C],
    scales fp32 [C]). Zero channels get scale 1.0 so dequant is exact there.
    """
    x = np.asarray(arr, np.float32)
    x2 = x.reshape(-1, x.shape[-1]) if x.ndim >= 2 else x.reshape(1, -1)
    amax = np.max(np.abs(x2), axis=0)
    scales = np.where(amax > 0.0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(x2 / scales[None, :]), -127, 127).astype(np.int8)
    return q, scales
