"""wkv6: chunked RWKV6 (Finch) recurrence as a Pallas TPU kernel.

The WKV state S [hd_k, hd_v] is the resident working set (VMEM scratch); the
sequence streams through in chunks of Q — the same swap-a-block-through-a-
window structure as the other kernels, here over TIME. Data-dependent
per-channel decay is handled in log space with the chunk-local factorization
(see models/ssm.py): all decay ratios inside a chunk are bounded by
exp(Q * |W_LOG_MIN|), which fits fp32 for Q <= 16.

Grid: (B*H, S/Q) — the chunk axis is sequential per head, carrying the state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

RWKV_CHUNK = 16


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *,
            q: int, hd: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # [Q, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = w_ref[0].astype(jnp.float32)         # per-step log decay (<= 0)
    u = u_ref[0].astype(jnp.float32)          # [1, hd] bonus

    l = jnp.cumsum(lw, axis=0)                # [Q, hd]
    lprev = l - lw
    r_dec = r * jnp.exp(lprev)
    k_inv = k * jnp.exp(-l)
    A = jax.lax.dot_general(r_dec, k_inv, (((1,), (1,)), ((), ())))  # [Q, Q]
    idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    A = jnp.where(idx > jdx, A, 0.0)          # strict lower triangle
    bonus = jnp.sum(r * (u * k), axis=1, keepdims=True)              # [Q, 1]
    S = state_ref[...]
    y = (jnp.dot(A, v, preferred_element_type=jnp.float32)
         + bonus * v
         + jnp.dot(r_dec, S, preferred_element_type=jnp.float32))
    k_tail = k * jnp.exp(l[-1:] - l)
    state_ref[...] = (jnp.exp(l[-1])[:, None] * S
                      + jax.lax.dot_general(k_tail, v, (((0,), (0,)), ((), ()))))
    o_ref[0] = y.astype(o_ref.dtype)


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w_log: jax.Array,
         u: jax.Array, *, chunk: int = RWKV_CHUNK,
         interpret: bool = False) -> jax.Array:
    """r,k,v,w_log: [BH, S, hd] (w_log = per-step log decay, clamped <= 0);
    u: [BH, hd] bonus. Returns y [BH, S, hd]."""
    BH, S, hd = r.shape
    q = min(chunk, S)
    assert S % q == 0, (S, q)
    n_c = S // q

    return pl.pallas_call(
        functools.partial(_kernel, q=q, hd=hd),
        grid=(BH, n_c),
        in_specs=[
            pl.BlockSpec((1, q, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, q, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w_log, u)
