"""QuantizedTensor: the quantized-RESIDENT form of a swapped weight.

PR 2's QuantizedStore cut storage->host bytes ~4x but still materialized a
full fp tensor at swap-in, so device memory and the matmul weight stream
paid full precision. A :class:`QuantizedTensor` is what the store hands the
engine instead when eager dequant is off: the int8 values (or packed int4
carrier) plus the per-channel fp32 scales, as device arrays. Linear
consumers (``models/layers.linear``: MLP in/out, attention qkv/output
projections, shared experts, the LM head) feed it straight to the fused
dequant-matmul kernel (kernels/swap_linear_q.py) so fp never exists for
those weights; every other consumer (conv, einsum expert stacks,
embeddings, SSM input mixes) dequantizes on device at use
(:meth:`dequant` / :func:`materialize`) — the documented fallback.

Registered as a pytree (children: values + scales; aux: logical shape,
dtype, bits) so it passes through jit / tree transforms; tree maps over
parameter trees that must treat it atomically use
``is_leaf=lambda x: isinstance(x, QuantizedTensor)``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# param keys whose consumers route through models/layers.linear — these may
# stay quantized-resident; everything else dequantizes at use (cast_unit_
# params). Covers MLP in/out, attention qkv/out projections, and the head.
FUSED_WEIGHT_KEYS = frozenset({"wi", "wi0", "wi1", "wo", "wq", "wk", "wv",
                               "lm_head"})


@jax.tree_util.register_pytree_node_class
class QuantizedTensor:
    """Per-channel symmetric-quantized tensor (int8, or int4 packed
    two-per-byte into an int8 carrier — see kernels/dequant.pack_int4).

    ``q``      — [R, C] int8 values (bits=8) or [ceil(R/2), C] carrier
                 (bits=4), C = channels = last axis of ``shape``;
    ``scales`` — [C] fp32;
    ``shape``/``dtype`` — the logical tensor this dequantizes back to;
    ``bits``   — 8 or 4.
    """

    __slots__ = ("q", "scales", "shape", "dtype", "bits")

    def __init__(self, q, scales, shape: Tuple[int, ...], dtype: str,
                 bits: int = 8):
        assert bits in (8, 4), bits
        self.q = q
        self.scales = scales
        self.shape = tuple(shape)
        self.dtype = dtype
        self.bits = bits

    # ------------------------------------------------------------ pytree
    def tree_flatten(self):
        return (self.q, self.scales), (self.shape, self.dtype, self.bits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    # ------------------------------------------------------------ sizes
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def rows(self) -> int:
        """Logical rows of the channel grid (prod of all but the last axis)."""
        return math.prod(self.shape[:-1]) if len(self.shape) > 1 else 1

    @property
    def nbytes(self) -> int:
        """Resident cost: quantized payload + scales (what the ledger and
        the VMEM weight stream actually hold)."""
        return int(self.q.nbytes) + int(self.scales.nbytes)

    @property
    def logical_nbytes(self) -> int:
        return math.prod(self.shape) * jnp.dtype(self.dtype).itemsize

    # ------------------------------------------------------------ dequant
    def dequant(self) -> jax.Array:
        """On-device reconstruction to the logical shape/dtype (the
        dequant-then-dense fallback for non-matmul consumers)."""
        from repro.kernels.ops import dequant_int8
        from repro.kernels.ref import unpack_int4_ref
        vals = self.q
        if self.bits == 4:
            vals = unpack_int4_ref(vals, self.rows)
        out = dequant_int8(vals, self.scales, jnp.dtype(self.dtype).type)
        return out.reshape(self.shape)

    def __repr__(self) -> str:
        return (f"QuantizedTensor(int{self.bits}, shape={self.shape}, "
                f"dtype={self.dtype})")


def is_quantized(x) -> bool:
    return isinstance(x, QuantizedTensor)


def materialize(x, dtype: Optional[jnp.dtype] = None):
    """Leaf -> device array: dequantize QuantizedTensors, pass arrays
    through; optionally cast floating leaves to ``dtype``."""
    x = x.dequant() if isinstance(x, QuantizedTensor) else jnp.asarray(x)
    if dtype is not None and jnp.issubdtype(x.dtype, jnp.floating):
        x = x.astype(dtype)
    return x


def materialize_tree(tree, dtype: Optional[jnp.dtype] = None):
    """Dequantize every QuantizedTensor leaf of a param tree."""
    return jax.tree.map(lambda a: materialize(a, dtype), tree,
                        is_leaf=is_quantized)


def cast_unit_params(uparams, dtype):
    """Compute-dtype cast for one swapped unit that KEEPS fused-routable
    weights quantized: 2-D matmul weights whose consumers call
    ``layers.linear`` — MLP in/out, attention qkv/output projections,
    shared experts (key in :data:`FUSED_WEIGHT_KEYS`) — stay
    :class:`QuantizedTensor` and stream through ``swap_linear_q``;
    everything else (3-D expert stacks, MLA down/up projections, SSM input
    mixes, norms) follows the seed's cast — dequantized on device, floats
    cast to ``dtype``.
    """
    from repro.compat import tree_flatten_with_path, tree_unflatten
    flat, treedef = tree_flatten_with_path(uparams, is_leaf=is_quantized)
    leaves = []
    for path, leaf in flat:
        if isinstance(leaf, QuantizedTensor):
            key = getattr(path[-1], "key", None) if path else None
            if leaf.ndim == 2 and key in FUSED_WEIGHT_KEYS:
                leaves.append(leaf)
                continue
            leaf = leaf.dequant()
        a = jnp.asarray(leaf)
        if jnp.issubdtype(a.dtype, jnp.floating):
            a = a.astype(dtype)
        leaves.append(a)
    return tree_unflatten(treedef, leaves)
