"""swap_linear: weight-streaming matmul — SwapNet's zero-copy swap at VMEM.

TPU adaptation of the paper's core move (DESIGN.md §2): compute a layer whose
weight matrix exceeds the fast-memory budget by streaming weight *blocks*
through a double-buffered VMEM window. The Pallas grid pipeline issues the
HBM->VMEM DMA for tile (k+1) while the MXU consumes tile k — exactly the
paper's "swap-in of block i+1 overlaps execution of block i" (m = 2), with
hardware DMA as the dedicated swap channel and no intermediate copies.

VMEM working set (the "memory budget b", see :func:`vmem_bytes`):
    2 * (bm*bk*itemsize + bk*bn*w_bits/8 + bn*itemsize)   (double-buffered
                                                           inputs; the weight
                                                           window streams at
                                                           w_bits per element)
    + bm*bn*4                                             (fp32 accumulator)
    + 2*bn*4 when w_bits < fp                             (per-channel scales)
For the fp path here w_bits == 8*itemsize; the fused quantized path
(kernels/swap_linear_q.py) streams the SAME grid at w_bits = 8 (int8) or 4
(packed int4), shrinking the weight window 2x / 4x vs bf16 and moving only
quantized bytes HBM->VMEM. Block shapes default to MXU-aligned multiples of
128. Shapes that do not divide the block sizes are zero-padded up to the
next multiple and the output is sliced back — odd-shaped heads (vocab
projections) take the streamed path instead of falling back to dense.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        r = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if act == "silu":
            r = r * jax.nn.sigmoid(r)
        elif act == "gelu":
            r = jax.nn.gelu(r, approximate=True)
        o_ref[...] = r.astype(o_ref.dtype)


def pad_up(n: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= n."""
    return -(-n // mult) * mult


def _pad2(a: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-D array up to [rows, cols] (no-op when already there)."""
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr or pc:
        a = jnp.pad(a, ((0, pr), (0, pc)))
    return a


def swap_linear(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                *, act: str = "none", block_m: int = 256, block_n: int = 256,
                block_k: int = 512, interpret: bool = False) -> jax.Array:
    """y = act(x @ w + b). x [M,K], w [K,N] (streamed), b [N] or None.

    M/N/K need not divide the block sizes: inputs are zero-padded up to the
    next block multiple and the [M, N] output sliced back out (zero K-columns
    contribute nothing to the k-sum; padded M rows / N cols are discarded).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    Mp, Np, Kp = pad_up(M, bm), pad_up(N, bn), pad_up(K, bk)
    if b is None:
        b = jnp.zeros((N,), x.dtype)
    x = _pad2(x, Mp, Kp)
    w = _pad2(w, Kp, Np)
    b = _pad2(b.reshape(1, N), 1, Np)
    n_m, n_n, n_k = Mp // bm, Np // bn, Kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, act=act),
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # activations
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # weight stream
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),    # bias
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, b)
    return out[:M, :N] if (Mp, Np) != (M, N) else out


def vmem_bytes(bm: int, bn: int, bk: int, itemsize: int = 2,
               w_bits: Optional[int] = None) -> int:
    """The VMEM budget a (bm, bn, bk) tiling claims (roofline notes).

    ``w_bits`` is the bit-width of the streamed weight elements: default
    ``8 * itemsize`` (an fp stream at the activation itemsize, the plain
    swap_linear path); 8 for int8 units, 4 for packed int4 — the fused
    swap_linear_q path, whose double-buffered weight window shrinks
    accordingly and adds one (1, bn) fp32 scales row per buffer.
    """
    if w_bits is None:
        w_bits = 8 * itemsize
    w_bytes = bk * bn * w_bits // 8
    scales = 2 * bn * 4 if w_bits < 8 * itemsize else 0
    return (2 * (bm * bk * itemsize + w_bytes + bn * itemsize)
            + scales + bm * bn * 4)


def weight_stream_bytes(M: int, K: int, N: int, *, block_m: int = 256,
                        block_n: int = 256, block_k: int = 512,
                        w_bits: int = 16) -> int:
    """HBM->VMEM weight-stream traffic of one swap_linear/_q call.

    Every (bk, bn) weight tile is DMA'd once per M-row block, so the stream
    moves ``ceil(M/bm) * Kp * Np * w_bits/8`` bytes (padded shapes);
    quantized streams add one (1, bn) fp32 scales row per (j, k) tile visit.
    This is the per-kernel figure the fused path shrinks 2x (int8) to 4x
    (int4) vs a bf16 stream at equal tile shapes.
    """
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    if w_bits == 4:
        bk = max(2, bk - (bk % 2))
    Mp, Np, Kp = pad_up(M, bm), pad_up(N, bn), pad_up(K, bk)
    n_m = Mp // bm
    total = n_m * Kp * Np * w_bits // 8
    if w_bits in (4, 8):
        total += n_m * (Np // bn) * (Kp // bk) * bn * 4     # scales tiles
    return total
