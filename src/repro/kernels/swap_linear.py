"""swap_linear: weight-streaming matmul — SwapNet's zero-copy swap at VMEM.

TPU adaptation of the paper's core move (DESIGN.md §2): compute a layer whose
weight matrix exceeds the fast-memory budget by streaming weight *blocks*
through a double-buffered VMEM window. The Pallas grid pipeline issues the
HBM->VMEM DMA for tile (k+1) while the MXU consumes tile k — exactly the
paper's "swap-in of block i+1 overlaps execution of block i" (m = 2), with
hardware DMA as the dedicated swap channel and no intermediate copies.

VMEM working set (the "memory budget b"):
    2 * (bm*bk + bk*bn + bn) * itemsize   (double-buffered inputs)
    + bm*bn*4                             (fp32 accumulator scratch)
Block shapes default to MXU-aligned multiples of 128.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        r = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if act == "silu":
            r = r * jax.nn.sigmoid(r)
        elif act == "gelu":
            r = jax.nn.gelu(r, approximate=True)
        o_ref[...] = r.astype(o_ref.dtype)


def swap_linear(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                *, act: str = "none", block_m: int = 256, block_n: int = 256,
                block_k: int = 512, interpret: bool = False) -> jax.Array:
    """y = act(x @ w + b). x [M,K], w [K,N] (streamed), b [N] or None."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"shapes ({M},{K},{N}) not divisible by blocks ({bm},{bk},{bn})"
    if b is None:
        b = jnp.zeros((N,), x.dtype)
    n_m, n_n, n_k = M // bm, N // bn, K // bk

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, act=act),
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # activations
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # weight stream
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),    # bias
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, b.reshape(1, N))


def vmem_bytes(bm: int, bn: int, bk: int, itemsize: int = 2) -> int:
    """The VMEM budget this tiling claims (for kernel-level roofline notes)."""
    return 2 * (bm * bk + bk * bn + bn) * itemsize + bm * bn * 4
