"""QuantizedStore: int8/int4 per-channel quantized swap units.

The paper's LLM outlook (§ "insights for deploying LLMs") points at raw I/O
bytes per block as the bottleneck once the redundant copies are gone. This
backend attacks exactly that: at BUILD time every large float tensor of a
unit is quantized to symmetric per-channel int8 (values + one fp32 scale per
output channel, ~4x fewer bytes than fp32) or packed int4 (two values per
carrier byte, ~8x — ``bits=4``), cutting the bytes a swap-in must move from
storage to host accordingly. At SWAP-IN the quantized payload is memmapped
(zero host copies, like the snet path) and transferred host->device still
quantized. What happens next is the ``eager`` knob:

  * ``eager=True``  (default, the PR 2 behaviour): the fp tree is
    reconstructed on device by the Pallas ``dequant_int8`` kernel (int4
    unpacks first) — the dequant rides the H2D transfer the swap-in pays
    anyway;
  * ``eager=False`` (the FUSED path, ROADMAP (f)): leaves whose consumers
    route through ``models/layers.linear`` — 2-D matmul weights under a
    fused-routable key (:data:`FUSED_STREAM_KEYS`) — come back as
    :class:`~repro.kernels.qtensor.QuantizedTensor`: fp is NEVER
    materialized for them; they stream straight through the fused
    dequant-matmul (kernels/swap_linear_q.py), so HBM->VMEM DMA and the
    VMEM weight window also shrink 2-4x. Leaves the fused kernel CANNOT
    stream (conv stacks, 3-D expert einsums, embeddings) are dequantized
    HERE, on the loader thread — dequant-at-use on the executor would
    serialize the dequant into the compute phase of every pass, which is
    exactly the fused-path overlap gap this store used to have. The I/O
    win (quantized bytes on the storage channel) applies to every leaf
    either way.

Pipeline contract (the PR 6 fix, asserted by tests/test_overlap_timeline):
the ENTIRE quantized payload is forced host-resident by one sequential
read at the top of ``read_unit`` — the old code memmapped the file and let
the carrier bytes fault in lazily inside the device put, so the host read
of block i+1 rode on the dispatch stage instead of overlapping block i's
compute. Every stage (read -> unpack -> dispatch, including the device-put
flush) runs and COMPLETES on the loader thread; the executor only ever
waits on a finished unit. In lazy mode the non-streamable dequant is
NUMPY on the loader ("unpack") — one device put per leaf, no per-leaf
device-op storm on the swap-in critical path.

Accounting (tested contract):
  * ``io_bytes`` / ``SwapStats.bytes_swapped`` — the QUANTIZED payload size
    (what actually crossed the storage channel);
  * ``ledger_bytes`` — with ``eager=True`` the stored (quantized) size, the
    PR 2 modeling convention (the repro materializes the fp tree as the
    execution artifact and reports that side as ``SwapStats.bytes_logical``
    so nothing is hidden); with ``eager=False`` the HONEST mixed residency:
    quantized payload + scales for QuantizedTensor leaves, logical fp bytes
    for loader-dequantized leaves — so the planner packs against what the
    ledger will really hold;
  * ``quantized_bytes`` — bytes delivered still-quantized (lazy mode only);
  * ``nbytes`` stays LOGICAL (dequantized) — partitioning and block-size
    reasoning are unchanged (the planner separately consults
    ``resident_nbytes`` to see the smaller working set).

What gets quantized: float leaves with ndim >= 2 and >= ``min_quant_size``
elements (weight matrices, conv stacks). 1-D leaves (norm gains, biases) and
small tensors are stored raw — they are bytes-cheap and accuracy-critical,
so the round-trip error bound (``|x̂ - x| <= max|x[:, c]| / 254`` at int8,
``/ 14`` at int4; see kernels/dequant.py) applies only where it is well
conditioned. Per-MODEL eligibility and precision are config knobs
(``ModelConfig.quant_eligible`` / ``swap_precision``): architectures whose
recurrent dynamics amplify weight error opt out and fall back to the mmap
backend.

Mixed precision (``plan=...``): instead of one store-wide bit-width, a
calibration-derived plan (repro/calibrate/) assigns fp | int8 | int4 PER
UNIT. Each ``QLeaf`` records its own ``bits`` and the read path dispatches
on the leaf, so one store mixes exact and quantized units freely; the
per-precision stored-byte split flows out through
``UnitRead.precision_bytes`` into ``SwapStats.bytes_by_precision``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qtensor import FUSED_WEIGHT_KEYS
from repro.store.base import BlockStore, UnitRead

MIN_QUANT_SIZE = 1024       # elements; smaller leaves are stored raw

# keys whose 2-D weights stream through the fused dequant-matmul and may
# therefore stay quantized-resident; "w" is the generic fc weight key of the
# vision models, whose consumer is also models/layers.linear
FUSED_STREAM_KEYS = FUSED_WEIGHT_KEYS | {"w"}

# per-unit bit-width labels for the byte accounting; 0 = raw/fp
BITS_PRECISION = {0: "fp", 8: "int8", 4: "int4"}


def quantizable(arr: np.ndarray, min_quant_size: int = MIN_QUANT_SIZE) -> bool:
    """The store's quantization predicate (module docstring, "What gets
    quantized") — shared with the calibration profiler so measured
    sensitivity covers exactly the leaves the store will quantize."""
    return (arr.ndim >= 2 and arr.size >= min_quant_size
            and jnp.issubdtype(jnp.dtype(arr.dtype), jnp.floating))


def unit_stored_nbytes(params, bits: int,
                       min_quant_size: int = MIN_QUANT_SIZE) -> int:
    """Exact stored payload size of one unit at a bit-width WITHOUT building
    the store: every ``put`` segment below pads to ALIGN, so the analytic
    sum of aligned segment sizes equals the file size byte-for-byte. The
    precision policy packs against this table. ``bits=0`` = all-raw (fp)."""
    from repro.core.skeleton import _align
    assert bits in (0, 4, 8), bits
    total = 0
    for leaf in jax.tree.leaves(params):
        arr = np.asarray(leaf)
        if bits and quantizable(arr, min_quant_size):
            rows = int(np.prod(arr.shape[:-1]))
            cols = int(arr.shape[-1])
            qrows = rows if bits == 8 else (rows + 1) // 2
            total += _align(qrows * cols) + _align(4 * cols)
        else:
            total += _align(arr.nbytes)
    return total


@dataclass(frozen=True)
class QLeaf:
    """One leaf inside a unit's quantized payload file.

    ``scale_offset < 0`` marks a raw (unquantized) leaf; otherwise the leaf
    is quantized [rows, cols] (``rows`` = LOGICAL rows of the channel grid;
    the int4 carrier holds ceil(rows/2) payload rows) at ``offset`` with
    fp32 [cols] scales at ``scale_offset``. ``dtype`` is the ORIGINAL dtype
    dequant restores. ``fusable`` marks leaves the fused kernel can stream
    still-quantized (2-D, key in :data:`FUSED_STREAM_KEYS`); in lazy mode
    every other quantized leaf is dequantized on the loader thread.
    ``bits`` is the PER-LEAF bit-width (8 | 4 for quantized leaves, 0 for
    raw) — under a mixed-precision plan different units of one store carry
    different widths, so the read path dispatches on the leaf, never on a
    store-global setting."""
    offset: int
    nbytes: int
    shape: Tuple[int, ...]
    dtype: str
    scale_offset: int = -1
    rows: int = 0
    cols: int = 0
    fusable: bool = False
    bits: int = 0


@dataclass
class QuantMeta:
    leaves: List[QLeaf]
    stored_nbytes: int
    resident_lazy: int = 0   # mixed residency of the eager=False read (bytes)
    precision_bytes: Dict[str, int] = None  # stored bytes per fp|int8|int4


class QuantizedStore(BlockStore):
    backend = "quant"
    raw_format = False

    def __init__(self, workdir: str, min_quant_size: int = MIN_QUANT_SIZE,
                 bits: int = 8, eager: bool = True, verify: bool = False,
                 plan=None):
        """``plan`` switches the store to PER-UNIT mixed precision: a dict
        ``{unit_name: 0|8|4}`` (0 = raw fp) or any object with a
        ``bits_map()`` method returning one — duck-typed so this module
        never imports the calibrate package that produces
        ``PrecisionPlan``s. Units the plan does not name are stored RAW:
        an unprofiled unit must round-trip bit-exactly, not inherit a
        bit-width nobody measured. Without a plan the store is uniform at
        ``bits`` (the pre-existing behaviour)."""
        assert bits in (8, 4), bits
        super().__init__(workdir, verify=verify)
        self.min_quant_size = min_quant_size
        self.bits = bits
        self.eager = eager
        bm = plan.bits_map() if hasattr(plan, "bits_map") else plan
        self.plan = dict(bm) if bm is not None else None
        if self.plan is not None:
            bad = {b for b in self.plan.values() if b not in (0, 4, 8)}
            assert not bad, f"plan bit-widths must be 0|4|8, got {bad}"
            self.suffix = ".qm"
        else:
            self.suffix = ".q8" if bits == 8 else ".q4"
        self._qmeta: Dict[str, QuantMeta] = {}

    @property
    def precision(self) -> str:
        if self.plan is not None:
            return "mixed"
        return "int8" if self.bits == 8 else "int4"

    def _unit_bits(self, name: str) -> int:
        return self.bits if self.plan is None else self.plan.get(name, 0)

    # ------------------------------------------------------------ build
    def _write_unit(self, name: str, params: dict) -> None:
        from repro.compat import tree_flatten_with_path
        from repro.core.skeleton import ALIGN, skeleton_of
        from repro.kernels.dequant import quantize_int4, quantize_int8
        bits_u = self._unit_bits(name)
        quantize = quantize_int8 if bits_u == 8 else quantize_int4
        flat, _ = tree_flatten_with_path(params)
        # logical skeleton (nbytes/meta) WITHOUT materializing the flat fp
        # buffer — the payload below is this store's only serialization
        self.skeletons[name] = skeleton_of(params)
        blob = bytearray()

        def put(b: bytes) -> int:
            off = len(blob)
            blob.extend(b)
            blob.extend(b"\0" * ((-len(blob)) % ALIGN))
            return off

        qleaves: List[QLeaf] = []
        resident_lazy = 0
        pbytes = {p: 0 for p in BITS_PRECISION.values()}
        for path, leaf in flat:
            arr = np.ascontiguousarray(np.asarray(leaf))
            seg0 = len(blob)
            if bits_u and quantizable(arr, self.min_quant_size):
                key = getattr(path[-1], "key", None) if path else None
                fusable = arr.ndim == 2 and key in FUSED_STREAM_KEYS
                q, scales = quantize(arr)
                off = put(q.tobytes())
                soff = put(scales.tobytes())
                rows = int(np.prod(arr.shape[:-1]))
                qleaves.append(QLeaf(off, q.nbytes, tuple(arr.shape),
                                     str(arr.dtype), soff, rows, q.shape[1],
                                     fusable, bits_u))
                resident_lazy += (q.nbytes + scales.nbytes if fusable
                                  else arr.nbytes)
            else:
                off = put(arr.tobytes())
                qleaves.append(QLeaf(off, arr.nbytes, tuple(arr.shape),
                                     str(arr.dtype)))
                resident_lazy += arr.nbytes
            # aligned segment growth, bucketed by the leaf's stored width
            pbytes[BITS_PRECISION[qleaves[-1].bits]] += len(blob) - seg0
        with open(self._path(name), "wb") as fh:
            fh.write(bytes(blob))
        self._qmeta[name] = QuantMeta(qleaves, len(blob), resident_lazy,
                                      pbytes)

    # ------------------------------------------------------------ read
    def read_unit(self, name: str) -> UnitRead:
        from repro.kernels.dequant import unpack_int4
        from repro.kernels.ops import dequant_int8
        from repro.kernels.qtensor import QuantizedTensor
        from repro.kernels.ref import unpack_int4_ref
        skel = self.skeletons[name]
        if skel.nbytes == 0:
            return self._empty_unit(name)
        meta = self._qmeta[name]
        lazy = not self.eager
        t0 = time.perf_counter()
        # read: ONE sequential buffered read forces the whole carrier payload
        # host-resident on the loader thread — a memmap here would defer the
        # storage traffic to page faults inside the device puts below, where
        # it can no longer overlap the executor (module docstring, "Pipeline
        # contract").
        buf = np.fromfile(self._path(name), dtype=np.uint8)
        # integrity over the CARRIER bytes: a flipped nibble in a packed-int4
        # payload is caught here, never dequantized into wrong weights
        self._verify_payload(name, buf)
        t1 = time.perf_counter()
        # unpack: host-side work over the payload. Raw and streamable leaves
        # are pure views; in lazy mode the quantized leaves the fused kernel
        # CANNOT stream dequantize here in numpy — host FLOPs on the
        # otherwise-idle loader core, one device put per leaf, instead of a
        # per-leaf device-op storm or dequant-at-use inside executor compute.
        host: list = []
        for ql in meta.leaves:
            dt = jnp.dtype(ql.dtype)
            if ql.scale_offset < 0:            # raw leaf
                host.append((ql, buf[ql.offset:ql.offset + ql.nbytes]
                             .view(dt.type).reshape(ql.shape), None))
                continue
            qv = buf[ql.offset:ql.offset + ql.nbytes] \
                .view(np.int8).reshape(-1, ql.cols)
            sv = buf[ql.scale_offset:ql.scale_offset + 4 * ql.cols] \
                .view(np.float32)
            if lazy and not ql.fusable:
                vals = unpack_int4(qv, ql.rows) if ql.bits == 4 else qv
                # one fused multiply pass (int8 x scales -> fp32 out); the
                # naive astype()*astype() chain costs 3 full-size copies
                fp = np.multiply(vals, sv[None, :], dtype=np.float32)
                if dt.type is not np.float32:
                    fp = fp.astype(dt.type)
                host.append((ql, fp.reshape(ql.shape), None))
            else:
                host.append((ql, qv, sv))
        t2 = time.perf_counter()
        # dispatch: host -> device puts (eager mode keeps the seed's
        # on-device Pallas dequant — it rides the H2D transfer), flushed
        # HERE so the executor never inherits loader work. All leaves go up
        # in ONE batched jax.device_put — per-call dispatch overhead
        # (~100-200us) over dozens of leaves is the single largest loader
        # cost after the dequant itself
        arrs: list = []
        for _, qv, sv in host:
            arrs.append(qv)
            if sv is not None:
                arrs.append(sv)
        dev = iter(jax.device_put(arrs))
        leaves = []
        qbytes = 0
        for ql, qv, sv in host:
            q = next(dev)
            if sv is None:
                leaves.append(q)
                continue
            s = next(dev)
            if lazy:                           # fused path: stay quantized
                leaves.append(QuantizedTensor(q, s, ql.shape, ql.dtype,
                                              ql.bits))
                qbytes += ql.nbytes + 4 * ql.cols
                continue
            vals = unpack_int4_ref(q, ql.rows) if ql.bits == 4 else q
            leaves.append(dequant_int8(vals, s, jnp.dtype(ql.dtype).type)
                          .reshape(ql.shape))
        tree = jax.tree.unflatten(skel.treedef, leaves)
        jax.block_until_ready(tree)
        t3 = time.perf_counter()
        stored = meta.stored_nbytes
        ledger = meta.resident_lazy if lazy else stored
        stages = (("read", t0, t1), ("unpack", t1, t2), ("dispatch", t2, t3))
        return UnitRead(tree, stored, ledger, t1 - t0, t3 - t1,
                        quantized_bytes=qbytes, stages=stages,
                        precision_bytes={k: v for k, v in
                                         (meta.precision_bytes or {}).items()
                                         if v})

    # ------------------------------------------------------------ sizes
    def stored_nbytes(self, name: str) -> int:
        return self._qmeta[name].stored_nbytes if name in self._qmeta \
            else self.skeletons[name].nbytes

    def resident_nbytes(self, name: str) -> int:
        """Eager mode holds the stored (quantized) payload convention; lazy
        mode holds the honest mixed residency (QuantizedTensor payloads for
        fusable leaves, restored fp for everything else)."""
        if not self.eager and name in self._qmeta:
            return self._qmeta[name].resident_lazy
        return self.stored_nbytes(name)

    def meta_bytes(self) -> int:
        """Skeletons plus the per-leaf quant refs (still KB-scale/model)."""
        base = super().meta_bytes()
        return base + sum(64 + 72 * len(m.leaves)
                          for m in self._qmeta.values())
