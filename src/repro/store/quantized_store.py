"""QuantizedStore: int8 per-channel quantized swap units, dequant-on-swap-in.

The paper's LLM outlook (§ "insights for deploying LLMs") points at raw I/O
bytes per block as the bottleneck once the redundant copies are gone. This
backend attacks exactly that: at BUILD time every large float tensor of a
unit is quantized to symmetric per-channel int8 (values + one fp32 scale per
output channel), cutting the bytes a swap-in must move from storage to host
~4x. At SWAP-IN the quantized payload is memmapped (zero host copies, like
the snet path), transferred host->device still quantized, and reconstructed
to fp32/bf16 ON DEVICE by the Pallas ``dequant_int8`` kernel — the dequant
multiply rides the H2D transfer the swap-in pays anyway, so saved I/O bytes
are pure profit on the critical path.

Accounting (tested contract):
  * ``io_bytes`` / ``SwapStats.bytes_swapped`` — the QUANTIZED payload size
    (what actually crossed the storage channel);
  * ``ledger_bytes`` — also the quantized size. This is a MODELING
    convention mirroring the paper's ledger, which budgets the target
    device: a production quant runtime keeps the int8 payload resident and
    dequantizes per use (ultimately fused into the matmul weight stream —
    ROADMAP next step (f)), so the quantized payload is the unit's durable
    residency. This repro DOES materialize the fp tree as the execution
    artifact, so host memory transiently holds payload + fp together;
    ``SwapStats.bytes_logical`` reports that fp side so nothing is hidden;
  * ``nbytes`` stays LOGICAL (dequantized) — partitioning and block-size
    reasoning are unchanged.

What gets quantized: float leaves with ndim >= 2 and >= ``min_quant_size``
elements (weight matrices, conv stacks). 1-D leaves (norm gains, biases) and
small tensors are stored raw — they are bytes-cheap and accuracy-critical,
so the round-trip error bound (``|x̂ - x| <= max|x[:, c]| / 254`` per
channel, see kernels/dequant.py) applies only where it is well conditioned.
Per-MODEL eligibility is a config knob (``ModelConfig.quant_eligible``):
architectures whose recurrent dynamics amplify weight error opt out and fall
back to the mmap backend.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.store.base import BlockStore, UnitRead

MIN_QUANT_SIZE = 1024       # elements; smaller leaves are stored raw


@dataclass(frozen=True)
class QLeaf:
    """One leaf inside a unit's quantized payload file.

    ``scale_offset < 0`` marks a raw (unquantized) leaf; otherwise the leaf
    is int8 [rows, cols] at ``offset`` with fp32 [cols] scales at
    ``scale_offset``. ``dtype`` is the ORIGINAL dtype dequant restores."""
    offset: int
    nbytes: int
    shape: Tuple[int, ...]
    dtype: str
    scale_offset: int = -1
    rows: int = 0
    cols: int = 0


@dataclass
class QuantMeta:
    leaves: List[QLeaf]
    stored_nbytes: int


class QuantizedStore(BlockStore):
    backend = "quant"
    raw_format = False
    suffix = ".q8"

    def __init__(self, workdir: str, min_quant_size: int = MIN_QUANT_SIZE):
        super().__init__(workdir)
        self.min_quant_size = min_quant_size
        self._qmeta: Dict[str, QuantMeta] = {}

    # ------------------------------------------------------------ build
    def _write_unit(self, name: str, params: dict) -> None:
        from repro.core.skeleton import ALIGN, skeleton_of
        from repro.kernels.dequant import quantize_int8
        leaves = jax.tree.leaves(params)
        # logical skeleton (nbytes/meta) WITHOUT materializing the flat fp
        # buffer — the payload below is this store's only serialization
        self.skeletons[name] = skeleton_of(params)
        blob = bytearray()

        def put(b: bytes) -> int:
            off = len(blob)
            blob.extend(b)
            blob.extend(b"\0" * ((-len(blob)) % ALIGN))
            return off

        qleaves: List[QLeaf] = []
        for leaf in leaves:
            arr = np.ascontiguousarray(np.asarray(leaf))
            if (arr.ndim >= 2 and arr.size >= self.min_quant_size
                    and jnp.issubdtype(jnp.dtype(arr.dtype), jnp.floating)):
                q, scales = quantize_int8(arr)
                off = put(q.tobytes())
                soff = put(scales.tobytes())
                qleaves.append(QLeaf(off, q.nbytes, tuple(arr.shape),
                                     str(arr.dtype), soff, *q.shape))
            else:
                off = put(arr.tobytes())
                qleaves.append(QLeaf(off, arr.nbytes, tuple(arr.shape),
                                     str(arr.dtype)))
        with open(self._path(name), "wb") as fh:
            fh.write(bytes(blob))
        self._qmeta[name] = QuantMeta(qleaves, len(blob))

    # ------------------------------------------------------------ read
    def read_unit(self, name: str) -> UnitRead:
        from repro.kernels.ops import dequant_int8
        skel = self.skeletons[name]
        if skel.nbytes == 0:
            return self._empty_unit(name)
        meta = self._qmeta[name]
        t0 = time.perf_counter()
        buf = np.memmap(self._path(name), dtype=np.uint8, mode="r")
        t1 = time.perf_counter()
        leaves = []
        for ql in meta.leaves:
            dt = jnp.dtype(ql.dtype)
            if ql.scale_offset < 0:            # raw leaf: view + one DMA
                view = buf[ql.offset:ql.offset + ql.nbytes].view(dt.type)
                leaves.append(jnp.asarray(view.reshape(ql.shape)))
                continue
            # quantized leaf: transfer int8 payload + scales, dequant there
            q = jnp.asarray(buf[ql.offset:ql.offset + ql.nbytes]
                            .view(np.int8).reshape(ql.rows, ql.cols))
            s = jnp.asarray(buf[ql.scale_offset:ql.scale_offset + 4 * ql.cols]
                            .view(np.float32))
            leaves.append(dequant_int8(q, s, dt.type).reshape(ql.shape))
        tree = jax.tree.unflatten(skel.treedef, leaves)
        t2 = time.perf_counter()
        stored = meta.stored_nbytes
        return UnitRead(tree, stored, stored, t1 - t0, t2 - t1)

    # ------------------------------------------------------------ sizes
    def stored_nbytes(self, name: str) -> int:
        return self._qmeta[name].stored_nbytes if name in self._qmeta \
            else self.skeletons[name].nbytes

    def meta_bytes(self) -> int:
        """Skeletons plus the per-leaf quant refs (still KB-scale/model)."""
        base = super().meta_bytes()
        return base + sum(64 + 72 * len(m.leaves)
                          for m in self._qmeta.values())
