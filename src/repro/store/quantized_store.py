"""QuantizedStore: int8/int4 per-channel quantized swap units.

The paper's LLM outlook (§ "insights for deploying LLMs") points at raw I/O
bytes per block as the bottleneck once the redundant copies are gone. This
backend attacks exactly that: at BUILD time every large float tensor of a
unit is quantized to symmetric per-channel int8 (values + one fp32 scale per
output channel, ~4x fewer bytes than fp32) or packed int4 (two values per
carrier byte, ~8x — ``bits=4``), cutting the bytes a swap-in must move from
storage to host accordingly. At SWAP-IN the quantized payload is memmapped
(zero host copies, like the snet path) and transferred host->device still
quantized. What happens next is the ``eager`` knob:

  * ``eager=True``  (default, the PR 2 behaviour): the fp tree is
    reconstructed on device by the Pallas ``dequant_int8`` kernel (int4
    unpacks first) — the dequant rides the H2D transfer the swap-in pays
    anyway;
  * ``eager=False`` (the FUSED path, ROADMAP (f)): quantized leaves come
    back as :class:`~repro.kernels.qtensor.QuantizedTensor` — fp is NEVER
    materialized for them. Linear consumers stream the quantized tiles
    straight through the fused dequant-matmul (kernels/swap_linear_q.py),
    so HBM->VMEM DMA and the VMEM weight window also shrink 2-4x; other
    consumers dequantize per use. Residency is genuinely the quantized
    payload, which is what the ledger charges — raising effective cache
    capacity by the same factor.

Accounting (tested contract):
  * ``io_bytes`` / ``SwapStats.bytes_swapped`` — the QUANTIZED payload size
    (what actually crossed the storage channel);
  * ``ledger_bytes`` — also the quantized size. With ``eager=False`` this
    is literal (the payload IS the resident unit); with ``eager=True`` it
    remains the PR 2 modeling convention (the repro materializes the fp
    tree as the execution artifact and reports that side as
    ``SwapStats.bytes_logical`` so nothing is hidden);
  * ``quantized_bytes`` — bytes delivered still-quantized (lazy mode only);
  * ``nbytes`` stays LOGICAL (dequantized) — partitioning and block-size
    reasoning are unchanged (the planner separately consults
    ``resident_nbytes`` to see the smaller working set).

What gets quantized: float leaves with ndim >= 2 and >= ``min_quant_size``
elements (weight matrices, conv stacks). 1-D leaves (norm gains, biases) and
small tensors are stored raw — they are bytes-cheap and accuracy-critical,
so the round-trip error bound (``|x̂ - x| <= max|x[:, c]| / 254`` at int8,
``/ 14`` at int4; see kernels/dequant.py) applies only where it is well
conditioned. Per-MODEL eligibility and precision are config knobs
(``ModelConfig.quant_eligible`` / ``swap_precision``): architectures whose
recurrent dynamics amplify weight error opt out and fall back to the mmap
backend.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.store.base import BlockStore, UnitRead

MIN_QUANT_SIZE = 1024       # elements; smaller leaves are stored raw


@dataclass(frozen=True)
class QLeaf:
    """One leaf inside a unit's quantized payload file.

    ``scale_offset < 0`` marks a raw (unquantized) leaf; otherwise the leaf
    is quantized [rows, cols] (``rows`` = LOGICAL rows of the channel grid;
    the int4 carrier holds ceil(rows/2) payload rows) at ``offset`` with
    fp32 [cols] scales at ``scale_offset``. ``dtype`` is the ORIGINAL dtype
    dequant restores."""
    offset: int
    nbytes: int
    shape: Tuple[int, ...]
    dtype: str
    scale_offset: int = -1
    rows: int = 0
    cols: int = 0


@dataclass
class QuantMeta:
    leaves: List[QLeaf]
    stored_nbytes: int


class QuantizedStore(BlockStore):
    backend = "quant"
    raw_format = False

    def __init__(self, workdir: str, min_quant_size: int = MIN_QUANT_SIZE,
                 bits: int = 8, eager: bool = True):
        assert bits in (8, 4), bits
        super().__init__(workdir)
        self.min_quant_size = min_quant_size
        self.bits = bits
        self.eager = eager
        self.suffix = ".q8" if bits == 8 else ".q4"
        self._qmeta: Dict[str, QuantMeta] = {}

    @property
    def precision(self) -> str:
        return "int8" if self.bits == 8 else "int4"

    # ------------------------------------------------------------ build
    def _write_unit(self, name: str, params: dict) -> None:
        from repro.core.skeleton import ALIGN, skeleton_of
        from repro.kernels.dequant import quantize_int4, quantize_int8
        quantize = quantize_int8 if self.bits == 8 else quantize_int4
        leaves = jax.tree.leaves(params)
        # logical skeleton (nbytes/meta) WITHOUT materializing the flat fp
        # buffer — the payload below is this store's only serialization
        self.skeletons[name] = skeleton_of(params)
        blob = bytearray()

        def put(b: bytes) -> int:
            off = len(blob)
            blob.extend(b)
            blob.extend(b"\0" * ((-len(blob)) % ALIGN))
            return off

        qleaves: List[QLeaf] = []
        for leaf in leaves:
            arr = np.ascontiguousarray(np.asarray(leaf))
            if (arr.ndim >= 2 and arr.size >= self.min_quant_size
                    and jnp.issubdtype(jnp.dtype(arr.dtype), jnp.floating)):
                q, scales = quantize(arr)
                off = put(q.tobytes())
                soff = put(scales.tobytes())
                rows = int(np.prod(arr.shape[:-1]))
                qleaves.append(QLeaf(off, q.nbytes, tuple(arr.shape),
                                     str(arr.dtype), soff, rows, q.shape[1]))
            else:
                off = put(arr.tobytes())
                qleaves.append(QLeaf(off, arr.nbytes, tuple(arr.shape),
                                     str(arr.dtype)))
        with open(self._path(name), "wb") as fh:
            fh.write(bytes(blob))
        self._qmeta[name] = QuantMeta(qleaves, len(blob))

    # ------------------------------------------------------------ read
    def read_unit(self, name: str) -> UnitRead:
        from repro.kernels.ops import dequant_int8
        from repro.kernels.qtensor import QuantizedTensor
        from repro.kernels.ref import unpack_int4_ref
        skel = self.skeletons[name]
        if skel.nbytes == 0:
            return self._empty_unit(name)
        meta = self._qmeta[name]
        t0 = time.perf_counter()
        buf = np.memmap(self._path(name), dtype=np.uint8, mode="r")
        t1 = time.perf_counter()
        leaves = []
        qbytes = 0
        for ql in meta.leaves:
            dt = jnp.dtype(ql.dtype)
            if ql.scale_offset < 0:            # raw leaf: view + one DMA
                view = buf[ql.offset:ql.offset + ql.nbytes].view(dt.type)
                leaves.append(jnp.asarray(view.reshape(ql.shape)))
                continue
            # quantized leaf: transfer the payload + scales, keep or dequant
            q = jnp.asarray(buf[ql.offset:ql.offset + ql.nbytes]
                            .view(np.int8).reshape(-1, ql.cols))
            s = jnp.asarray(buf[ql.scale_offset:ql.scale_offset + 4 * ql.cols]
                            .view(np.float32))
            if not self.eager:                 # fused path: stay quantized
                leaves.append(QuantizedTensor(q, s, ql.shape, ql.dtype,
                                              self.bits))
                qbytes += ql.nbytes + 4 * ql.cols
                continue
            vals = unpack_int4_ref(q, ql.rows) if self.bits == 4 else q
            leaves.append(dequant_int8(vals, s, dt.type).reshape(ql.shape))
        tree = jax.tree.unflatten(skel.treedef, leaves)
        t2 = time.perf_counter()
        stored = meta.stored_nbytes
        return UnitRead(tree, stored, stored, t1 - t0, t2 - t1,
                        quantized_bytes=qbytes)

    # ------------------------------------------------------------ sizes
    def stored_nbytes(self, name: str) -> int:
        return self._qmeta[name].stored_nbytes if name in self._qmeta \
            else self.skeletons[name].nbytes

    def meta_bytes(self) -> int:
        """Skeletons plus the per-leaf quant refs (still KB-scale/model)."""
        base = super().meta_bytes()
        return base + sum(64 + 72 * len(m.leaves)
                          for m in self._qmeta.values())
