"""Pluggable tiered block-store subsystem: the storage tier of the swap path.

SwapNet (paper §4-§5) removes the redundant memory operations from swap-in;
once those copies are gone the next bottleneck is the storage tier itself —
raw I/O bytes per block and how they travel storage -> host -> device. A
:class:`BlockStore` owns exactly that tier: how a model's swappable units are
laid out at build time and how one unit is read back at swap-in. The engine
(`repro.core.swap_engine.SwapEngine`) no longer knows about files; it asks
its store for a :class:`UnitRead` and does the bookkeeping.

Backends (see the sibling modules):

  * ``MmapStore``      — zero-copy swap-in (the paper's full system): memmap
                         the unit file, host assembly by reference, one H2D
                         transfer. ``assembly="dummy"`` is the w/o-mod-ske
                         ablation arm (framework-default dummy-model copies).
  * ``RawIOStore``     — read()-based swap-in (the w/o-uni-add / ``copy_in``
                         ablation arm): page-cache copy + staging copy +
                         transfer (+ GPU dispatch copy when modelled).
  * ``QuantizedStore`` — int8 per-channel quantized swap units written at
                         build time (~4x fewer stored bytes), dequantized
                         ON DEVICE by a Pallas kernel after the (already
                         cheaper) H2D transfer — dequant rides the DMA the
                         swap-in pays anyway instead of adding host work.

File naming is collision-free: ``_`` is escaped before ``/`` is replaced, so
``"a/b"`` and ``"a_b"`` never map to the same file (a latent bug in the old
``LayerStore._path``).
"""
from __future__ import annotations

import os
import zlib
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.errors import SwapCorruptionError

if TYPE_CHECKING:       # repro.core.skeleton is imported lazily at call time:
    # repro.core.__init__ imports swap_engine which imports this package, so
    # a module-level import here would be circular when repro.store loads
    # first.
    from repro.core.skeleton import Skeleton


def escape_name(name: str) -> str:
    """Collision-free filename escaping: ``_`` -> ``__`` first, then
    ``/`` -> ``_.`` — injective, so distinct unit names (``"a/b"`` vs
    ``"a_b"``) can never share a file."""
    return name.replace("_", "__").replace("/", "_.")


@dataclass
class UnitRead:
    """One unit's swap-in, as performed by a store backend.

    This is the store -> engine contract: ``SwapEngine.swap_in`` consumes a
    ``UnitRead`` per non-cached unit and does ALL the bookkeeping from it —
    it never inspects the params tree or the files itself.

    ``params``          — assembled (device-transferred) parameter tree;
    ``io_bytes``        — bytes actually moved storage -> host (what
                          ``SwapStats.bytes_swapped`` accumulates; quantized
                          backends move 4-8x less than the logical unit
                          size; direct-I/O reads whole aligned sectors);
    ``ledger_bytes``    — resident bytes to charge to the memory ledger
                          (mode-induced extra copies included);
    ``io_s/asm_s``      — the t_in split: fetch vs assembly wall-clock;
    ``quantized_bytes`` — payload bytes delivered STILL QUANTIZED (as
                          ``QuantizedTensor`` leaves, the fused-path
                          residency; 0 for eager/raw backends) — what
                          ``SwapStats.bytes_resident_quantized`` reports;
    ``precision_bytes`` — io_bytes split by stored precision
                          (``{"fp"|"int8"|"int4": bytes}``); None from
                          single-precision backends — the engine then
                          buckets the whole read under its store's
                          precision (``SwapStats.bytes_by_precision``);
    ``stages``          — the per-stage timeline of this read: ``(stage,
                          start, end)`` tuples in ``time.perf_counter``
                          absolute seconds, run on the LOADER thread. Stage
                          names are backend-chosen from {"read", "unpack",
                          "dispatch"}: "read" is storage -> host bytes,
                          "unpack" is dequant/unpack/assembly work, and
                          "dispatch" is the host -> device put (kernel-
                          visible bytes). ``SwapEngine`` folds these into
                          ``SwapStats.timeline`` so a stall is attributable
                          to the stage that caused it (executor-side "wait"
                          / "exec" events are recorded by the engine).
    """
    params: Any
    io_bytes: int
    ledger_bytes: int
    io_s: float = 0.0
    asm_s: float = 0.0
    quantized_bytes: int = 0
    stages: Tuple[Tuple[str, float, float], ...] = ()
    precision_bytes: Optional[Dict[str, int]] = None


class BlockStore:
    """Interface + shared layout for per-unit block storage.

    Contract (what `SwapEngine` relies on):
      * ``build(units, workdir)``   — one-time serialization of the model's
        smallest divisible units; shared units (same name) are stored once;
      * ``open()``                  — prepare for reading (idempotent hook);
      * ``read_unit(name)``         — one unit storage -> host -> device,
        returning a :class:`UnitRead`. Called ONLY from the engine's single
        loader thread, so backends may keep per-read scratch state (e.g.
        the direct-I/O buffer arena) without locking against their own
        reads — but a store SHARED by several engines must tolerate
        concurrent ``read_unit`` calls from their loader threads;
      * ``nbytes(name)``            — LOGICAL (dequantized) unit bytes: what
        partitioning and block accounting reason about;
      * ``stored_nbytes(name)``     — bytes the unit occupies on storage
        (== ``nbytes`` except for quantized backends);
      * ``resident_nbytes(name)``   — bytes ONE resident copy costs this
        backend at runtime: what the ledger is charged per un-cached read
        (stored bytes plus any mode-induced extra copies — rawio holds 2-3x,
        quant holds the quantized payload). Cache admission reasons in this
        currency;
      * ``meta_bytes()``            — resident metadata overhead (skeletons,
        paper Fig. 19a).

    Blocks are ranges of units; adaptation only re-indexes ranges (paper
    §6.2.2 operations 2-3), never rewrites files.

    Registered backends (``repro.store.STORE_BACKENDS``): ``mmap`` (zero-
    copy page-cache reads), ``rawio`` (buffered read() ablation arm),
    ``quant`` (int8/int4 quantized payloads), ``directio`` (O_DIRECT
    page-cache-bypassing reads with an aligned pooled-buffer arena and
    queue-depth control). See docs/ARCHITECTURE.md for the full map.
    """

    backend = "abstract"
    raw_format = False      # True: on-disk files are the raw flat-fp layout
    suffix = ".bin"

    def __init__(self, workdir: str, verify: bool = False):
        self.workdir = workdir
        self.skeletons: Dict[str, "Skeleton"] = {}
        self.order: List[str] = []
        # Integrity tier (see docs/ARCHITECTURE.md "Failure handling"):
        # ``digests`` holds one CRC32 per unit FILE, recorded at build time;
        # with ``verify=True`` every read checks its payload against the
        # digest and raises SwapCorruptionError on mismatch BEFORE assembly,
        # so a flipped bit can never become silently wrong weights. Off by
        # default: the check costs one linear pass over the payload (and
        # forces eager page-in for the otherwise-lazy mmap backend), so it
        # is an explicit knob — chaos tests, the FaultInjector wrapper, and
        # unreliable-storage deployments turn it on.
        self.verify = verify
        self.digests: Dict[str, int] = {}
        self.integrity_failures = 0

    # ------------------------------------------------------------ build
    @classmethod
    def build(cls, units: Sequence[Tuple[str, dict]], workdir: str,
              **opts) -> "BlockStore":
        os.makedirs(workdir, exist_ok=True)
        store = cls(workdir, **opts)
        for name, params in units:
            store.order.append(name)
            if name in store.skeletons:     # shared unit (zamba2): once
                continue
            store._write_unit(name, params)
            store._record_digest(name)
        return store.open()

    def _write_unit(self, name: str, params: dict) -> None:
        raise NotImplementedError

    def _write_raw(self, name: str, params: dict) -> None:
        """Shared raw layout: one contiguous flat-fp buffer per unit."""
        from repro.core.skeleton import flatten_params
        buf, skel = flatten_params(params)
        with open(self._path(name), "wb") as fh:
            fh.write(buf.tobytes())
        self.skeletons[name] = skel

    @classmethod
    def attach(cls, other: "BlockStore", **opts) -> "BlockStore":
        """A reader over ANOTHER store's already-built raw files (shared
        skeletons, no rebuild) — how the engine's ablation ``mode`` flags
        reinterpret one set of files through a different swap-in path."""
        if not (cls.raw_format and other.raw_format):
            raise TypeError(
                f"cannot attach {cls.__name__} to {type(other).__name__}: "
                "both ends must use the raw flat-fp file format")
        store = cls(other.workdir, **opts)
        store.skeletons = other.skeletons
        store.order = other.order
        store.digests = other.digests
        store.verify = store.verify or other.verify
        return store.open()

    # ------------------------------------------------------------ integrity
    def _record_digest(self, name: str) -> None:
        """CRC32 of the unit FILE as written (quantized payloads digest
        their carrier bytes; direct-I/O files digest including alignment
        padding — whatever ``read_unit`` will actually pull off storage)."""
        crc = 0
        with open(self._path(name), "rb") as fh:
            while True:
                chunk = fh.read(1 << 20)
                if not chunk:
                    break
                crc = zlib.crc32(chunk, crc)
        self.digests[name] = crc

    def _verify_payload(self, name: str, buf) -> None:
        """Check ``buf`` (the full file payload as read) against the unit's
        build-time digest; no-op unless ``self.verify``. ``buf`` may be any
        buffer-protocol object — for a memmap this forces the page-ins,
        which is exactly the point: corruption is caught on the LOADER
        thread, before assembly, never inside executor compute."""
        if not self.verify:
            return
        want = self.digests.get(name)
        if want is None:
            return
        got = zlib.crc32(memoryview(np.ascontiguousarray(buf)))
        if got != want:
            self.integrity_failures += 1
            raise SwapCorruptionError(
                f"unit {name!r}: payload CRC32 {got:#010x} != recorded "
                f"{want:#010x} ({self.backend} store, "
                f"{self._path(name)})", unit=name)

    # ------------------------------------------------------------ read
    def open(self) -> "BlockStore":
        """Prepare the store for reading. Idempotent; returns self."""
        return self

    def read_unit(self, name: str) -> UnitRead:
        raise NotImplementedError

    def _empty_unit(self, name: str) -> UnitRead:
        """Parameter-less unit (pool/gap/...): nothing to fetch."""
        from repro.core.skeleton import assemble_np
        skel = self.skeletons[name]
        return UnitRead(assemble_np(skel, np.zeros(0, np.uint8)), 0, 0)

    # ------------------------------------------------------------ sizes
    def _path(self, name: str) -> str:
        return os.path.join(self.workdir, escape_name(name) + self.suffix)

    def nbytes(self, name: str) -> int:
        return self.skeletons[name].nbytes

    def stored_nbytes(self, name: str) -> int:
        return self.skeletons[name].nbytes

    def resident_nbytes(self, name: str) -> int:
        return self.stored_nbytes(name)

    def meta_bytes(self) -> int:
        """Resident skeleton overhead (paper Fig. 19a: 0.01-0.06 MB/model)."""
        return sum(s.meta_bytes() for s in self.skeletons.values())


def as_reader(store: BlockStore, mode: str = "snet",
              gpu_dispatch: bool = False) -> BlockStore:
    """Resolve the engine's ablation ``mode`` against a built store.

    ``snet`` reads the store through its own backend; ``copy_in`` and
    ``dummy_asm`` (the paper's Fig. 15 ablation arms) reinterpret a
    raw-format store through the RawIO / dummy-assembly paths.
    """
    from repro.store.mmap_store import MmapStore
    from repro.store.rawio_store import RawIOStore
    if mode == "copy_in":
        return RawIOStore.attach(store, gpu_dispatch=gpu_dispatch)
    if mode == "dummy_asm":
        return MmapStore.attach(store, assembly="dummy")
    return store.open()
