"""DirectIOStore: O_DIRECT swap-in — page-cache-bypassing reads.

The mmap backend rides the kernel page cache: great when blocks re-fault
warm, but on a memory-constrained edge box the page cache is exactly the
memory the budget is trying to protect — every cached block page competes
with the resident block window, and under pressure the kernel reclaims the
cache mid-pipeline, turning "warm" swap-ins cold at the worst moment.
O_DIRECT moves unit bytes storage -> user buffer with no page-cache copy at
all: the read cost is paid once, explicitly, on the loader thread, and the
budget the MemoryLedger enforces is the whole story (no invisible
double-caching of swapped bytes).

Mechanics (this is the only backend with alignment constraints):

  * O_DIRECT requires the buffer address, the file offset, and the byte
    count to all be multiples of the logical block size (``ALIGNMENT`` =
    4096 covers every common case). Unit files are therefore padded to the
    alignment at build time, and reads land in an :class:`AlignedArena` —
    a small pool of page-aligned buffers obtained by over-allocating a
    numpy array and offsetting to the first aligned byte. Buffers are
    reused round-robin across reads (the arena is sized so a buffer is not
    rewritten before its device put completes), so steady-state swap-in
    does zero host allocations.
  * ``queue_depth > 1`` splits a unit read into that many contiguous
    aligned extents issued concurrently (``os.preadv`` per worker) —
    NVMe-class storage needs multiple outstanding requests to reach its
    bandwidth; queue_depth=1 degenerates to one sequential pread.
  * Filesystems that reject O_DIRECT (tmpfs, some overlayfs) are detected
    at ``open()`` by probing a real unit file; the store then falls back to
    buffered preads into the same arena (``direct_io`` records which path
    is live) so the backend stays portable — the accounting and the
    pipeline stages are identical either way.

Accounting: ``io_bytes`` is the ALIGNED byte count actually issued to
storage (file size after padding) — deterministic, so the CI regression
gate can byte-match it; ``nbytes`` / ``ledger_bytes`` stay logical like the
other raw-format backends.
"""
from __future__ import annotations

import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional

import jax
import numpy as np

from repro.store.base import BlockStore, UnitRead

ALIGNMENT = 4096        # logical block size bound: address, offset, count


def _align_up(n: int, a: int = ALIGNMENT) -> int:
    return (n + a - 1) // a * a


class AlignedArena:
    """Pool of page-aligned reusable read buffers.

    numpy cannot request aligned memory directly, so each buffer
    over-allocates by one alignment unit and exposes the slice starting at
    the first aligned address. ``take(nbytes)`` returns an aligned uint8
    view of at least ``nbytes``, growing the backing buffer when a unit is
    larger than anything seen before; buffers rotate round-robin so the
    previous ``depth - 1`` reads stay intact while their device puts drain.
    """

    def __init__(self, depth: int = 4):
        assert depth >= 1, depth
        self._bufs: List[Optional[np.ndarray]] = [None] * depth
        self._next = 0
        self.allocations = 0    # observability: steady state must not grow

    def _alloc(self, nbytes: int) -> np.ndarray:
        raw = np.zeros(nbytes + ALIGNMENT, dtype=np.uint8)
        off = (-raw.ctypes.data) % ALIGNMENT
        self.allocations += 1
        return raw[off:off + nbytes]

    def take(self, nbytes: int) -> np.ndarray:
        """An aligned buffer of >= nbytes (rounded up to the alignment)."""
        need = _align_up(max(nbytes, 1))
        i = self._next
        self._next = (self._next + 1) % len(self._bufs)
        buf = self._bufs[i]
        if buf is None or buf.nbytes < need:
            buf = self._alloc(max(need, ALIGNMENT))
            self._bufs[i] = buf
        return buf[:need]


class DirectIOStore(BlockStore):
    backend = "directio"
    raw_format = True

    def __init__(self, workdir: str, queue_depth: int = 4,
                 arena_depth: int = 4, verify: bool = False):
        assert queue_depth >= 1, queue_depth
        super().__init__(workdir, verify=verify)
        self.queue_depth = queue_depth
        self.arena = AlignedArena(arena_depth)
        self.direct_io: Optional[bool] = None   # resolved by open()
        self._pool: Optional[ThreadPoolExecutor] = None

    # ------------------------------------------------------------ build
    def _write_unit(self, name: str, params: dict) -> None:
        self._write_raw(name, params)
        # pad the file to the alignment so O_DIRECT can read it whole
        path = self._path(name)
        size = os.path.getsize(path)
        pad = _align_up(size) - size
        if pad:
            with open(path, "ab") as fh:
                fh.write(b"\0" * pad)

    def open(self) -> "DirectIOStore":
        if self.direct_io is None:
            self.direct_io = self._probe_direct()
        if self._pool is None and self.queue_depth > 1:
            self._pool = ThreadPoolExecutor(
                max_workers=self.queue_depth,
                thread_name_prefix="directio")
        return self

    def _probe_direct(self) -> bool:
        """O_DIRECT support is a property of the filesystem, not the OS:
        probe with a real read so tmpfs/overlay fall back cleanly."""
        probe = next((n for n in self.order if self.skeletons[n].nbytes), None)
        if probe is None or not hasattr(os, "O_DIRECT"):
            return False
        try:
            fd = os.open(self._path(probe), os.O_RDONLY | os.O_DIRECT)
        except OSError:
            return False
        try:
            os.preadv(fd, [self.arena.take(ALIGNMENT)[:ALIGNMENT]], 0)
            return True
        except OSError:
            return False
        finally:
            os.close(fd)

    # ------------------------------------------------------------ read
    def _read_into(self, path: str, buf: np.ndarray) -> None:
        """Fill ``buf`` (aligned, whole-file size) from ``path`` with
        ``queue_depth`` concurrent aligned extents."""
        flags = os.O_RDONLY | (os.O_DIRECT if self.direct_io else 0)
        fd = os.open(path, flags)
        try:
            total = buf.nbytes
            if self._pool is None or total <= ALIGNMENT * self.queue_depth:
                got = os.preadv(fd, [buf], 0)
                assert got == total, (path, got, total)
                return
            # contiguous aligned extents, one outstanding read per worker
            chunk = _align_up(-(-total // self.queue_depth))
            spans = [(off, min(chunk, total - off))
                     for off in range(0, total, chunk)]

            def issue(span):
                off, ln = span
                got = os.preadv(fd, [buf[off:off + ln]], off)
                assert got == ln, (path, off, got, ln)

            list(self._pool.map(issue, spans))
        finally:
            os.close(fd)

    def read_unit(self, name: str) -> UnitRead:
        from repro.core.skeleton import assemble_np
        skel = self.skeletons[name]
        n = skel.nbytes
        if n == 0:
            return self._empty_unit(name)
        aligned = _align_up(n)
        t0 = time.perf_counter()
        buf = self.arena.take(aligned)
        self._read_into(self._path(name), buf)
        # digest covers the padded file (what storage actually delivered)
        self._verify_payload(name, buf)
        t1 = time.perf_counter()
        host_tree = assemble_np(skel, buf[:n])     # views: zero copy
        t2 = time.perf_counter()
        # the device put MUST copy out of the arena before the buffer
        # rotates back around — block here (loader thread) to guarantee it
        dev = jax.device_put(host_tree)            # batched puts
        jax.block_until_ready(dev)
        t3 = time.perf_counter()
        stages = (("read", t0, t1), ("unpack", t1, t2), ("dispatch", t2, t3))
        return UnitRead(dev, aligned, n, t1 - t0, t3 - t1, stages=stages)

    def stored_nbytes(self, name: str) -> int:
        return _align_up(self.skeletons[name].nbytes)

    def resident_nbytes(self, name: str) -> int:
        """What stays resident is the device copy (logical bytes); the
        alignment padding only exists on storage and in the fixed-size
        arena, never per resident unit."""
        return self.skeletons[name].nbytes
