"""FaultInjector: deterministic storage-fault injection over any backend.

SwapNet re-reads weight blocks from storage on EVERY pass, so the storage
tier's failure modes — a worn SD card returning EIO, an NFS latency spike,
a torn read after power loss, silent bit rot — land directly in the serving
critical path. This wrapper makes those failures *reproducible*: it wraps a
built store of any backend and, on a seed-driven schedule, makes individual
``read_unit`` calls fail the way real storage fails. The rest of the stack
(loader retry/backoff, integrity verification, ledger drain, scheduler
degradation — see docs/ARCHITECTURE.md "Failure handling") is then tested
against the REAL read paths, not mocks.

Fault classes (relative weights via ``mix``; total probability ``p``):

  * ``io``      — the read raises :class:`SwapIOError` (device EIO / missing
                  file class);
  * ``latency`` — the read succeeds but only after a deterministic latency
                  spike (``latency_s`` scaled 0.5-1.5x by the seeded rng) —
                  exercises the per-read deadline path;
  * ``torn``    — the unit file is truncated mid-file before the inner
                  backend reads it (and restored afterwards): whatever the
                  backend raises — a short ``preadv``, a CRC mismatch, an
                  assembly size error — is normalized to
                  :class:`SwapIOError`, the short-read class;
  * ``corrupt`` — ONE BIT of the unit file is flipped before the inner read
                  (and restored afterwards): the backend's CRC32 integrity
                  check (``wrap`` forces ``verify=True`` on the inner store)
                  must catch it and raise :class:`SwapCorruptionError` —
                  the read travels the genuine end-to-end corruption path,
                  never a simulated one.

Tamper-and-restore is the load-bearing trick: faults are applied to the
on-disk bytes and undone in a ``finally``, so a retry of the same unit sees
a clean file (unless the schedule draws a new fault) and the chaos property
"outputs are bit-identical whenever retries eventually succeed" holds by
construction.

Determinism: one ``random.Random(seed)`` drives every draw, and draws
happen in ``read_unit`` call order. A single loader thread per engine makes
single-model runs exactly reproducible; the per-store lock serializes
multi-engine runs (fault COUNTS stay deterministic, interleaving may not).
``force(*kinds)`` pushes an explicit fault script consumed before the rng —
how the tests stage "fail twice, then succeed" without probability math.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from typing import Deque, Dict, Optional, Sequence, Tuple

from repro.errors import SwapCorruptionError, SwapIOError
from repro.store.base import BlockStore, UnitRead

DEFAULT_MIX: Dict[str, float] = {
    "io": 0.35, "latency": 0.25, "torn": 0.15, "corrupt": 0.25}


class FaultInjector(BlockStore):
    """A :class:`BlockStore` that wraps another store and injects faults.

    Build directly through the registry (``backend="faulty"``) with the
    inner backend by name::

        store = build_store(units, workdir, backend="faulty",
                            inner="mmap", p=0.05, seed=1234)

    or wrap an already-built store with :meth:`wrap`. Skeletons, unit order
    and integrity digests are SHARED by reference with the inner store, so
    size accounting and runtime planning see the wrapped backend unchanged.
    """

    backend = "faulty"
    raw_format = False      # refuse as_reader re-interpretation: attaching a
    #                         plain backend to the same files would silently
    #                         bypass the injector

    def __init__(self, workdir: str, inner_store: Optional[BlockStore] = None,
                 p: float = 0.05, seed: int = 0,
                 mix: Optional[Dict[str, float]] = None,
                 latency_s: float = 0.05):
        if inner_store is None:
            raise TypeError("FaultInjector wraps a built store; use "
                            "FaultInjector.wrap(store, ...) or "
                            "build_store(..., backend='faulty', inner=...)")
        assert 0.0 <= p <= 1.0, p
        super().__init__(workdir, verify=True)
        self.inner = inner_store
        # integrity ON: an injected bit flip must surface as
        # SwapCorruptionError, never as silently wrong weights
        self.inner.verify = True
        self.skeletons = inner_store.skeletons
        self.order = inner_store.order
        self.digests = inner_store.digests
        self.p = p
        self.seed = seed
        self.mix = dict(mix or DEFAULT_MIX)
        assert self.mix and all(k in ("io", "latency", "torn", "corrupt")
                                for k in self.mix), self.mix
        self.latency_s = latency_s
        import random
        self._rng = random.Random(seed)
        self._script: Deque[Optional[str]] = deque()
        self._lock = threading.Lock()
        # observability: per-class injected counts + total reads served
        self.injected: Dict[str, int] = {k: 0 for k in
                                         ("io", "latency", "torn", "corrupt")}
        self.reads = 0

    # ------------------------------------------------------------ build/wrap
    @classmethod
    def build(cls, units: Sequence[Tuple[str, dict]], workdir: str,
              inner: str = "mmap", inner_opts: Optional[dict] = None,
              **opts) -> "FaultInjector":
        from repro.store import build_store
        if inner == "faulty":
            raise ValueError("FaultInjector cannot wrap itself")
        store = build_store(units, workdir, backend=inner,
                            **(inner_opts or {}))
        return cls.wrap(store, **opts)

    @classmethod
    def wrap(cls, store: BlockStore, **opts) -> "FaultInjector":
        return cls(store.workdir, inner_store=store, **opts).open()

    def open(self) -> "FaultInjector":
        self.inner.open()
        return self

    # ------------------------------------------------------------ schedule
    def force(self, *kinds: Optional[str]) -> None:
        """Push an explicit fault script: each entry is consumed by the next
        ``read_unit`` call BEFORE the rng draw (None = force a clean read).
        FIFO; deterministic tests stage e.g. ``force("io", "io", None)``."""
        for k in kinds:
            assert k is None or k in self.injected, k
            self._script.append(k)

    def _draw(self) -> Optional[str]:
        if self._script:
            return self._script.popleft()
        if self._rng.random() >= self.p:
            return None
        total = sum(self.mix.values())
        r = self._rng.random() * total
        for kind, w in sorted(self.mix.items()):
            r -= w
            if r < 0:
                return kind
        return next(iter(sorted(self.mix)))

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    # ------------------------------------------------------------ read
    def read_unit(self, name: str) -> UnitRead:
        with self._lock:
            self.reads += 1
            kind = self._draw()
            if kind is None:
                return self.inner.read_unit(name)
            self.injected[kind] += 1
            if kind == "io":
                raise SwapIOError(
                    f"injected I/O error reading unit {name!r}", unit=name)
            if kind == "latency":
                time.sleep(self.latency_s * (0.5 + self._rng.random()))
                return self.inner.read_unit(name)
            if kind == "torn":
                return self._torn_read(name)
            return self._corrupt_read(name)

    def _torn_read(self, name: str) -> UnitRead:
        """Truncate the unit file mid-way, run the REAL inner read against
        it, restore. Every way the backend notices (short preadv, CRC
        mismatch, assembly size error) is the same storage fact — a short
        read — so it is normalized to SwapIOError here."""
        path = self.inner._path(name)
        size = os.path.getsize(path)
        if size < 2:        # nothing to tear; degrade to an I/O fault
            raise SwapIOError(f"injected torn read of unit {name!r} "
                              "(empty file)", unit=name)
        cut = max(1, size // 2)
        with open(path, "rb+") as fh:
            fh.seek(cut)
            tail = fh.read()
            fh.truncate(cut)
        try:
            try:
                self.inner.read_unit(name)
            except Exception as e:
                raise SwapIOError(
                    f"injected torn read of unit {name!r}: file cut to "
                    f"{cut}/{size} bytes ({type(e).__name__}: {e})",
                    unit=name) from e
            raise SwapIOError(     # a backend that missed a torn file has a
                f"injected torn read of unit {name!r} went UNDETECTED by "
                f"the {self.inner.backend} backend", unit=name)  # real bug
        finally:
            with open(path, "rb+") as fh:
                fh.seek(cut)
                fh.write(tail)

    def _corrupt_read(self, name: str) -> UnitRead:
        """Flip one bit of the unit file, run the real inner read (its CRC32
        check must reject the payload), restore. The corruption travels the
        genuine storage -> host path — if the integrity tier ever regresses,
        this surfaces as the UNDETECTED error below, not a green test."""
        path = self.inner._path(name)
        size = os.path.getsize(path)
        if size == 0:
            raise SwapIOError(f"injected corrupt read of unit {name!r} "
                              "(empty file)", unit=name)
        off = self._rng.randrange(size)
        bit = 1 << self._rng.randrange(8)
        with open(path, "rb+") as fh:
            fh.seek(off)
            orig = fh.read(1)
            fh.seek(off)
            fh.write(bytes([orig[0] ^ bit]))
        try:
            try:
                self.inner.read_unit(name)
            except SwapCorruptionError:
                raise                       # the expected, verified outcome
            except Exception as e:          # backend tripped before the CRC
                raise SwapIOError(
                    f"injected corruption in unit {name!r} at byte {off}: "
                    f"({type(e).__name__}: {e})", unit=name) from e
            raise SwapCorruptionError(
                f"injected bit flip in unit {name!r} (byte {off}, mask "
                f"{bit:#04x}) went UNDETECTED by the {self.inner.backend} "
                "backend integrity check", unit=name)
        finally:
            with open(path, "rb+") as fh:
                fh.seek(off)
                fh.write(orig)

    # ------------------------------------------------------------ delegation
    def _write_unit(self, name: str, params: dict) -> None:
        raise NotImplementedError("FaultInjector wraps a built store")

    def nbytes(self, name: str) -> int:
        return self.inner.nbytes(name)

    def stored_nbytes(self, name: str) -> int:
        return self.inner.stored_nbytes(name)

    def resident_nbytes(self, name: str) -> int:
        return self.inner.resident_nbytes(name)

    def meta_bytes(self) -> int:
        return self.inner.meta_bytes()

    @property
    def integrity_failures(self) -> int:        # type: ignore[override]
        return self.inner.integrity_failures

    @integrity_failures.setter
    def integrity_failures(self, value: int) -> None:
        # BlockStore.__init__ assigns 0 before ``inner`` exists; swallow it
        if getattr(self, "inner", None) is not None:
            self.inner.integrity_failures = value
