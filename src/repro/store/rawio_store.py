"""RawIOStore: read()-based swap-in — the w/o-uni-add (``copy_in``) arm.

The standard framework load path the paper ablates against: read() lands the
unit in a page-cache copy, a staging copy materializes it in the process
heap, then the device transfer — 2x resident bytes per unit (3x for models
dispatched through a GPU runtime, which adds its own dispatch copy). Kept as
a first-class backend for ablation parity and because on some storage tiers
(e.g. network filesystems where mmap page faults serialize) buffered read()
is genuinely the faster channel.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.store.base import BlockStore, UnitRead


class RawIOStore(BlockStore):
    backend = "rawio"
    raw_format = True

    def __init__(self, workdir: str, gpu_dispatch: bool = False,
                 verify: bool = False):
        super().__init__(workdir, verify=verify)
        self.gpu_dispatch = gpu_dispatch

    def _write_unit(self, name: str, params: dict) -> None:
        self._write_raw(name, params)

    def resident_nbytes(self, name: str) -> int:
        return (3 if self.gpu_dispatch else 2) * self.skeletons[name].nbytes

    def read_unit(self, name: str) -> UnitRead:
        from repro.core.skeleton import assemble_np
        skel = self.skeletons[name]
        n = skel.nbytes
        if n == 0:
            return self._empty_unit(name)
        t0 = time.perf_counter()
        with open(self._path(name), "rb") as fh:       # read(): page-cache copy
            raw = fh.read()
        staged = np.frombuffer(raw, np.uint8).copy()   # staging copy
        self._verify_payload(name, staged)
        t1 = time.perf_counter()
        host_tree = assemble_np(skel, staged)
        t2 = time.perf_counter()
        dev = jax.device_put(host_tree)                # device transfer
        if self.gpu_dispatch:
            dev = jax.tree.map(jnp.array, dev)         # dispatch copy (.to('cuda'))
            extra = 3 * n
        else:
            extra = 2 * n
        t3 = time.perf_counter()
        stages = (("read", t0, t1), ("unpack", t1, t2), ("dispatch", t2, t3))
        return UnitRead(dev, n, extra, t1 - t0, t3 - t1, stages=stages)
