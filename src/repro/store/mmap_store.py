"""MmapStore: the paper's zero-copy swap-in path, extracted from SwapEngine.

Memory-maps the unit file (direct-I/O analogue: no page-cache staging copy),
assembles host-side by reference (numpy views over the map — O(depth) pointer
writes), then pays the ONE irreducible host->device transfer per unit.
Swap-out stays write-back-free: parameters are immutable, drop references.

``assembly="dummy"`` is the w/o-mod-ske ablation arm: same zero-copy I/O, but
framework-default assembly — instantiate a dummy unit and copy parameters in
(per-tensor copies, 2x resident during assembly).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.store.base import BlockStore, UnitRead


class MmapStore(BlockStore):
    backend = "mmap"
    raw_format = True

    def __init__(self, workdir: str, assembly: str = "ref",
                 verify: bool = False):
        assert assembly in ("ref", "dummy"), assembly
        super().__init__(workdir, verify=verify)
        self.assembly = assembly

    def _write_unit(self, name: str, params: dict) -> None:
        self._write_raw(name, params)

    def resident_nbytes(self, name: str) -> int:
        n = self.skeletons[name].nbytes
        return 2 * n if self.assembly == "dummy" else n

    def read_unit(self, name: str) -> UnitRead:
        from repro.core.skeleton import assemble_dummy, assemble_np
        skel = self.skeletons[name]
        n = skel.nbytes
        if n == 0:
            return self._empty_unit(name)
        t0 = time.perf_counter()
        buf = np.memmap(self._path(name), dtype=np.uint8, mode="r")
        # verify (opt-in) trades mmap's lazy page-in for integrity: the CRC
        # pass faults every page on the loader thread, so a corrupt unit is
        # rejected here instead of being device-put and silently computed on
        self._verify_payload(name, buf)
        t1 = time.perf_counter()
        if self.assembly == "dummy":
            host_tree = assemble_dummy(skel, buf)      # dummy-model copies
            t2 = time.perf_counter()
            dev = jax.device_put(host_tree)       # batched puts
            extra = 2 * n
        else:
            host_tree = assemble_np(skel, buf)         # views: zero copy
            t2 = time.perf_counter()
            dev = jax.device_put(host_tree)       # the one (batched) DMA
            extra = n
        t3 = time.perf_counter()
        # mmap blurs the read stage: the memmap open is O(1) and the actual
        # page-ins fault lazily inside the device put, so "dispatch" carries
        # the storage traffic too (documented in docs/BENCHMARKS.md).
        stages = (("read", t0, t1), ("unpack", t1, t2), ("dispatch", t2, t3))
        return UnitRead(dev, n, extra, t1 - t0, t3 - t1, stages=stages)


class LayerStore(MmapStore):
    """Backwards-compatible name for the default raw store (per-layer flat
    files + resident skeletons). Prefer :class:`MmapStore` in new code."""
