"""Pluggable tiered block-store subsystem (storage tier of the swap path).

Pick a backend by name::

    store = build_store(units, workdir, backend="quant")
    engine = SwapEngine(store)

Backends: ``mmap`` (zero-copy, the paper's full system), ``rawio`` (read()-
based, the copy_in ablation arm), ``quant`` (per-channel quantized swap
units: ``bits=8`` int8 or ``bits=4`` packed int4; ``eager=False`` keeps
fused-routable weights quantized-RESIDENT as QuantizedTensor leaves for the
fused dequant-matmul path and dequantizes the rest on the loader thread),
``directio`` (O_DIRECT page-cache-bypassing reads with an aligned buffer
arena and queue-depth control), ``faulty`` (deterministic fault injection
wrapped around any other backend — ``inner="mmap"``, ``p``, ``seed``; see
faulty.py and the chaos suite). See base.py for the BlockStore contract and
docs/ARCHITECTURE.md for how the tier fits the swap pipeline.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple, Type

from repro.store.base import BlockStore, UnitRead, as_reader, escape_name
from repro.store.directio_store import DirectIOStore
from repro.store.faulty import FaultInjector
from repro.store.mmap_store import LayerStore, MmapStore
from repro.store.quantized_store import QuantizedStore
from repro.store.rawio_store import RawIOStore

STORE_BACKENDS: Dict[str, Type[BlockStore]] = {
    "mmap": MmapStore,
    "rawio": RawIOStore,
    "quant": QuantizedStore,
    "directio": DirectIOStore,
    "faulty": FaultInjector,
}


def build_store(units: Sequence[Tuple[str, dict]], workdir: str,
                backend: str = "mmap", **opts) -> BlockStore:
    """Serialize ``units`` under ``workdir`` through the named backend."""
    if backend not in STORE_BACKENDS:
        raise ValueError(f"unknown store backend {backend!r}; "
                         f"choose from {sorted(STORE_BACKENDS)}")
    return STORE_BACKENDS[backend].build(units, workdir, **opts)


__all__ = ["BlockStore", "UnitRead", "MmapStore", "RawIOStore",
           "QuantizedStore", "DirectIOStore", "FaultInjector", "LayerStore",
           "STORE_BACKENDS", "build_store", "as_reader", "escape_name"]
