"""Calibration pass + precision policy for per-unit mixed-precision swapping.

The end-to-end flow (``--precision mixed --fidelity 1e-2``):

1. :func:`profiler.profile_model` / :func:`profiler.profile_sequential`
   measure each swap unit's output error at int8 and int4 on a small
   calibration batch (versioned ``SensitivityProfile`` artifact).
2. :func:`policy.assign_precisions` solves the knapsack-style per-unit
   int4/int8/fp assignment against a fidelity target
   (:class:`policy.PrecisionPlan`).
3. ``QuantizedStore(plan=...)`` writes each unit at its assigned bits;
   ``cost_model.resident_infos`` + the planner then pack more layers per
   block wherever int4 was safe; SwapStats reports ``bytes_by_precision``.

:func:`calibrate_model` bundles 1+2 for a repro model (it builds a
throwaway LOSSLESS swapped instance to measure on — calibration must see
exact weights, not the quantized store it is about to parameterize).
``python -m repro.calibrate`` is the CLI wrapper.
"""
from __future__ import annotations

import os
import tempfile
from typing import Optional, Tuple

import numpy as np

from repro.calibrate.policy import (PLAN_VERSION, PRECISION_BITS,
                                    PRECISION_LADDER, PrecisionPlan,
                                    assign_precisions)
from repro.calibrate.profiler import (PROFILE_VERSION, SensitivityProfile,
                                      profile_model, profile_sequential,
                                      quantize_roundtrip,
                                      quantize_unit_params,
                                      unit_precision_bytes)

__all__ = [
    "PLAN_VERSION", "PROFILE_VERSION", "PRECISION_BITS", "PRECISION_LADDER",
    "PrecisionPlan", "SensitivityProfile", "assign_precisions",
    "calibrate_model", "calibrate_sequential", "calibration_batch",
    "profile_model", "profile_sequential", "quantize_roundtrip",
    "quantize_unit_params", "unit_precision_bytes",
]

# small by design: calibration rides the production swap path, so batch
# cost is (1 + 2q) swapped passes — keep the batch tiny
CALIB_BATCH, CALIB_SEQ = 2, 16


def calibration_batch(cfg, batch: int = CALIB_BATCH, seq: int = CALIB_SEQ,
                      seed: int = 0) -> dict:
    """Deterministic synthetic prefill batch for an arch (token models get
    uniform token ids, feature models get unit-normal frontend inputs)."""
    rng = np.random.default_rng(seed)
    if cfg.embed_inputs:
        return {"tokens": rng.integers(
            0, cfg.vocab_size, (batch, seq)).astype(np.int32)}
    return {"features": rng.standard_normal(
        (batch, seq, cfg.d_frontend)).astype(np.float32)}


def calibrate_sequential(sw, x, fidelity: float, method: str = "output",
                         seed: int = 0, min_quant_size: int = 1024,
                         headroom: float = 0.7
                         ) -> Tuple[SensitivityProfile, PrecisionPlan]:
    """Profile + assign for a SwappedSequential (bench/scenario path)."""
    prof = profile_sequential(sw, x, method=method, seed=seed,
                              min_quant_size=min_quant_size)
    return prof, assign_precisions(prof, fidelity, headroom=headroom)


def calibrate_model(model, params: dict, fidelity: float,
                    batch: Optional[dict] = None, method: str = "output",
                    seed: int = 0, name: Optional[str] = None,
                    budget: Optional[int] = None, dm=None,
                    prefetch_depth: int = 2, min_quant_size: int = 1024,
                    headroom: float = 0.7, workdir: Optional[str] = None
                    ) -> Tuple[SensitivityProfile, PrecisionPlan]:
    """Profile + assign for a repro model.

    Builds a throwaway MMAP SwappedModel (same ``name`` namespace, so the
    returned plan's unit keys match the quant store the caller builds next)
    and sweeps it with :func:`profiler.profile_model`. ``budget``/``dm``
    partition the throwaway instance when given; otherwise every unit is
    its own block — fine for calibration, whose outputs are plan keys and
    errors, not latencies.
    """
    from repro.core.cost_model import DelayModel
    from repro.core.runtime import SwappedModel

    if batch is None:
        batch = calibration_batch(model.cfg, seed=seed)
    tmp = None
    if workdir is None:
        tmp = tempfile.TemporaryDirectory(prefix="calibrate_")
        workdir = tmp.name
    sm = SwappedModel(model, params, os.path.join(workdir, "calib_store"),
                      prefetch_depth=prefetch_depth, name=name,
                      store_backend="mmap")
    try:
        if budget is not None:
            first = next(iter(batch.values()))
            sm.partition(budget, dm or DelayModel(),
                         int(first.shape[0]), int(first.shape[1]))
        else:
            sm.set_plan(tuple(range(1, len(sm.units))))
        prof = profile_model(sm, batch, method=method, seed=seed,
                             min_quant_size=min_quant_size)
    finally:
        sm.close()
        if tmp is not None:
            tmp.cleanup()
    return prof, assign_precisions(prof, fidelity, headroom=headroom)
