"""CLI for the sensitivity calibration pass.

    python -m repro.calibrate --arch qwen2.5-3b --fidelity 1e-2 \
        --out results/calib_qwen.json --plan-out results/plan_qwen.json

Runs the calibration sweep (method ``output`` by default, ``weight`` for
the free proxy), writes the versioned :class:`SensitivityProfile` artifact,
and — when ``--fidelity`` is given — the solved :class:`PrecisionPlan`.
``serve.py --precision mixed`` runs the same pass in-process; this wrapper
exists so the expensive sweep can be done once offline and its artifacts
inspected or committed.
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.calibrate",
                                 description=__doc__.split("\n")[0])
    ap.add_argument("--arch", required=True,
                    help="model architecture (see repro.configs)")
    ap.add_argument("--reduce", default="smoke",
                    choices=["smoke", "100m", "full"],
                    help="scale preset for the weights (default: smoke)")
    ap.add_argument("--method", choices=["output", "weight"],
                    default="output",
                    help="output = measured rel-L2 at the model output; "
                         "weight = free Frobenius-perturbation proxy")
    ap.add_argument("--fidelity", type=float, default=None,
                    help="max rel-L2 output error target; when given the "
                         "solved PrecisionPlan is emitted too")
    ap.add_argument("--seed", type=int, default=0,
                    help="calibration batch + init seed")
    ap.add_argument("--calib-batch", type=int, default=2)
    ap.add_argument("--calib-seq", type=int, default=16)
    ap.add_argument("--out", default=None,
                    help="write the SensitivityProfile JSON here")
    ap.add_argument("--plan-out", default=None,
                    help="write the PrecisionPlan JSON here "
                         "(requires --fidelity)")
    args = ap.parse_args(argv)
    if args.plan_out and args.fidelity is None:
        ap.error("--plan-out requires --fidelity")

    import jax

    from repro.calibrate import calibrate_model, calibration_batch
    from repro.configs import get_arch
    from repro.launch.train import scale_config
    from repro.models.transformer import Model

    mcfg = scale_config(get_arch(args.arch), args.reduce)
    model = Model(mcfg)
    params = model.init(jax.random.key(args.seed))
    batch = calibration_batch(mcfg, args.calib_batch, args.calib_seq,
                              seed=args.seed)
    # fidelity=inf when only profiling: the solver runs but stops at once
    prof, plan = calibrate_model(model, params,
                                 fidelity=args.fidelity or float("inf"),
                                 batch=batch, method=args.method,
                                 seed=args.seed)
    if args.out:
        prof.save(args.out)
        print(f"profile ({args.method}, {len(prof.units)} units) "
              f"-> {args.out}")
    if args.fidelity is not None:
        hist = plan.histogram()
        print(f"plan @ fidelity {args.fidelity:g}: "
              f"predicted_err {plan.predicted_err:.2e}, "
              f"stored {plan.stored_bytes / 1e6:.2f} MB, "
              f"units {json.dumps(hist)}")
        if args.plan_out:
            plan.save(args.plan_out)
            print(f"plan -> {args.plan_out}")
    if not args.out and args.fidelity is None:
        json.dump(json.loads(prof.to_json()), sys.stdout, indent=2)
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
