"""Precision-assignment policy: sensitivity profile -> :class:`PrecisionPlan`.

The knapsack the mixed-precision path solves (ROADMAP "per-unit mixed
precision"): given each unit's measured output-error contribution at int8
and int4 (see profiler.py) and its stored bytes at every candidate
precision, pick the per-unit assignment fp | int8 | int4 that MINIMIZES the
bytes a swap-in must move — which, through the planner's resident-size
packing (``cost_model.resident_infos``), is what maximizes layers-per-block
under a fixed budget — subject to a fidelity target on the model output.

Error composition: per-unit errors are combined root-sum-square. Unit
quantization perturbations are independent draws (independent rounding
residuals through a shared linear-ish map), so RSS is the first-order
estimate of their joint output error; ``headroom`` shrinks the target the
solver works against to absorb the correlated remainder RSS ignores.

The solver is a greedy ratio ladder, not an LP: start every quantizable
unit at int4 (cheapest bytes), then while the predicted error exceeds the
(headroom-scaled) target, upgrade the unit with the best error-reduction
per extra stored byte one step up the ladder int4 -> int8 -> fp. Greedy on
the squared-error/byte ratio is the classic knapsack relaxation and — the
property the determinism tests pin — the upgrade TRAJECTORY depends only
on the profile, never on the target: a tighter target just walks further
along the same sequence, so per-unit precision is monotone in the target
(fidelity-monotonicity satellite).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Optional

PLAN_VERSION = 1

# upgrade ladder (bytes ascending, error descending); "fp" = raw, exact
PRECISION_LADDER = ("int4", "int8", "fp")
PRECISION_BITS = {"int4": 4, "int8": 8, "fp": 0}
# coarser-first rank used by the monotonicity tests
PRECISION_RANK = {p: i for i, p in enumerate(PRECISION_LADDER)}


@dataclass
class PrecisionPlan:
    """Per-unit precision assignment, the artifact the mixed swap path
    threads end-to-end: ``QuantizedStore`` consumes ``bits_map()`` to pick
    per-leaf bit-widths at build time, the planner packs against the
    resulting per-unit resident bytes, and ``SwapStats.bytes_by_precision``
    reports the realized split."""
    assignments: Dict[str, str]         # unit name -> fp | int8 | int4
    fidelity_target: float              # max rel-L2 model-output error asked
    predicted_err: float                # RSS estimate under the assignment
    stored_bytes: int = 0               # predicted stored payload, all units
    version: int = PLAN_VERSION

    def bits_for(self, name: str) -> int:
        """Bit-width for one unit (0 = raw fp); unknown units stay fp —
        safer to swap a stray unit exact than to quantize unprofiled."""
        return PRECISION_BITS[self.assignments.get(name, "fp")]

    def bits_map(self) -> Dict[str, int]:
        """``{unit: 0|8|4}`` — the shape ``QuantizedStore(plan=...)`` eats
        (duck-typed so the store never imports this package)."""
        return {n: PRECISION_BITS[p] for n, p in self.assignments.items()}

    def histogram(self) -> Dict[str, int]:
        out = {p: 0 for p in PRECISION_LADDER}
        for p in self.assignments.values():
            out[p] += 1
        return out

    # ------------------------------------------------------------ serialize
    def to_json(self) -> str:
        """Canonical (sorted, fixed-separator) encoding: two plans born from
        the same profile + target are byte-identical (determinism test)."""
        return json.dumps({
            "version": self.version,
            "fidelity_target": self.fidelity_target,
            "predicted_err": self.predicted_err,
            "stored_bytes": self.stored_bytes,
            "assignments": dict(sorted(self.assignments.items())),
        }, sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, s: str) -> "PrecisionPlan":
        d = json.loads(s)
        if d.get("version") != PLAN_VERSION:
            raise ValueError(f"PrecisionPlan version {d.get('version')!r} "
                             f"!= supported {PLAN_VERSION}")
        return cls(assignments=dict(d["assignments"]),
                   fidelity_target=float(d["fidelity_target"]),
                   predicted_err=float(d["predicted_err"]),
                   stored_bytes=int(d.get("stored_bytes", 0)),
                   version=int(d["version"]))

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "PrecisionPlan":
        with open(path) as fh:
            return cls.from_json(fh.read())


@dataclass
class _UnitState:
    name: str
    bytes_by: Dict[str, int]            # precision -> stored bytes
    err_by: Dict[str, float] = field(default_factory=dict)
    level: int = 0                      # index into PRECISION_LADDER

    @property
    def precision(self) -> str:
        return PRECISION_LADDER[self.level]

    def err(self, level: Optional[int] = None) -> float:
        p = PRECISION_LADDER[self.level if level is None else level]
        return 0.0 if p == "fp" else self.err_by.get(p, 0.0)


def assign_precisions(profile, fidelity: float,
                      headroom: float = 0.7) -> PrecisionPlan:
    """Solve the assignment for a fidelity target (max rel-L2 model-output
    error). ``profile`` is a :class:`~repro.calibrate.profiler
    .SensitivityProfile` (or anything with its ``units`` mapping:
    ``name -> {bytes_fp, bytes_int8, bytes_int4, err_int8, err_int4}``).

    ``headroom`` < 1 shrinks the target the RSS estimate must meet, leaving
    margin for the correlated error the independence assumption drops —
    the bench gates the MEASURED mixed-arm error against the full target.
    """
    if fidelity <= 0:
        raise ValueError(f"fidelity target must be > 0 (got {fidelity!r})")
    states = []
    for name in sorted(profile.units):
        u = profile.units[name]
        st = _UnitState(name, {
            "fp": int(u["bytes_fp"]),
            "int8": int(u["bytes_int8"]),
            "int4": int(u["bytes_int4"]),
        }, {"int8": float(u["err_int8"]), "int4": float(u["err_int4"])})
        # nothing quantizable in the unit -> identical bytes at every
        # precision: keep it fp so the store round-trips it bit-exactly
        if st.bytes_by["int4"] >= st.bytes_by["fp"]:
            st.level = PRECISION_RANK["fp"]
        states.append(st)

    def combined() -> float:
        return sum(s.err() ** 2 for s in states) ** 0.5

    target = fidelity * headroom
    while combined() > target:
        best = None                     # (ratio, gain, name) max
        for s in states:
            if s.precision == "fp":
                continue
            gain = s.err() ** 2 - s.err(s.level + 1) ** 2
            cost = max(s.bytes_by[PRECISION_LADDER[s.level + 1]]
                       - s.bytes_by[s.precision], 1)
            key = (gain / cost, gain, s.name)
            if best is None or key > best[0]:
                best = (key, s)
        if best is None or best[0][1] <= 0.0:
            break                       # every unit exact, or no gain left
        best[1].level += 1

    total = sum(s.bytes_by[s.precision] for s in states)
    return PrecisionPlan(
        assignments={s.name: s.precision for s in states},
        fidelity_target=float(fidelity),
        predicted_err=float(combined()),
        stored_bytes=int(total))
