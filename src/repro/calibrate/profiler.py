"""Per-unit quantization-sensitivity profiler (mixed-precision tentpole).

Answers one question per (swap unit, candidate precision): if ONLY this
unit's quantizable leaves round-trip through int8 / packed int4 — exactly
the transform ``QuantizedStore`` applies at build time — how far does the
MODEL OUTPUT move? The per-unit answers feed policy.assign_precisions,
which spends the fidelity budget where bytes buy the least error.

Two measurement methods:

* ``output`` — the reference method. One clean swapped pass records the
  reference output, then one pass per (unit x precision) with that unit's
  params replaced by their host quantize->dequantize round-trip (via the
  executors' ``param_override`` hook, so the sweep runs block-by-block
  under the same budget as production — ``forward_partial`` on the model
  path). Error = relative L2 at the model output. Cost: 1 + 2q passes for
  q quantizable units, on a SMALL calibration batch.
* ``weight`` — the cheap proxy (Fisher/grad-norm style, with the gradient
  replaced by the identity): relative Frobenius perturbation
  ``||W - Wq||_F / ||W||_F`` per unit. No forward passes at all; first-order
  correct when units contribute error roughly proportionally to their
  relative weight perturbation. Use it when even the small calibration
  sweep is too slow (fleet-scale registration).

The result persists as a versioned JSON artifact keyed by arch + unit/leaf
shapes + method + seed (``SensitivityProfile``), so a saved profile is
rejected rather than silently misapplied when the model it was measured on
changes shape.
"""
from __future__ import annotations

import json
import hashlib
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import numpy as np

from repro.kernels.dequant import (quantize_int4, quantize_int8,
                                   unpack_int4)
from repro.store.quantized_store import quantizable, unit_stored_nbytes

PROFILE_VERSION = 1
CANDIDATE_BITS = {"int8": 8, "int4": 4}


# --------------------------------------------------------------- round-trip
def quantize_roundtrip(arr: np.ndarray, bits: int) -> np.ndarray:
    """Host quantize -> dequantize mirroring the store's numerics exactly
    (same quantizers, same fp32 multiply), so measured sensitivity is the
    sensitivity the quant store will realize."""
    x = np.asarray(arr)
    if bits == 8:
        q, scales = quantize_int8(x)
        vals = q
    elif bits == 4:
        carrier, scales = quantize_int4(x)
        rows = int(np.prod(x.shape[:-1])) if x.ndim >= 2 else 1
        vals = unpack_int4(carrier, rows)
    else:
        raise ValueError(f"bits must be 8 or 4 (got {bits})")
    out = np.multiply(vals, scales[None, :], dtype=np.float32)
    return out.reshape(x.shape).astype(x.dtype)


def quantize_unit_params(params, bits: int, min_quant_size: int = 1024):
    """Round-trip every leaf the quant store would quantize; other leaves
    pass through untouched (the store keeps them raw)."""
    return jax.tree.map(
        lambda a: (quantize_roundtrip(np.asarray(a), bits)
                   if quantizable(np.asarray(a), min_quant_size)
                   else np.asarray(a)),
        params)


def unit_precision_bytes(params, min_quant_size: int = 1024) -> Dict[str, int]:
    """Stored bytes of one unit at each candidate precision (exact: matches
    the quant store's aligned segment layout byte-for-byte)."""
    return {"fp": unit_stored_nbytes(params, 0, min_quant_size),
            "int8": unit_stored_nbytes(params, 8, min_quant_size),
            "int4": unit_stored_nbytes(params, 4, min_quant_size)}


def _rel_l2(y, y_ref) -> float:
    a = np.asarray(y, np.float64).ravel()
    b = np.asarray(y_ref, np.float64).ravel()
    denom = float(np.linalg.norm(b))
    return float(np.linalg.norm(a - b)) / (denom if denom > 0.0 else 1.0)


def _weight_err(params, bits: int, min_quant_size: int) -> float:
    """``weight`` proxy: relative Frobenius perturbation over the unit."""
    num = den = 0.0
    for leaf in jax.tree.leaves(params):
        arr = np.asarray(leaf)
        if not np.issubdtype(arr.dtype, np.floating):
            continue
        x = arr.astype(np.float64)
        den += float(np.sum(x * x))
        if quantizable(arr, min_quant_size):
            d = (quantize_roundtrip(arr, bits).astype(np.float64) - x)
            num += float(np.sum(d * d))
    return (num / den) ** 0.5 if den > 0.0 else 0.0


def _unit_signature(name: str, params) -> str:
    leaves = jax.tree.leaves(params)
    sig = [f"{np.asarray(a).shape}:{np.asarray(a).dtype}" for a in leaves]
    return f"{name}|" + ",".join(sig)


# ----------------------------------------------------------------- artifact
@dataclass
class SensitivityProfile:
    """Versioned calibration artifact: per-unit error at each candidate
    precision plus the exact stored-bytes table the policy packs against."""
    arch: str
    method: str                          # output | weight
    seed: int
    signature: str                       # digest of arch + unit/leaf shapes
    units: Dict[str, Dict[str, float]] = field(default_factory=dict)
    batch_shape: tuple = ()
    version: int = PROFILE_VERSION

    def to_json(self) -> str:
        return json.dumps({
            "version": self.version,
            "arch": self.arch,
            "method": self.method,
            "seed": self.seed,
            "signature": self.signature,
            "batch_shape": list(self.batch_shape),
            "units": {n: dict(sorted(u.items()))
                      for n, u in sorted(self.units.items())},
        }, sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, s: str) -> "SensitivityProfile":
        d = json.loads(s)
        if d.get("version") != PROFILE_VERSION:
            raise ValueError(f"SensitivityProfile version {d.get('version')!r}"
                             f" != supported {PROFILE_VERSION}")
        return cls(arch=d["arch"], method=d["method"], seed=int(d["seed"]),
                   signature=d["signature"],
                   units={n: dict(u) for n, u in d["units"].items()},
                   batch_shape=tuple(d.get("batch_shape", ())),
                   version=int(d["version"]))

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "SensitivityProfile":
        with open(path) as fh:
            return cls.from_json(fh.read())


def shape_signature(named_units) -> str:
    """Digest over unit names + leaf shapes/dtypes: the key that pins a
    saved profile to the exact model geometry it was measured on."""
    h = hashlib.sha256()
    for name, params in named_units:
        h.update(_unit_signature(name, params).encode())
        h.update(b";")
    return h.hexdigest()[:16]


# ---------------------------------------------------------------- profilers
def _profile(named_units, run_clean, run_override, arch: str, method: str,
             seed: int, min_quant_size: int,
             batch_shape: tuple) -> SensitivityProfile:
    """Shared sweep driver. ``run_clean()`` -> reference output;
    ``run_override(name, qparams)`` -> output with one unit substituted.
    Either may be None for method='weight' (never called)."""
    prof = SensitivityProfile(
        arch=arch, method=method, seed=seed,
        signature=shape_signature(named_units), batch_shape=batch_shape)
    y_ref = run_clean() if method == "output" else None
    for name, params in named_units:
        row: Dict[str, float] = dict(unit_precision_bytes(params,
                                                          min_quant_size))
        row = {f"bytes_{k}": int(v) for k, v in row.items()}
        has_q = any(quantizable(np.asarray(a), min_quant_size)
                    for a in jax.tree.leaves(params))
        for prec, bits in CANDIDATE_BITS.items():
            if not has_q:
                err = 0.0
            elif method == "weight":
                err = _weight_err(params, bits, min_quant_size)
            elif method == "output":
                qp = quantize_unit_params(params, bits, min_quant_size)
                err = _rel_l2(run_override(name, qp), y_ref)
            else:
                raise ValueError(f"unknown method {method!r}")
            row[f"err_{prec}"] = err
        prof.units[name] = row
    return prof


def profile_sequential(sw, x, method: str = "output", seed: int = 0,
                       min_quant_size: int = 1024) -> SensitivityProfile:
    """Profile a :class:`~repro.core.runtime.SwappedSequential` on input
    ``x`` — the perturbed passes run through sw.forward via its
    ``param_override`` hook, block-by-block under the executor's budget."""
    assert sw.plan is not None, "call partition_with()/set_plan() first"
    names = [n for n, _ in sw.named_units]

    def run(override) -> np.ndarray:
        sw.param_override = override
        try:
            y, _ = sw.forward(x)
            return np.asarray(y)
        finally:
            sw.param_override = None

    return _profile(
        sw.named_units,
        run_clean=lambda: run(None),
        run_override=lambda name, qp, _n=names: run(
            lambda i, p: qp if _n[i] == name else p),
        arch="sequential", method=method, seed=seed,
        min_quant_size=min_quant_size,
        batch_shape=tuple(np.asarray(x).shape))


def profile_model(sm, batch: dict, method: str = "output", seed: int = 0,
                  min_quant_size: int = 1024) -> SensitivityProfile:
    """Profile a :class:`~repro.core.runtime.SwappedModel` on a prefill
    ``batch`` — unit names come back NAMESPACED exactly as the model's
    store/planner see them, so the resulting plan keys line up."""
    assert sm.plan is not None, "call partition()/set_plan() first"
    seen, named = set(), []
    for u in sm.units:                 # shared units appear once per use;
        if u.name in seen:             # profile (and store) them once
            continue
        seen.add(u.name)
        named.append((u.name, u.params))

    def run(override) -> np.ndarray:
        sm.param_override = override
        try:
            logits, _ = sm.forward(batch)
            return np.asarray(logits)
        finally:
            sm.param_override = None

    shape = tuple(np.asarray(next(iter(batch.values()))).shape)
    return _profile(
        named,
        run_clean=lambda: run(None),
        run_override=lambda name, qp: run(
            lambda u, p: qp if u.name == name else p),
        arch=sm.cfg.name, method=method, seed=seed,
        min_quant_size=min_quant_size, batch_shape=shape)
