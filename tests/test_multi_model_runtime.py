"""Async multi-model swap runtime: prefetch depth, shared ledger, block cache.

Covers the ISSUE-1 acceptance invariants:
  * prefetch depth m in {1, 2, 3} keeps swapped output bit-identical to
    direct (unswapped) execution of the same per-unit graph;
  * cache-pinned shared blocks are charged to the ledger exactly once, no
    matter how many blocks/handles reference them;
  * two models served interleaved under ONE budget never exceed it.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.core.cost_model import DelayModel, LayerInfo
from repro.core.multi_model import MultiModelRuntime
from repro.core.partition import plan_peak_bytes, simulate_pipeline
from repro.core.runtime import SwappedModel
from repro.core.swap_engine import BlockCache, MemoryLedger
from repro.models.transformer import Model

from conftest import make_batch


def _setup(arch, seed=0):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    shape = ShapeConfig("p", 32, 2, "prefill")
    batch = make_batch(cfg, shape)
    return cfg, model, params, batch


# ------------------------------------------------------------ prefetch depth
def test_prefetch_depth_bit_identical():
    """m=1 (serial), m=2 (double buffer) and m=3 (deep pipeline) must all
    produce byte-for-byte the same logits: pipelining changes WHEN blocks
    load, never WHAT executes. Also allclose vs the whole-model jit (the
    repo's lossless standard; residual diffs there are XLA fusion order)."""
    cfg, model, params, batch = _setup("qwen2.5-3b")
    ref, _ = jax.jit(model.prefill)(params, batch)
    outs = {}
    for m in (1, 2, 3):
        with tempfile.TemporaryDirectory() as d:
            sm = SwappedModel(model, params, d, mode="snet", prefetch_depth=m)
            sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(),
                         batch=2, seq=32)
            assert sm.plan.m == m
            logits, stats = sm.forward(batch)
            outs[m] = np.asarray(logits)
            sm.close()
        assert stats["peak_resident_mb"] > 0
    np.testing.assert_array_equal(outs[1], outs[2])
    np.testing.assert_array_equal(outs[2], outs[3])
    np.testing.assert_allclose(outs[2], np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_deeper_pipeline_holds_more_blocks():
    """An m=3 plan may keep 3 blocks resident; the ledger peak must reflect
    it (and stay within the window bound the planner promised)."""
    cfg, model, params, batch = _setup("qwen2.5-3b")
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet", prefetch_depth=3)
        sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(), batch=2, seq=32)
        from repro.core.partition import create_blocks
        s, _, _ = create_blocks(sm.plan, sm.planner.sizes, sm.planner.depths,
                                sm.planner.flops)
        sm.forward(batch)
        peak = sm.engine.stats.peak_resident
        sm.close()
    assert peak <= plan_peak_bytes(s, 3) + 1


def test_simulate_pipeline_monotone_in_depth():
    """Deeper prefetch can only help (same blocks, more residency)."""
    dm = DelayModel(alpha=1e-9, beta=0, gamma=1e-10, eta=1e-6)
    s = np.array([1e9, 2e9, 1e9, 2e9, 1e9])
    d = np.ones(5)
    f = np.array([1.5e10] * 5)
    t1 = simulate_pipeline(s, d, f, dm, m=1)
    t2 = simulate_pipeline(s, d, f, dm, m=2)
    t3 = simulate_pipeline(s, d, f, dm, m=3)
    assert t3 <= t2 <= t1
    assert t2 < t1          # overlap must actually buy something here


def test_plan_peak_bytes_window():
    s = np.array([3.0, 1.0, 4.0, 1.0, 5.0])
    assert plan_peak_bytes(s, 1) == 5.0
    assert plan_peak_bytes(s, 2) == 6.0          # 1+5
    assert plan_peak_bytes(s, 3) == 10.0         # 4+1+5
    assert plan_peak_bytes(s, 99) == s.sum()     # window capped at n


# ------------------------------------------------------------ block cache
def test_cache_lru_eviction_and_refcount():
    ledger = MemoryLedger()
    cache = BlockCache(capacity=100, ledger=ledger, admit_frac=1.0)
    cache.put("a", {"w": 1}, 60)
    cache.put("b", {"w": 2}, 60)                 # over capacity: "a" evicted
    assert cache.acquire("a") is None
    assert cache.acquire("b") is not None        # refcount 1 now
    # "b" is in use (not evictable); the fresh idle insert is dropped instead
    # — the engine then charges its handle, so no bytes escape the ledger.
    cache.put("c", {"w": 3}, 60)
    assert cache.acquire("c", count=False) is None
    assert cache.resident_bytes == 60
    cache.release("b")
    cache.put("d", {"w": 4}, 60)                 # now "b" (LRU, idle) goes
    assert cache.acquire("b", count=False) is None
    assert cache.acquire("d", count=False) is not None
    assert ledger.resident == cache.resident_bytes


def test_cache_pinned_never_evicted():
    ledger = MemoryLedger()
    cache = BlockCache(capacity=10, ledger=ledger, admit_frac=1.0)
    cache.pin(["hot"])
    assert cache.admits("hot", 10**9)            # pinned bypasses capacity
    cache.put("hot", {"w": 0}, 10**6)
    cache.put("x", {"w": 1}, 10)
    cache.put("y", {"w": 2}, 10)                 # evicts "x", never "hot"
    assert cache.acquire("hot", count=False) is not None
    assert cache.acquire("x", count=False) is None


def test_shared_block_ledger_counted_once():
    """zamba2's shared attention block is referenced by every other layer;
    the cache must charge it to the ledger exactly once and serve repeats
    from memory."""
    cfg, model, params, batch = _setup("zamba2-7b")
    ref, _ = jax.jit(model.prefill)(params, batch)
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet")
        n_shared_refs = sum(1 for u in sm.units if u.name == "shared_attn")
        assert n_shared_refs >= 2
        sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(), batch=2, seq=32)
        logits, _ = sm.forward(batch)
        eng = sm.engine
        shared_nbytes = sm.store.nbytes("shared_attn")
        # exactly one ledger entry for the shared unit, of one unit's bytes
        assert eng.cache.resident_bytes == shared_nbytes
        # after the pass only the cache-resident shared unit stays charged
        assert eng.ledger.resident == shared_nbytes
        # repeat pass: every shared reference after the first is a cache hit
        eng.stats.__init__()
        logits2, stats = sm.forward(batch)
        assert stats["cache_hit_rate"] > 0
        assert eng.cache.resident_bytes == shared_nbytes
        sm.close()
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits2))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ multi-model
def test_two_models_one_budget_never_exceeded():
    """Two models interleaved under one shared budget: the ledger never
    exceeds it (enforced, not just observed), outputs stay lossless, and
    repeat requests are byte-stable and hit the shared cache."""
    budget = 24 * 1024 * 1024
    archs = ["qwen2.5-3b", "gemma2-9b"]
    setups = {a: _setup(a, seed=i) for i, a in enumerate(archs)}
    refs = {a: jax.jit(m.prefill)(p, b)[0]
            for a, (c, m, p, b) in setups.items()}
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(budget, cache_frac=0.25, prefetch_depth=2)
        for a, (cfg, model, params, _) in setups.items():
            rt.add_model(a, model, params, d)
        rt.plan(batch=2, seq=32)
        assert rt.block_budget() <= budget - rt.cache.capacity
        first, second = {}, {}
        for rnd in range(2):
            for a in archs:
                logits, _ = rt.forward(a, setups[a][3])
                (first if rnd == 0 else second)[a] = np.asarray(logits)
        st = rt.stats()
        rt.close()
    assert st["peak_resident_mb"] * 1e6 <= budget
    assert st["cache_hits"] > 0                  # round 2 reused hot units
    for a in archs:
        np.testing.assert_array_equal(first[a], second[a])
        np.testing.assert_allclose(first[a], np.asarray(refs[a][:, -1:]),
                                   rtol=1e-4, atol=1e-4)


def test_multi_model_budget_too_small_rejected():
    archs = ["qwen2.5-3b", "gemma2-9b"]
    setups = {a: _setup(a, seed=i) for i, a in enumerate(archs)}
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(4096, cache_frac=0.25)   # 4 KB: hopeless
        for a, (cfg, model, params, _) in setups.items():
            rt.add_model(a, model, params, d)
        with pytest.raises(ValueError):
            rt.plan(batch=2, seq=32)
        rt.close()


def test_abandoned_request_releases_ledger():
    """A request that dies mid-forward (body exception / caller bailing) must
    release its resident blocks AND its in-flight prefetches — on a shared
    ledger a leak here would charge a dead request's bytes against every
    other tenant forever."""
    from repro.core.runtime import swap_schedule
    cfg, model, params, batch = _setup("qwen2.5-3b")
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet")
        sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(), batch=2, seq=32)
        assert sm.plan.n_blocks >= 2
        gen = swap_schedule(sm.engine, sm.plan.blocks(),
                            [u.name for u in sm.units], sm.plan.m)
        next(gen)            # block 0 resident, block 1 prefetching
        gen.close()          # request abandoned mid-run
        # only cache-resident bytes may remain charged
        assert sm.engine.ledger.resident == sm.engine.cache.resident_bytes
        logits, _ = sm.forward(batch)        # runtime still serviceable
        sm.close()
    assert np.asarray(logits).shape[0] == 2


def test_multi_model_namespacing():
    """Two instances of the SAME arch must not collide in the shared store
    or cache (unit names are namespaced per model)."""
    cfg, model, params, batch = _setup("qwen2.5-3b")
    _, model2, params2, _ = _setup("qwen2.5-3b", seed=1)
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(24 * 1024 * 1024)
        rt.add_model("a", model, params, d)
        rt.add_model("b", model2, params2, d)
        rt.plan(batch=2, seq=32)
        la, _ = rt.forward("a", batch)
        lb, _ = rt.forward("b", batch)
        rt.close()
    # different seeds => different weights => different logits
    assert not np.allclose(np.asarray(la), np.asarray(lb))
