"""Concurrent priority-aware multi-tenant serving (ISSUE 5).

Covers the tentpole's acceptance invariants:
  * bit-identity under concurrency AND under block-boundary preemption
    (a preempted+resumed pass re-executes nothing);
  * single-charge of shared blocks with concurrent executors;
  * the shared ledger never exceeds the budget under adversarial
    interleavings (fuzzed reserve/add/drop and real concurrent serving);
  * priority wakeup on the blocking ``reserve()``;
  * the priority-inversion regression: a high-urgency arrival is served
    before earlier low-priority queue entries instead of draining behind
    them;
  * ``MultiModelRuntime`` planning edges: ``block_budget() <= 0`` raises,
    ``cache_frac=0.0`` serves correctly with no cache;
  * ``replan_budgets`` reacting to the live urgency mix.
"""
import dataclasses
import tempfile
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.core.cost_model import DelayModel
from repro.core.multi_model import MultiModelRuntime
from repro.core.runtime import SwappedModel
from repro.core.serving_scheduler import RequestQueue, ServingRequest, \
    ServingScheduler
from repro.core.swap_engine import MemoryLedger
from repro.models.transformer import Model

from conftest import make_batch


def _setup(arch, seed=0):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    batch = make_batch(cfg, ShapeConfig("p", 32, 2, "prefill"))
    return cfg, model, params, batch


# ----------------------------------------------------------------- ledger
def test_reserve_blocks_until_bytes_free():
    led = MemoryLedger(100)
    led.add("a", 80)
    admitted = []

    def waiter():
        led.reserve("b", 50, priority=1.0)
        admitted.append("b")

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    assert not admitted           # 80 + 50 > 100: must be waiting
    led.drop("a")
    t.join(timeout=5)
    assert admitted == ["b"]
    assert led.resident == 50
    assert led.peak <= 100


def test_reserve_priority_wakeup_order():
    """When bytes free, the HIGHEST-priority waiter is admitted first,
    regardless of wait order; FIFO within one priority class."""
    led = MemoryLedger(100)
    led.add("filler", 100)
    order = []
    started = []

    def waiter(name, prio):
        started.append(name)
        led.reserve(name, 60, priority=prio)
        order.append(name)
        time.sleep(0.05)          # hold so admissions serialize observably
        led.drop(name)

    threads = []
    for name, prio in (("lo", 1.0), ("mid", 2.0), ("hi", 8.0)):
        t = threading.Thread(target=waiter, args=(name, prio))
        t.start()
        threads.append(t)
        time.sleep(0.05)          # deterministic wait order: lo, mid, hi
    assert started == ["lo", "mid", "hi"] and not order
    led.drop("filler")
    for t in threads:
        t.join(timeout=5)
    assert order == ["hi", "mid", "lo"]
    assert led.peak <= 100


def test_reserve_timeout_and_never_fits():
    led = MemoryLedger(100)
    with pytest.raises(MemoryError):
        led.reserve("huge", 101)          # can never fit: fail fast
    led.add("a", 90)
    t0 = time.perf_counter()
    with pytest.raises(MemoryError):
        led.reserve("b", 50, timeout=0.1)
    assert time.perf_counter() - t0 < 2.0
    assert led.resident == 90             # failed reserve charged nothing


def test_ledger_never_exceeds_budget_adversarial():
    """Fuzzed interleavings: many threads adding/reserving/dropping random
    sizes; the budget is an invariant, not an observation."""
    budget = 1000
    led = MemoryLedger(budget)
    rng_seed = 0

    def hammer(tid):
        rng = np.random.default_rng(tid + rng_seed)
        held = []
        for i in range(200):
            if held and rng.random() < 0.45:
                led.drop(held.pop())
            else:
                key = (tid, i)
                n = int(rng.integers(1, 400))
                if rng.random() < 0.5:
                    try:
                        led.add(key, n)
                        held.append(key)
                    except MemoryError:
                        pass
                else:
                    try:
                        led.reserve(key, n, priority=float(tid % 3),
                                    timeout=0.02)
                        held.append(key)
                    except MemoryError:
                        pass
        for key in held:
            led.drop(key)

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert led.peak <= budget
    assert led.resident == 0


# ------------------------------------------------------------ request queue
def test_request_queue_urgency_weighted_deadline():
    q = RequestQueue(default_slack=1.0)
    now = time.perf_counter()
    lo = ServingRequest("a", {}, priority=1.0, rid=0, arrival=now)
    hi = ServingRequest("b", {}, priority=8.0, rid=1, arrival=now + 0.01)
    tight = ServingRequest("c", {}, priority=1.0, deadline=0.05, rid=2,
                           arrival=now + 0.02)
    for r in (lo, hi, tight):
        q.submit(r)
    assert q.max_waiting_priority() == 8.0
    assert q.urgency_mix() == {"a": 1.0, "b": 8.0, "c": 1.0}
    # explicit 50 ms deadline beats urgency-8's 1s/8 slack; both beat lo
    assert q.pop_ready().rid == 2
    assert q.pop_ready().rid == 1
    assert q.pop_ready().rid == 0


def test_request_queue_busy_model_filter():
    q = RequestQueue(default_slack=1.0)
    now = time.perf_counter()
    q.submit(ServingRequest("a", {}, priority=8.0, rid=0, arrival=now))
    q.submit(ServingRequest("b", {}, priority=1.0, rid=1, arrival=now))
    got = q.pop_ready(busy=("a",))
    assert got.rid == 1                   # urgent req's model is busy
    assert q.pop_ready(busy=("a",), timeout=0.01) is None
    assert q.pop_ready().rid == 0         # still queued, served once free


# ----------------------------------------------------- preemption / resume
def test_preempted_pass_resumes_bit_identical():
    """Yield at EVERY block boundary; the stitched pass must be
    byte-for-byte the uninterrupted pass, and each pause must leave only
    cache-resident bytes charged (prefetches drained)."""
    cfg, model, params, batch = _setup("qwen2.5-3b")
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet")
        sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(), batch=2, seq=32)
        assert sm.plan.n_blocks >= 2
        ref, _ = sm.forward(batch)
        state, stats = sm.forward_partial(batch,
                                          should_yield=lambda s: True)
        resumes = 0
        while stats is None:
            assert sm.engine.ledger.resident == \
                sm.engine.cache.resident_bytes
            resumes += 1
            state, stats = sm.forward_partial(batch, state=state,
                                              should_yield=lambda s: True)
        sm.close()
    assert resumes == sm.plan.n_blocks - 1
    assert stats["preemptions"] == resumes
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(state.logits))


def test_scheduler_concurrent_bit_identity_and_budget():
    """2 executors, mixed priorities, repeated requests: every response
    equals the unswapped reference, repeats are byte-stable, and the shared
    ledger never exceeded the budget."""
    budget = 24 * 1024 * 1024
    archs = ["qwen2.5-3b", "gemma2-9b"]
    setups = {a: _setup(a, seed=i) for i, a in enumerate(archs)}
    refs = {a: np.asarray(jax.jit(m.prefill)(p, b)[0][:, -1:])
            for a, (c, m, p, b) in setups.items()}
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(budget, cache_frac=0.25, executors=2)
        for a, (cfg, model, params, _) in setups.items():
            rt.add_model(a, model, params, d)
        rt.plan(batch=2, seq=32)
        with ServingScheduler(rt) as sched:
            reqs = []
            for rnd in range(3):
                for a in archs:
                    prio = 8.0 if rnd == 1 else 1.0
                    reqs.append(sched.submit(a, setups[a][3], priority=prio))
            for r in reqs:
                r.wait(timeout=300)
        st = rt.stats()
        rt.close()
    assert st["peak_resident_mb"] * 1e6 <= budget
    assert rt.ledger.peak <= budget
    by_model = {}
    for r in reqs:
        got = np.asarray(r.logits)
        np.testing.assert_allclose(got, refs[r.model], rtol=1e-4, atol=1e-4)
        if r.model in by_model:              # repeats are byte-stable
            np.testing.assert_array_equal(got, by_model[r.model])
        by_model[r.model] = got
    assert len(sched.completed) == len(reqs)


def test_scheduler_shared_blocks_single_charge_concurrent():
    """zamba2's pinned shared block under CONCURRENT serving: after the
    queue drains, the only charged bytes are the cache's, and the shared
    unit was charged exactly once."""
    archs = ["zamba2-7b", "qwen2.5-3b"]
    setups = {a: _setup(a, seed=i) for i, a in enumerate(archs)}
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(32 * 1024 * 1024, cache_frac=0.2, executors=2)
        for a, (cfg, model, params, _) in setups.items():
            rt.add_model(a, model, params, d)
        rt.plan(batch=2, seq=32)
        with ServingScheduler(rt) as sched:
            reqs = [sched.submit(a, setups[a][3],
                                 priority=float(1 + (i % 2) * 7))
                    for i in range(4) for a in archs]
            for r in reqs:
                r.wait(timeout=300)
        shared = rt.models["zamba2-7b"].store.nbytes("zamba2-7b/shared_attn")
        assert shared > 0
        # every in-flight handle dropped: only cache entries stay charged,
        # and the pinned shared unit is exactly one of them (single charge)
        assert rt.ledger.resident == rt.cache.resident_bytes
        assert rt.cache.resident_bytes >= shared
        rt.close()


def test_priority_inversion_regression():
    """One executor, a backlog of low-priority work, then a high-urgency
    arrival: it must complete BEFORE the queued low-priority requests
    (with preemption it overtakes the in-flight pass at a block boundary
    instead of waiting out the whole backlog)."""
    archs = ["qwen2.5-3b", "gemma2-9b"]
    setups = {a: _setup(a, seed=i) for i, a in enumerate(archs)}
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(10 * 1024 * 1024, cache_frac=0.25,
                               executors=1)
        for a, (cfg, model, params, _) in setups.items():
            rt.add_model(a, model, params, d)
        rt.plan(batch=2, seq=32)
        for a in archs:
            rt.forward(a, setups[a][3])          # warm outside the clock
        sched = ServingScheduler(rt, executors=1, preempt=True)
        lo = [sched.submit("qwen2.5-3b", setups["qwen2.5-3b"][3],
                           priority=1.0) for _ in range(3)]
        time.sleep(0.03)                         # mid first lo pass
        hi = sched.submit("gemma2-9b", setups["gemma2-9b"][3], priority=8.0)
        for r in lo + [hi]:
            r.wait(timeout=300)
        sched.shutdown()
        rt.close()
    done_at = {r.rid: i for i, r in enumerate(sched.completed)}
    # the hi request never drains behind the lo backlog: at most the
    # in-flight lo pass finishes ahead of it
    assert done_at[hi.rid] <= 1
    assert done_at[hi.rid] < done_at[lo[2].rid]


# ------------------------------------------------------- runtime planning
def test_plan_raises_when_no_block_budget():
    """cache + pinned >= budget must fail loudly at plan time."""
    cfg, model, params, batch = _setup("zamba2-7b")
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(512 * 1024, cache_frac=0.9)
        rt.add_model("z", model, params, d)
        # pinned shared block + 90% cache swallow the whole budget
        assert rt.block_budget() <= 0
        with pytest.raises(ValueError, match="no room for blocks"):
            rt.plan(batch=2, seq=32)
        rt.close()


def test_cache_frac_zero_degenerate_path():
    """cache_frac=0.0: a pin-only cache — serving stays lossless, nothing
    unpinned is ever cached, and the block budget is the full budget."""
    cfg, model, params, batch = _setup("qwen2.5-3b")
    ref = np.asarray(jax.jit(model.prefill)(params, batch)[0][:, -1:])
    budget = 12 * 1024 * 1024
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(budget, cache_frac=0.0)
        rt.add_model("q", model, params, d)
        rt.plan(batch=2, seq=32)
        assert rt.cache.capacity == 0
        assert rt.block_budget() == budget      # qwen pins nothing
        out1, _ = rt.forward("q", batch)
        out2, stats = rt.forward("q", batch)
        assert rt.cache.resident_bytes == 0     # nothing admitted
        assert stats["cache_hit_rate"] == 0.0
        rt.close()
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_allclose(np.asarray(out1), ref, rtol=1e-4, atol=1e-4)


def test_replan_budgets_follows_urgency_mix():
    """Same-size models: a skewed urgency mix must tilt the Eq. 1 split
    toward the urgent model (its budget strictly above the uniform share)
    while per-model budgets keep summing to the block budget."""
    archs = ["qwen2.5-3b", "gemma2-9b"]
    setups = {a: _setup(a, seed=i) for i, a in enumerate(archs)}
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(10 * 1024 * 1024, cache_frac=0.25,
                               executors=2)
        for a, (cfg, model, params, _) in setups.items():
            rt.add_model(a, model, params, d)
        rt.plan(batch=2, seq=32)
        budgets = rt.replan_budgets({"qwen2.5-3b": 8.0, "gemma2-9b": 1.0})
        assert budgets["qwen2.5-3b"] > budgets["gemma2-9b"]
        assert sum(budgets.values()) <= rt.block_budget() + 1
        # runtime still serves correctly off the re-selected plans
        out, _ = rt.forward("qwen2.5-3b", setups["qwen2.5-3b"][3])
        rt.close()
    assert np.asarray(out).shape[0] == 2
