import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# dryrun.py-only, per the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeConfig
from repro.models.transformer import input_specs


def make_batch(cfg, shape: ShapeConfig, seed: int = 0):
    """Random batch matching input_specs."""
    import numpy as np
    rng = np.random.default_rng(seed)
    out = {}
    for k, v in input_specs(cfg, shape).items():
        if v.dtype == jnp.int32:
            if k == "pos":
                out[k] = jnp.zeros(v.shape, jnp.int32)
            elif k == "positions":
                out[k] = jnp.zeros(v.shape, jnp.int32)
            else:
                out[k] = jnp.asarray(
                    rng.integers(0, cfg.vocab_size, v.shape), jnp.int32)
        elif v.dtype == jnp.bool_:
            out[k] = jnp.asarray(rng.random(v.shape) < 0.3)
        else:
            out[k] = jnp.asarray(rng.normal(0, 0.5, v.shape), v.dtype)
    return out
