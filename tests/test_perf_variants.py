"""§Perf variants must be bit-compatible with the portable paths:
- shard_map flash-decoding (sequence-sharded cache)
- ring-buffer (windowed) KV cache for SWA architectures
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.distributed.sharding import set_mesh
from repro.launch.mesh import make_smoke_mesh
from repro.models import attention as A
from repro.models import transformer as T
from repro.models.transformer import Model, alloc_cache


def _decode_logits(model, params, tokens, S):
    B = tokens.shape[0]
    cache = alloc_cache(model, ShapeConfig("d", S, B, "decode"))
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        db = {"token": tokens[:, t:t + 1], "pos": jnp.full((B,), t, jnp.int32)}
        logits, cache = step(params, cache, db)
        outs.append(np.asarray(logits[:, 0]))
    return np.stack(outs, 1)


def test_flash_decode_shard_map_matches_plain():
    cfg = dataclasses.replace(ARCHS["qwen2.5-3b"].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (B, S)), jnp.int32)
    ref = _decode_logits(model, params, tokens, S)
    set_mesh(make_smoke_mesh())
    A.SHARDED_DECODE_AXIS = ("model",)
    try:
        got = _decode_logits(model, params, tokens, S)
    finally:
        A.SHARDED_DECODE_AXIS = None
        set_mesh(None)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_windowed_kv_cache_matches_full():
    """SWA decode with a ring buffer of length W == full cache with window
    masking, including far beyond the window."""
    cfg = dataclasses.replace(ARCHS["h2o-danube-3-4b"].reduced(),
                              dtype="float32", sliding_window=8)
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 24                                  # 3 windows deep
    tokens = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (B, S)), jnp.int32)
    ref = _decode_logits(model, params, tokens, S)   # full cache + masking
    T.WINDOWED_KV_CACHE = True
    try:
        struct = model.cache_struct(ShapeConfig("d", S, B, "decode"))
        # cache really is window-sized
        assert struct[0]["k"].shape[2] == 8 or struct[0]["k"].shape[1] == 8 \
            or 8 in struct[0]["k"].shape
        got = _decode_logits(model, params, tokens, S)
    finally:
        T.WINDOWED_KV_CACHE = False
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
