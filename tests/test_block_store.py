"""Tiered block-store subsystem: backend bit-identity / bounded error,
collision-free file naming, quantized I/O + ledger accounting, the Pallas
dequant kernel vs its numpy reference, and size-aware cache admission.

Documented quantization tolerance (see kernels/dequant.py): symmetric
round-to-nearest per-channel int8 reproduces a tensor x within
``|x_hat - x| <= scale_c / 2 = max|x[:, c]| / 254`` elementwise.
"""
import dataclasses
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.core.cost_model import DelayModel
from repro.core.runtime import SwappedModel
from repro.core.swap_engine import (BlockCache, MemoryLedger, SwapEngine,
                                    size_aware_policy)
from repro.kernels.dequant import dequant_int8, quantize_int8
from repro.models.transformer import Model
from repro.store import MmapStore, RawIOStore, build_store, escape_name

from conftest import make_batch


def _units(seed=0, n=3, shape=(64, 128)):
    rng = np.random.default_rng(seed)
    return [(f"u{i:02d}", {"w": rng.standard_normal(shape).astype(np.float32),
                           "g": rng.standard_normal(shape[0]).astype(np.float32)})
            for i in range(n)]


def _setup(arch, seed=0):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    shape = ShapeConfig("p", 32, 2, "prefill")
    batch = make_batch(cfg, shape)
    return cfg, model, params, batch


# ------------------------------------------------------------ path escaping
def test_store_path_collision_free():
    """Regression: the old ``name.replace('/', '_')`` mapped "a/b" and "a_b"
    to the SAME file — the second build clobbered the first unit's bytes."""
    assert escape_name("a/b") != escape_name("a_b")
    rng = np.random.default_rng(0)
    w1 = rng.standard_normal((8, 16)).astype(np.float32)
    w2 = rng.standard_normal((8, 16)).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        store = build_store([("a/b", {"w": w1}), ("a_b", {"w": w2})], d)
        r1 = store.read_unit("a/b")
        r2 = store.read_unit("a_b")
    np.testing.assert_array_equal(np.asarray(r1.params["w"]), w1)
    np.testing.assert_array_equal(np.asarray(r2.params["w"]), w2)


def test_escape_name_injective_on_tricky_names():
    names = ["a/b", "a_b", "a__b", "a_/b", "a/_b", "a_.b", "a//b", "a"]
    escaped = [escape_name(n) for n in names]
    assert len(set(escaped)) == len(names)


# ------------------------------------------------------------ bit identity
@pytest.mark.parametrize("backend", ["mmap", "rawio"])
def test_raw_backends_bit_identical(backend):
    units = _units()
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend=backend)
        for name, params in units:
            r = store.read_unit(name)
            for k in params:
                np.testing.assert_array_equal(np.asarray(r.params[k]),
                                              params[k])
            assert r.io_bytes == store.nbytes(name)
            assert r.ledger_bytes >= store.nbytes(name)


def test_quantized_roundtrip_bounded_error():
    """Per-channel int8 round-trip stays within the documented bound
    |x_hat - x| <= scale_c / 2; small 1-D leaves (norm gains) stay exact."""
    units = _units()
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend="quant")
        for name, params in units:
            r = store.read_unit(name)
            w, w_hat = params["w"], np.asarray(r.params["w"])
            scales = np.max(np.abs(w), axis=0) / 127.0
            assert np.all(np.abs(w_hat - w) <= scales[None, :] / 2 + 1e-7)
            # raw (unquantized) leaf: exact
            np.testing.assert_array_equal(np.asarray(r.params["g"]),
                                          params["g"])


def test_quantized_store_moves_fewer_bytes():
    units = _units(shape=(128, 256))
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend="quant")
        for name, _ in units:
            assert store.stored_nbytes(name) * 3 < store.nbytes(name)
            r = store.read_unit(name)
            assert r.io_bytes == store.stored_nbytes(name)


# ------------------------------------------------------------ dequant kernel
@pytest.mark.parametrize("R,C", [(8, 128), (200, 96), (1, 7)])
@pytest.mark.parametrize("out_dtype", ["float32", "bfloat16"])
def test_dequant_kernel_matches_numpy_ref(R, C, out_dtype):
    """The Pallas kernel (interpret mode) vs a plain numpy dequant."""
    rng = np.random.default_rng(42)
    q = rng.integers(-127, 128, (R, C)).astype(np.int8)
    scales = (rng.random(C).astype(np.float32) + 0.1) / 127.0
    got = np.asarray(dequant_int8(jax.numpy.asarray(q),
                                  jax.numpy.asarray(scales),
                                  jax.numpy.dtype(out_dtype).type,
                                  interpret=True), np.float32)
    want = q.astype(np.float32) * scales[None, :]
    if out_dtype == "bfloat16":
        want = want.astype(jax.numpy.bfloat16).astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_quantize_int8_reference_properties():
    rng = np.random.default_rng(7)
    x = rng.standard_normal((64, 32)).astype(np.float32) * 3.0
    q, scales = quantize_int8(x)
    assert q.dtype == np.int8 and scales.shape == (32,)
    assert np.abs(q).max() <= 127
    x_hat = q.astype(np.float32) * scales[None, :]
    assert np.all(np.abs(x_hat - x) <= scales[None, :] / 2 + 1e-7)
    # zero channel: scale 1.0, exact zero round-trip
    x[:, 3] = 0.0
    q, scales = quantize_int8(x)
    assert scales[3] == 1.0 and np.all(q[:, 3] == 0)


# ------------------------------------------------------- engine accounting
def test_quant_ledger_charges_quantized_resident_bytes():
    """The resident swap unit of the quant backend is the quantized payload:
    the ledger (and therefore the shared budget) is charged stored bytes,
    not the dequantized logical bytes."""
    units = _units(shape=(128, 256))
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend="quant")
        eng = SwapEngine(store)
        h = eng.swap_in([n for n, _ in units])
        expect = sum(store.stored_nbytes(n) for n, _ in units)
        assert h.resident_bytes == expect
        assert eng.ledger.resident == expect
        assert h.nbytes == sum(store.nbytes(n) for n, _ in units)
        eng.swap_out(h)
        assert eng.ledger.resident == 0
        eng.close()


def test_quant_swapin_moves_3x_fewer_bytes_than_mmap():
    """Acceptance: QuantizedStore swap-in moves >= 3x fewer bytes from store
    to host than MmapStore on the same model, per SwapStats."""
    cfg, model, params, batch = _setup("qwen2.5-3b")
    swapped = {}
    for backend in ("mmap", "quant"):
        with tempfile.TemporaryDirectory() as d:
            sm = SwappedModel(model, params, d, store_backend=backend)
            assert sm.store_backend == backend
            sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(),
                         batch=2, seq=32)
            _, stats = sm.forward(batch)
            swapped[backend] = stats["bytes_swapped"]
            assert stats["bytes_logical"] > 0
            assert stats["store_backend"] == backend
            sm.close()
    assert swapped["quant"] * 3 <= swapped["mmap"]


def test_quant_swapped_forward_close_to_reference():
    """End-to-end: swapped inference through int8 units stays close to the
    unswapped fp32 model (bounded per-channel error, cosine fidelity)."""
    cfg, model, params, batch = _setup("qwen2.5-3b")
    ref, _ = jax.jit(model.prefill)(params, batch)
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, store_backend="quant")
        sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(), batch=2, seq=32)
        logits, _ = sm.forward(batch)
        sm.close()
    a = np.asarray(logits, np.float64).ravel()
    b = np.asarray(ref, np.float64).ravel()[-a.size:]
    cos = a @ b / max(np.linalg.norm(a) * np.linalg.norm(b), 1e-30)
    assert cos > 0.98


def test_quant_ineligible_config_falls_back_to_mmap():
    """Per-model eligibility (configs): a quant_eligible=False arch served
    with store_backend='quant' silently uses the exact mmap store."""
    cfg, model, params, batch = _setup("rwkv6-3b")
    assert not cfg.quant_eligible
    ref, _ = jax.jit(model.prefill)(params, batch)
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, store_backend="quant")
        assert sm.store_backend == "mmap"
        assert isinstance(sm.store, MmapStore)
        sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(), batch=2, seq=32)
        logits, _ = sm.forward(batch)
        sm.close()
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_mode_flags_resolve_against_raw_store():
    """Ablation modes reinterpret one set of raw files; quant rejects them."""
    units = _units()
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend="mmap")
        eng = SwapEngine(store, mode="copy_in")
        assert isinstance(eng.store, RawIOStore)
        eng.close()
        eng = SwapEngine(store, mode="dummy_asm")
        assert isinstance(eng.store, MmapStore) and eng.store.assembly == "dummy"
        eng.close()
    with tempfile.TemporaryDirectory() as d:
        qstore = build_store(units, d, backend="quant")
        with pytest.raises(TypeError):
            SwapEngine(qstore, mode="copy_in")


def test_store_backend_rejects_mode_combination():
    units = _units()
    from repro.core.runtime import SwappedSequential
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="requires mode='snet'"):
            SwappedSequential(units, lambda i, p, x: x, d,
                              mode="copy_in", store_backend="quant")


# ------------------------------------------------------- cache admission
def test_size_aware_policy_admits_cofitting_size_classes():
    """ROADMAP item (d): admission from the partition table's per-unit
    sizes. All units of a size class enter together or not at all."""
    sizes = {"embed": 5, "head": 5, "l0": 20, "l1": 20, "l2": 20}
    # capacity 30: both small units (10) fit; adding the 60-byte layer
    # class would not -> threshold 5
    policy = size_aware_policy(sizes, capacity=30)
    assert policy("embed", 5) and policy("head", 5)
    assert not policy("l0", 20)
    # capacity 80: small class (10) + layer class (60) both fit
    policy = size_aware_policy(sizes, capacity=80)
    assert policy("l0", 20) and policy("embed", 5)
    # unknown units fall back to their observed size
    assert policy("new_small", 3)
    assert not policy("new_big", 10**9)
    # zero-size units never admitted
    assert not policy("empty", 0)


def test_cache_policy_constructor_argument():
    ledger = MemoryLedger()
    cache = BlockCache(100, ledger, policy=lambda name, n: name.startswith("hot"))
    assert cache.admits("hot1", 10**9)
    assert not cache.admits("cold", 1)
    cache.pin(["cold_pinned"])
    assert cache.admits("cold_pinned", 1)      # pinned bypasses policy
    # legacy default: admit_frac heuristic still the fallback
    legacy = BlockCache(100, ledger, admit_frac=0.25)
    assert legacy.admits("x", 25) and not legacy.admits("x", 26)
    legacy.set_policy(lambda name, n: True)
    assert legacy.admits("x", 26)


def test_multi_model_plan_installs_size_aware_policy():
    from repro.core.multi_model import MultiModelRuntime
    setups = {a: _setup(a, seed=i)
              for i, a in enumerate(["qwen2.5-3b", "gemma2-9b"])}
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(24 * 1024 * 1024, cache_frac=0.25)
        for a, (cfg, model, params, _) in setups.items():
            rt.add_model(a, model, params, d)
        assert rt.cache.policy is None
        rt.plan(batch=2, seq=32)
        assert rt.cache.policy is not None
        # the small hot units (embed/head) are admitted, full layers not
        sm = rt.models["qwen2.5-3b"]
        embed = "qwen2.5-3b/embed"
        layer = next(n for n in sm.store.order if "layer" in n)
        assert rt.cache.admits(embed, sm.store.stored_nbytes(embed))
        assert not rt.cache.admits(layer, sm.store.stored_nbytes(layer))
        rt.close()


def test_multi_model_mixed_backends_share_budget():
    """One tenant on quant units, one on mmap, one shared budget: both stay
    lossless-or-bounded and the ledger never exceeds the budget."""
    from repro.core.multi_model import MultiModelRuntime
    budget = 24 * 1024 * 1024
    setups = {a: _setup(a, seed=i)
              for i, a in enumerate(["qwen2.5-3b", "gemma2-9b"])}
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(budget, cache_frac=0.25)
        rt.add_model("qwen2.5-3b", setups["qwen2.5-3b"][1],
                     setups["qwen2.5-3b"][2], d, store_backend="quant")
        rt.add_model("gemma2-9b", setups["gemma2-9b"][1],
                     setups["gemma2-9b"][2], d)
        rt.plan(batch=2, seq=32)
        for a in setups:
            rt.forward(a, setups[a][3])
        st = rt.stats()
        rt.close()
    assert st["peak_resident_mb"] * 1e6 <= budget
    assert st["models"]["qwen2.5-3b"]["store_backend"] == "quant"
    assert st["models"]["gemma2-9b"]["store_backend"] == "mmap"
    q = st["models"]["qwen2.5-3b"]
    assert q["bytes_swapped_mb"] * 3 < q["bytes_logical_mb"]
