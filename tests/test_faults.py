"""Fault-tolerance tier, unit level (ISSUE 8).

Covers the taxonomy, the per-backend CRC32 integrity check (every backend
must reject a flipped byte BEFORE assembly — quant's packed-int4 carrier
included), the FaultInjector's determinism and tamper-and-restore
mechanics, the loader's retry/backoff/deadline ladder, and the
ledger/cache zero-leak guarantee on loader exception paths.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.runtime import SwappedSequential
from repro.core.swap_engine import BlockCache, MemoryLedger
from repro.errors import (RequestCancelled, SwapCorruptionError, SwapError,
                          SwapIOError, SwapTimeoutError)
from repro.store import STORE_BACKENDS, FaultInjector, build_store
from repro.store.directio_store import DirectIOStore

from conftest import make_batch           # noqa: F401  (sys.path side effect)


def _units(n=4, rows=16, cols=32, seed=0):
    rng = np.random.default_rng(seed)
    return [(f"u{i}", {"w": rng.normal(0, 1, (rows, cols))
                       .astype(np.float32)})
            for i in range(n)]


def _flip_byte(path, off=None):
    size = os.path.getsize(path)
    off = size // 2 if off is None else off
    with open(path, "rb+") as fh:
        fh.seek(off)
        b = fh.read(1)
        fh.seek(off)
        fh.write(bytes([b[0] ^ 0x10]))      # one nibble
    return off, b


# ----------------------------------------------------------------- taxonomy
def test_error_taxonomy():
    e = SwapIOError("x", unit="u1", attempts=3)
    assert isinstance(e, SwapError) and isinstance(e, IOError)
    assert (e.unit, e.attempts) == ("u1", 3)
    assert isinstance(SwapTimeoutError("t"), TimeoutError)
    assert isinstance(SwapCorruptionError("c"), SwapError)
    # cancellation is a caller decision, NOT a swap fault: it must never
    # count against a model's circuit breaker
    assert not isinstance(RequestCancelled("r"), SwapError)


# ----------------------------------------------------------------- integrity
@pytest.mark.parametrize("backend,opts", [
    ("mmap", {}),
    ("rawio", {}),
    ("quant", {"bits": 8}),
    ("quant", {"bits": 4}),
    ("directio", {}),
])
def test_crc_rejects_flipped_byte(backend, opts):
    """Every backend records per-unit digests at build and, with
    verify=True, rejects a corrupted file before assembly — a flipped
    nibble in a packed-int4 carrier raises SwapCorruptionError instead of
    becoming silently wrong weights."""
    with tempfile.TemporaryDirectory() as d:
        st = build_store(_units(), d, backend=backend, verify=True, **opts)
        assert len(st.digests) == 4
        clean = np.concatenate(
            [np.asarray(l).ravel()
             for l in jax.tree.leaves(st.read_unit("u1").params)])
        path = st._path("u1")
        off, orig = _flip_byte(path)
        with pytest.raises(SwapCorruptionError) as ei:
            st.read_unit("u1")
        assert ei.value.unit == "u1"
        assert st.integrity_failures == 1
        # restore -> reads verify clean again, payload identical
        with open(path, "rb+") as fh:
            fh.seek(off)
            fh.write(orig)
        again = np.concatenate(
            [np.asarray(l).ravel()
             for l in jax.tree.leaves(st.read_unit("u1").params)])
        assert np.array_equal(clean, again)


def test_verify_off_by_default():
    """The integrity pass is opt-in: the perf-gated default path must not
    pay a CRC sweep (or forced mmap page-in) per unit."""
    with tempfile.TemporaryDirectory() as d:
        st = build_store(_units(), d, backend="mmap")
        assert st.verify is False
        assert len(st.digests) == 4     # digests recorded regardless
        _flip_byte(st._path("u0"))
        st.read_unit("u0")              # not checked: no raise


# ----------------------------------------------------------- fault injector
def test_fault_injector_registered():
    assert STORE_BACKENDS["faulty"] is FaultInjector


def test_fault_injector_deterministic_and_restoring():
    with tempfile.TemporaryDirectory() as da, \
            tempfile.TemporaryDirectory() as db:
        seq = {}
        for key, d in (("a", da), ("b", db)):
            st = build_store(_units(), d, backend="faulty",
                             inner="mmap", p=0.5, seed=99, latency_s=0.001)
            out = []
            for _ in range(24):
                try:
                    st.read_unit("u0")
                    out.append("ok")
                except SwapError as e:
                    out.append(type(e).__name__)
            seq[key] = out
        # same seed, same call sequence -> identical fault schedule
        assert seq["a"] == seq["b"]
        assert any(s != "ok" for s in seq["a"])


def test_fault_injector_forced_script_and_counters():
    with tempfile.TemporaryDirectory() as d:
        st = build_store(_units(), d, backend="faulty", inner="mmap", p=0.0,
                         seed=0)
        before = open(st.inner._path("u2"), "rb").read()
        st.force("io", "torn", "corrupt", None)
        with pytest.raises(SwapIOError):
            st.read_unit("u2")
        with pytest.raises(SwapIOError):        # torn normalizes to IO
            st.read_unit("u2")
        with pytest.raises(SwapCorruptionError):
            st.read_unit("u2")
        st.read_unit("u2")                      # forced-clean read
        # tamper-and-restore: the on-disk bytes are byte-identical after
        assert open(st.inner._path("u2"), "rb").read() == before
        assert st.injected == {"io": 1, "latency": 0, "torn": 1, "corrupt": 1}
        assert st.reads == 4
        assert st.total_injected == 3


def test_fault_injector_wraps_every_backend():
    for inner, opts in (("mmap", {}), ("rawio", {}), ("quant", {"bits": 4}),
                        ("directio", {})):
        with tempfile.TemporaryDirectory() as d:
            st = build_store(_units(), d, backend="faulty", inner=inner,
                             inner_opts=opts, p=0.0, seed=0)
            st.force("corrupt")
            with pytest.raises(SwapCorruptionError):
                st.read_unit("u1")
            st.read_unit("u1")      # restored
            # size accounting delegates to the wrapped backend
            assert st.stored_nbytes("u1") == st.inner.stored_nbytes("u1")
            assert st.resident_nbytes("u1") == st.inner.resident_nbytes("u1")


def test_fault_injector_refuses_self_wrap():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError):
            build_store(_units(), d, backend="faulty", inner="faulty")


# ----------------------------------------------------------- directio probe
def test_directio_probe_falls_back_on_open_failure(monkeypatch):
    """A filesystem that rejects O_DIRECT at open() must demote the store
    to buffered reads, not break it."""
    with tempfile.TemporaryDirectory() as d:
        st = DirectIOStore.build(_units(), d, queue_depth=2)
        real_open = os.open

        def deny_direct(path, flags, *a, **kw):
            if getattr(os, "O_DIRECT", 0) and (flags & os.O_DIRECT):
                raise OSError(22, "O_DIRECT not supported here")
            return real_open(path, flags, *a, **kw)

        monkeypatch.setattr(os, "open", deny_direct)
        st.direct_io = None             # force a re-probe through the patch
        st.open()
        assert st.direct_io is False
        r = st.read_unit("u3")          # buffered path serves reads fine
        got = np.asarray(r.params["w"])
        assert np.array_equal(got, _units()[3][1]["w"])


def test_directio_probe_falls_back_on_read_failure(monkeypatch):
    """Some filesystems accept the O_DIRECT open but fail the first read —
    the probe must catch that too."""
    with tempfile.TemporaryDirectory() as d:
        st = DirectIOStore.build(_units(), d)
        real_preadv = os.preadv
        denied = {"n": 0}

        def deny_read(fd, bufs, off):
            if denied["n"] == 0:
                denied["n"] += 1
                raise OSError(22, "Invalid argument")
            return real_preadv(fd, bufs, off)

        if not st.direct_io:
            pytest.skip("filesystem already rejects O_DIRECT at open")
        monkeypatch.setattr(os, "preadv", deny_read)
        st.direct_io = None
        st.open()
        assert st.direct_io is False


# ----------------------------------------------------------------- retries
def _seq_runtime(d, **store_options):
    units = [(f"u{i}", {"w": np.eye(8, dtype=np.float32) * (i + 1)})
             for i in range(6)]

    def apply_fn(i, p, x):
        return x @ p["w"]

    s = SwappedSequential(units, apply_fn, d, store_backend="faulty",
                          store_options=dict(store_options))
    s.set_plan((2, 4))
    return s


def test_retry_absorbs_transient_faults():
    with tempfile.TemporaryDirectory() as d:
        s = _seq_runtime(d, p=0.0, seed=0)
        s.store.force("io", None, "corrupt")    # fail 1st read twice over
        eng = s.engine
        eng.retry_backoff_s = 0.001
        x0 = jnp.ones((2, 8), jnp.float32)
        y, st = s.forward(x0)
        assert st["faults"] == {"SwapIOError": 1, "SwapCorruptionError": 1}
        assert st["retries"] == 2
        # each retry logged a backoff span on the timeline
        assert len(eng.stats.stage_spans("retry")) == 2
        assert np.array_equal(np.asarray(y), np.asarray(x0) @ np.diag(
            [1.0 * 2 * 3 * 4 * 5 * 6] * 8).astype(np.float32))
        s.close()


def test_retry_budget_exhaustion_raises_with_attempts():
    with tempfile.TemporaryDirectory() as d:
        s = _seq_runtime(d, p=0.0, seed=0)
        eng = s.engine
        eng.read_retries = 2
        eng.retry_backoff_s = 0.001
        s.store.force("io", "io", "io")         # one more than the budget
        with pytest.raises(SwapIOError) as ei:
            s.forward(jnp.ones((2, 8), jnp.float32))
        assert ei.value.attempts == 3           # 1 try + 2 retries
        assert ei.value.unit == "u0"
        assert eng.stats.faults["SwapIOError"] == 3
        s.close()


def test_read_deadline_counts_as_timeout():
    with tempfile.TemporaryDirectory() as d:
        s = _seq_runtime(d, p=0.0, seed=0, latency_s=0.2)
        eng = s.engine
        eng.read_deadline_s = 0.05
        eng.read_retries = 1
        eng.retry_backoff_s = 0.001
        s.store.force("latency", "latency")     # both attempts blow deadline
        with pytest.raises(SwapTimeoutError) as ei:
            s.forward(jnp.ones((2, 8), jnp.float32))
        assert ei.value.attempts == 2
        assert eng.stats.faults["SwapTimeoutError"] == 2
        s.close()


# ------------------------------------------------------------- zero leaks
def test_midblock_failure_leaves_ledger_at_prepass_total():
    """The satellite regression: a pass that dies mid-block must return the
    MemoryLedger exactly to its pre-pass total and leak no cache leases
    (a leaked lease would pin the entry unevictable forever)."""
    units = [(f"u{i}", {"w": np.eye(8, dtype=np.float32) * (i + 1)})
             for i in range(6)]

    def apply_fn(i, p, x):
        return x @ p["w"]

    with tempfile.TemporaryDirectory() as d:
        ledger = MemoryLedger(None)
        cache = BlockCache(1 << 20, ledger,
                           policy=lambda name, nb: name in ("u0", "u1"))
        s = SwappedSequential(units, apply_fn, d, store_backend="faulty",
                              store_options=dict(p=0.0, seed=0),
                              ledger=ledger, cache=cache)
        s.set_plan((2, 4))
        eng = s.engine
        eng.retry_backoff_s = 0.001
        x0 = jnp.ones((2, 8), jnp.float32)
        s.forward(x0)                   # warm pass caches u0+u1
        pre = ledger.resident
        assert pre > 0                  # the cached block stays charged
        assert cache.active_leases() == {}
        # pass 2: u0/u1 are cache hits (leases taken), so the first REAL
        # read is u2 — it fails unrecoverably mid-pipeline while other
        # blocks are in flight
        s.store.force("io", "io", "io")
        with pytest.raises(SwapIOError):
            s.forward(x0)
        assert ledger.resident == pre
        assert cache.active_leases() == {}
        # the pipeline is not poisoned: the next pass serves cleanly and
        # returns the exact result
        y, _ = s.forward(x0)
        assert np.allclose(np.asarray(y), 720.0)
        assert ledger.resident == pre
        assert cache.active_leases() == {}
        s.close()
