"""Roofline/report helpers + CI reporting surface: model FLOPs, analytic
flops, CSV rendering, the perf-regression gate sections, and the
tools/ci_summary.py job-summary renderers (unit-tested here against the
COMMITTED results/*.json fixtures, so a bench schema shift fails a test
instead of silently blanking the job summary)."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _fixture(name):
    with open(os.path.join(RESULTS, name)) as fh:
        return json.load(fh)

from repro.configs import ARCHS, SHAPES
from repro.configs.flops import analytic_flops_per_device


def test_analytic_flops_scaling():
    """Train = 4x fwd; decode tokens = batch; SWA bounds the score term."""
    cfg = ARCHS["qwen2.5-3b"]
    tr = analytic_flops_per_device(cfg, SHAPES["train_4k"], 256)
    pf = analytic_flops_per_device(cfg, SHAPES["prefill_32k"], 256)
    dc = analytic_flops_per_device(cfg, SHAPES["decode_32k"], 256)
    assert tr > pf > dc > 0
    # danube's SWA caps its prefill attention term vs an unwindowed twin
    import dataclasses
    dan = ARCHS["h2o-danube-3-4b"]
    full = dataclasses.replace(dan, sliding_window=None, layer_pattern="global")
    assert analytic_flops_per_device(dan, SHAPES["prefill_32k"], 256) < \
        analytic_flops_per_device(full, SHAPES["prefill_32k"], 256)


def test_model_flops_moe_uses_active():
    from benchmarks.bench_roofline import model_flops
    dense_like = model_flops("granite-20b", "prefill_32k")
    moe = model_flops("llama4-scout-17b-a16e", "prefill_32k")
    # scout: 107B total but 17B active -> model flops reflect ACTIVE params
    assert moe < 2.1 * 17.5e9 * 32 * 32768 * 1.05
    assert dense_like > 0


@pytest.mark.skipif(
    not os.path.isdir(os.path.join(os.path.dirname(__file__), "..",
                                   "results", "dryrun")),
    reason="no dry-run artifacts")
def test_report_renders():
    from benchmarks.report import dryrun_table, roofline_table, skip_table
    t = dryrun_table("16x16")
    assert t.count("| ok") == 34
    assert "granite-20b" in t
    s = skip_table()
    assert s.count("encoder-only") == 2
    r = roofline_table()
    assert "**" in r            # dominant terms highlighted


# ------------------------------------------------------ perf-regression gate
def _baseline_matrix():
    import json
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_baseline.json")
    with open(path) as fh:
        return json.load(fh)


def test_regression_gate_clean_on_identity():
    from benchmarks.check_regression import compare
    base = _baseline_matrix()
    assert compare(base, base) == []


def test_regression_gate_fails_synthetic_2x_latency():
    """The CI acceptance case: a doctored 2x swap-in latency on every arm
    must trip the gate (it exceeds the +-20% tolerance by construction)."""
    import copy
    from benchmarks.check_regression import compare
    base = _baseline_matrix()
    doctored = copy.deepcopy(base)
    for rows in doctored["backends"].values():
        for m in ("m1", "m2", "m3"):
            rows[m]["swap_in_ms"] *= 2.0
    violations = compare(base, doctored, latency_tol=0.2)
    assert len(violations) >= 12            # every backend x m arm trips
    # but a run 2x FASTER is not a regression
    assert compare(doctored, base, latency_tol=0.2) == []


def test_regression_gate_fails_byte_drift_and_missing_arm():
    import copy
    from benchmarks.check_regression import compare
    base = _baseline_matrix()
    drift = copy.deepcopy(base)
    drift["backends"]["quant"]["m2"]["bytes_swapped"] += 1
    assert any("bytes must match exactly" in v for v in compare(base, drift))
    shrunk = copy.deepcopy(base)
    del shrunk["backends"]["fused"]
    assert any("missing" in v for v in compare(base, shrunk))


def test_regression_gate_mixed_section():
    """The mixed-precision separation is absolute and deterministic: any
    doctored flip of its four invariants must trip the gate."""
    import copy
    from benchmarks.check_regression import compare_mixed
    mp = _fixture("BENCH_swap_store.json").get("mixed_precision")
    if mp is None:
        pytest.skip("fixture predates the mixed_precision section")
    assert compare_mixed(mp, mp) == []
    assert compare_mixed(None, mp) == []          # pre-section baseline era
    assert any("missing" in v for v in compare_mixed(mp, None))
    broken = copy.deepcopy(mp)
    broken["mixed"]["meets_target"] = False
    assert any("fidelity target" in v for v in compare_mixed(mp, broken))
    broken = copy.deepcopy(mp)
    broken["int4"]["meets_target"] = True
    assert any("separation" in v for v in compare_mixed(mp, broken))
    broken = copy.deepcopy(mp)
    broken["mixed"]["layers_per_block"] = broken["int8"]["layers_per_block"]
    assert any("packing" in v for v in compare_mixed(mp, broken))
    broken = copy.deepcopy(mp)
    broken["mixed"]["bytes_swapped"] = broken["int8"]["bytes_swapped"] + 1
    assert any("strictly between" in v for v in compare_mixed(mp, broken))


def test_regression_gate_multi_tenant_section():
    import copy
    from benchmarks.check_regression import compare_multi_tenant
    mt = _fixture("BENCH_multi_tenant.json")
    assert compare_multi_tenant(mt, mt) == []
    assert compare_multi_tenant(None, mt) == []
    assert any("missing" in v for v in compare_multi_tenant(mt, None))
    slow = copy.deepcopy(mt)                      # hi-class tail blowout
    slow["arms"]["scheduled"]["classes"]["hi"]["p99_ms"] *= 3.0
    assert any("p99_ms" in v
               for v in compare_multi_tenant(mt, slow, latency_tol=0.2))
    flat = copy.deepcopy(mt)                      # scheduler stopped helping
    flat["hi_p99_speedup"] = 1.0
    assert any("floor" in v for v in compare_multi_tenant(mt, flat))
    over = copy.deepcopy(mt)
    over["arms"]["scheduled"]["budget_ok"] = False
    assert any("budget" in v for v in compare_multi_tenant(mt, over))
    leak = copy.deepcopy(mt)
    leak["decode_heavy"]["kv_pool_clean"] = False
    assert any("kv_pool_clean" in v for v in compare_multi_tenant(mt, leak))


def test_regression_gate_fleet_section():
    import copy
    from benchmarks.check_regression import compare_fleet
    fl = _fixture("BENCH_fleet.json")
    assert compare_fleet(fl, fl) == []
    assert compare_fleet(None, fl) == []
    assert any("missing" in v for v in compare_fleet(fl, None))
    cold = copy.deepcopy(fl)
    cold["arrival"]["cold_over_warm"] = 50.0
    assert any("cold_over_warm" in v for v in compare_fleet(fl, cold))
    for key in ("ledger_clean", "budget_ok", "clean_shutdown"):
        broken = copy.deepcopy(fl)
        broken[key] = False
        assert any(key in v for v in compare_fleet(fl, broken))


def test_regression_gate_decode_section():
    """The continuous-batching point: deterministic counts exact, throughput
    may only rise or dip within tolerance, b8/b1 speedup has an absolute
    floor, and the whole section may not silently vanish."""
    import copy
    from benchmarks.check_regression import compare_decode
    base = _baseline_matrix()["decode"]
    assert compare_decode(base, base) == []
    slow = copy.deepcopy(base)
    slow["arms"]["b8"]["tok_per_s"] *= 0.5
    assert any("tok/s" in v for v in compare_decode(base, slow))
    fast = copy.deepcopy(base)           # faster is never a regression
    fast["arms"]["b8"]["tok_per_s"] *= 2.0
    assert compare_decode(base, fast) == []
    drift = copy.deepcopy(base)
    drift["arms"]["b1"]["tokens_emitted"] += 1
    assert any("deterministic" in v for v in compare_decode(base, drift))
    flat = copy.deepcopy(base)
    flat["speedup_b8_over_b1"] = 1.4
    assert any("floor" in v for v in compare_decode(base, flat))
    assert any("missing" in v for v in compare_decode(base, None))


# ------------------------------------------------------- CI job summary tool
def test_ci_summary_junit_counts_and_verdict(tmp_path):
    import ci_summary
    xml = tmp_path / "report.xml"
    xml.write_text(
        '<testsuites><testsuite tests="10" failures="1" errors="0" '
        'skipped="2"/></testsuites>')
    counts = ci_summary.junit_counts(str(xml))
    assert counts == {"passed": 7, "failed": 1, "errors": 0, "skipped": 2}
    lines, ok = ci_summary.render_junit(counts, baseline=7)
    assert not ok and "REGRESSION" in lines[0]    # failures always trip
    clean = {"passed": 7, "failed": 0, "errors": 0, "skipped": 0}
    assert ci_summary.render_junit(clean, baseline=7)[1]
    assert not ci_summary.render_junit(clean, baseline=8)[1]
    assert ci_summary.junit_counts(str(tmp_path / "absent.xml")) == \
        {"passed": 0, "failed": 0, "errors": 0, "skipped": 0}


def test_ci_summary_renders_committed_fixtures():
    """Every renderer must digest its COMMITTED fixture — the schema the
    bench actually writes — and surface its headline quantities."""
    import ci_summary
    swap = _fixture("BENCH_swap_store.json")
    out = "\n".join(ci_summary.render_swap_store(swap, chaos_seed="42"))
    assert "swap-store fused m2" in out and "swap-store mmap m2" in out
    assert "chaos faulty" in out and "randomized pytest seed 42" in out
    if "mixed_precision" in swap:
        assert "mixed-precision plan @ fidelity" in out
        assert "meets target" in out
    out = "\n".join(ci_summary.render_decode(_fixture("BENCH_decode.json")))
    assert "decode b1" in out and "decode b8" in out and "speedup" in out
    out = "\n".join(ci_summary.render_multi_tenant(
        _fixture("BENCH_multi_tenant.json")))
    assert "multi-tenant scheduled" in out and "hi-class p99 speedup" in out
    assert "decode-heavy mix" in out and "http arm parity" in out
    out = "\n".join(ci_summary.render_fleet(_fixture("BENCH_fleet.json")))
    assert "fleet over HTTP" in out and "ledger clean" in out


def test_ci_summary_mixed_precision_renderer():
    import ci_summary
    assert ci_summary.render_mixed_precision(None) == []
    mp = {"fidelity_target": 0.035,
          "plan": {"histogram": {"fp": 0, "int8": 6, "int4": 6},
                   "predicted_err": 0.0195, "stored_mb": 14.9},
          "int8": {"layers_per_block": 2.4, "bytes_swapped": 19783680,
                   "rel_err": 0.0302, "meets_target": True},
          "int4": {"layers_per_block": 4.0, "bytes_swapped": 9953280,
                   "rel_err": 0.3601, "meets_target": False},
          "mixed": {"layers_per_block": 4.0, "bytes_swapped": 14868480,
                    "rel_err": 0.0195, "meets_target": True}}
    out = "\n".join(ci_summary.render_mixed_precision(mp))
    assert "fp=0 int8=6 int4=6" in out
    assert "int4: 4.00 layers/block" in out
    assert "(meets target: False)" in out


def test_ci_summary_end_to_end(tmp_path):
    """render_summary over the committed results dir: one markdown doc,
    exit verdict from the junit side only."""
    import ci_summary
    text, ok = ci_summary.render_summary(
        results_dir=RESULTS, report_xml=str(tmp_path / "absent.xml"),
        baseline=0)
    assert ok and text.startswith("### tier-1:")
    for marker in ("swap-store", "decode", "multi-tenant", "fleet"):
        assert marker in text, f"missing section {marker}"
    _, bad = ci_summary.render_summary(
        results_dir=RESULTS, report_xml=str(tmp_path / "absent.xml"),
        baseline=1)
    assert not bad
