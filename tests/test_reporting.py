"""Roofline/report helpers: model FLOPs, analytic flops, CSV rendering."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs import ARCHS, SHAPES
from repro.configs.flops import analytic_flops_per_device


def test_analytic_flops_scaling():
    """Train = 4x fwd; decode tokens = batch; SWA bounds the score term."""
    cfg = ARCHS["qwen2.5-3b"]
    tr = analytic_flops_per_device(cfg, SHAPES["train_4k"], 256)
    pf = analytic_flops_per_device(cfg, SHAPES["prefill_32k"], 256)
    dc = analytic_flops_per_device(cfg, SHAPES["decode_32k"], 256)
    assert tr > pf > dc > 0
    # danube's SWA caps its prefill attention term vs an unwindowed twin
    import dataclasses
    dan = ARCHS["h2o-danube-3-4b"]
    full = dataclasses.replace(dan, sliding_window=None, layer_pattern="global")
    assert analytic_flops_per_device(dan, SHAPES["prefill_32k"], 256) < \
        analytic_flops_per_device(full, SHAPES["prefill_32k"], 256)


def test_model_flops_moe_uses_active():
    from benchmarks.bench_roofline import model_flops
    dense_like = model_flops("granite-20b", "prefill_32k")
    moe = model_flops("llama4-scout-17b-a16e", "prefill_32k")
    # scout: 107B total but 17B active -> model flops reflect ACTIVE params
    assert moe < 2.1 * 17.5e9 * 32 * 32768 * 1.05
    assert dense_like > 0


@pytest.mark.skipif(
    not os.path.isdir(os.path.join(os.path.dirname(__file__), "..",
                                   "results", "dryrun")),
    reason="no dry-run artifacts")
def test_report_renders():
    from benchmarks.report import dryrun_table, roofline_table, skip_table
    t = dryrun_table("16x16")
    assert t.count("| ok") == 34
    assert "granite-20b" in t
    s = skip_table()
    assert s.count("encoder-only") == 2
    r = roofline_table()
    assert "**" in r            # dominant terms highlighted


# ------------------------------------------------------ perf-regression gate
def _baseline_matrix():
    import json
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_baseline.json")
    with open(path) as fh:
        return json.load(fh)


def test_regression_gate_clean_on_identity():
    from benchmarks.check_regression import compare
    base = _baseline_matrix()
    assert compare(base, base) == []


def test_regression_gate_fails_synthetic_2x_latency():
    """The CI acceptance case: a doctored 2x swap-in latency on every arm
    must trip the gate (it exceeds the +-20% tolerance by construction)."""
    import copy
    from benchmarks.check_regression import compare
    base = _baseline_matrix()
    doctored = copy.deepcopy(base)
    for rows in doctored["backends"].values():
        for m in ("m1", "m2", "m3"):
            rows[m]["swap_in_ms"] *= 2.0
    violations = compare(base, doctored, latency_tol=0.2)
    assert len(violations) >= 12            # every backend x m arm trips
    # but a run 2x FASTER is not a regression
    assert compare(doctored, base, latency_tol=0.2) == []


def test_regression_gate_fails_byte_drift_and_missing_arm():
    import copy
    from benchmarks.check_regression import compare
    base = _baseline_matrix()
    drift = copy.deepcopy(base)
    drift["backends"]["quant"]["m2"]["bytes_swapped"] += 1
    assert any("bytes must match exactly" in v for v in compare(base, drift))
    shrunk = copy.deepcopy(base)
    del shrunk["backends"]["fused"]
    assert any("missing" in v for v in compare(base, shrunk))


def test_regression_gate_decode_section():
    """The continuous-batching point: deterministic counts exact, throughput
    may only rise or dip within tolerance, b8/b1 speedup has an absolute
    floor, and the whole section may not silently vanish."""
    import copy
    from benchmarks.check_regression import compare_decode
    base = _baseline_matrix()["decode"]
    assert compare_decode(base, base) == []
    slow = copy.deepcopy(base)
    slow["arms"]["b8"]["tok_per_s"] *= 0.5
    assert any("tok/s" in v for v in compare_decode(base, slow))
    fast = copy.deepcopy(base)           # faster is never a regression
    fast["arms"]["b8"]["tok_per_s"] *= 2.0
    assert compare_decode(base, fast) == []
    drift = copy.deepcopy(base)
    drift["arms"]["b1"]["tokens_emitted"] += 1
    assert any("deterministic" in v for v in compare_decode(base, drift))
    flat = copy.deepcopy(base)
    flat["speedup_b8_over_b1"] = 1.4
    assert any("floor" in v for v in compare_decode(base, flat))
    assert any("missing" in v for v in compare_decode(base, None))
