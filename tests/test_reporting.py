"""Roofline/report helpers: model FLOPs, analytic flops, CSV rendering."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs import ARCHS, SHAPES
from repro.configs.flops import analytic_flops_per_device


def test_analytic_flops_scaling():
    """Train = 4x fwd; decode tokens = batch; SWA bounds the score term."""
    cfg = ARCHS["qwen2.5-3b"]
    tr = analytic_flops_per_device(cfg, SHAPES["train_4k"], 256)
    pf = analytic_flops_per_device(cfg, SHAPES["prefill_32k"], 256)
    dc = analytic_flops_per_device(cfg, SHAPES["decode_32k"], 256)
    assert tr > pf > dc > 0
    # danube's SWA caps its prefill attention term vs an unwindowed twin
    import dataclasses
    dan = ARCHS["h2o-danube-3-4b"]
    full = dataclasses.replace(dan, sliding_window=None, layer_pattern="global")
    assert analytic_flops_per_device(dan, SHAPES["prefill_32k"], 256) < \
        analytic_flops_per_device(full, SHAPES["prefill_32k"], 256)


def test_model_flops_moe_uses_active():
    from benchmarks.bench_roofline import model_flops
    dense_like = model_flops("granite-20b", "prefill_32k")
    moe = model_flops("llama4-scout-17b-a16e", "prefill_32k")
    # scout: 107B total but 17B active -> model flops reflect ACTIVE params
    assert moe < 2.1 * 17.5e9 * 32 * 32768 * 1.05
    assert dense_like > 0


@pytest.mark.skipif(
    not os.path.isdir(os.path.join(os.path.dirname(__file__), "..",
                                   "results", "dryrun")),
    reason="no dry-run artifacts")
def test_report_renders():
    from benchmarks.report import dryrun_table, roofline_table, skip_table
    t = dryrun_table("16x16")
    assert t.count("| ok") == 34
    assert "granite-20b" in t
    s = skip_table()
    assert s.count("encoder-only") == 2
    r = roofline_table()
    assert "**" in r            # dominant terms highlighted
