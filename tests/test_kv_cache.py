"""Contiguous-cache management (serving/kv_cache.py): pad_prefill_cache
across model families, gather_cache_rows, and the engine's per-request
retirement (no decoding padding for finished requests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import gather_cache_rows, pad_prefill_cache


def _model(arch, dtype="float32"):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype=dtype)
    model = Model(cfg)
    return cfg, model, model.init(jax.random.key(0))


@pytest.mark.parametrize("arch", ["qwen2.5-3b",       # plain GQA KV
                                  "rwkv6-3b",         # pure state (no seq dim)
                                  "zamba2-7b",        # mamba2 + shared attn
                                  "deepseek-v2-lite-16b"])  # MLA latents
def test_pad_prefill_cache_families(arch):
    cfg, model, params = _model(arch)
    B, S, MAX = 2, 10, 32
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    _, pc = jax.jit(model.prefill)(params, batch)
    padded = pad_prefill_cache(model, pc, MAX, B)

    target = model.cache_struct(ShapeConfig("serve", seq_len=MAX,
                                            global_batch=B, mode="decode"))
    t_leaves = jax.tree.leaves(target)
    p_leaves = jax.tree.leaves(padded)
    pc_leaves = jax.tree.leaves(pc)
    assert len(p_leaves) == len(t_leaves) == len(pc_leaves)
    for got, tgt, src in zip(p_leaves, t_leaves, pc_leaves):
        # every leaf lands exactly on the decode struct (shape AND dtype)
        assert got.shape == tgt.shape and got.dtype == tgt.dtype
        # the prefill content survives as a prefix; the padding is zero
        sl = tuple(slice(0, s) for s in src.shape)
        np.testing.assert_array_equal(np.asarray(got[sl], np.float32),
                                      np.asarray(src, np.float32))
        total = float(jnp.sum(jnp.abs(got.astype(jnp.float32))))
        prefix = float(jnp.sum(jnp.abs(src.astype(jnp.float32))))
        assert total == pytest.approx(prefix, rel=1e-6)
    # state leaves (SSM h/conv, rwkv S/shifts) are carried UNPADDED
    if arch in ("rwkv6-3b", "zamba2-7b"):
        assert any(g.shape == s.shape
                   for g, s in zip(p_leaves, pc_leaves))


def test_pad_prefill_cache_mrope_positions():
    """The VLM (mrope) family pads its KV cache identically — positions are
    an input, not cache state, so [B,L,3] prefill positions must not leak
    into the padded cache shapes."""
    cfg, model, params = _model("qwen2-vl-72b")
    B, S, MAX = 2, 8, 24
    rng = np.random.default_rng(1)
    pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3)).copy()
    batch = {"tokens": jnp.asarray(
                 rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
             "positions": jnp.asarray(pos, jnp.int32)}
    _, pc = jax.jit(model.prefill)(params, batch)
    padded = pad_prefill_cache(model, pc, MAX, B)
    target = model.cache_struct(ShapeConfig("serve", seq_len=MAX,
                                            global_batch=B, mode="decode"))
    for got, tgt in zip(jax.tree.leaves(padded), jax.tree.leaves(target)):
        assert got.shape == tgt.shape and got.dtype == tgt.dtype


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "zamba2-7b"])
def test_gather_cache_rows_selects_batch_rows(arch):
    """Fill every leaf so each batch row (along WHATEVER axis batch lives
    on) holds its own index, gather rows [3, 1], and check both selection
    and order per leaf."""
    cfg, model, params = _model(arch)
    B, MAX, rows = 4, 16, [3, 1]
    old_struct = model.cache_struct(ShapeConfig("serve", seq_len=MAX,
                                                global_batch=B,
                                                mode="decode"))
    new_struct = model.cache_struct(ShapeConfig("serve", seq_len=MAX,
                                                global_batch=len(rows),
                                                mode="decode"))
    axes, filled = [], []
    for leaf, nleaf in zip(jax.tree.leaves(old_struct),
                           jax.tree.leaves(new_struct)):
        diffs = [i for i, (a, b) in enumerate(zip(leaf.shape, nleaf.shape))
                 if a != b]
        assert len(diffs) == 1, (leaf.shape, nleaf.shape)
        axes.append(diffs[0])
        ids = jnp.arange(B).reshape(
            [B if i == diffs[0] else 1 for i in range(leaf.ndim)])
        filled.append(jnp.broadcast_to(ids, leaf.shape).astype(leaf.dtype))
    cache = jax.tree.unflatten(jax.tree.structure(old_struct), filled)

    out = gather_cache_rows(model, cache, rows, MAX, B)
    for leaf, nleaf, axis in zip(jax.tree.leaves(out),
                                 jax.tree.leaves(new_struct), axes):
        assert leaf.shape == nleaf.shape
        arr = np.asarray(leaf, np.float32)
        for slot, src_row in enumerate(rows):
            got = np.take(arr, slot, axis=axis)
            assert (got == src_row).all(), \
                f"axis {axis} slot {slot}: expected row {src_row}"


def test_ragged_generate_matches_solo():
    """Requests with different max_new_tokens / EOS each retire at their own
    length and produce exactly their solo-run outputs."""
    cfg, model, params = _model("qwen2.5-3b")
    engine = ServingEngine(model, params, max_len=64)
    rng = np.random.default_rng(2)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 10)))
               for _ in range(4)]
    max_new = [2, 7, 4, 5]

    solo = []
    for p, n in zip(prompts, max_new):
        r = Request(0, list(p), max_new_tokens=n)
        engine.generate([r])
        solo.append(list(r.output))

    reqs = [Request(i, list(p), max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts, max_new))]
    stats = engine.generate(reqs)
    assert [list(r.output) for r in reqs] == solo
    assert all(len(r.output) == n for r, n in zip(reqs, max_new))
    # the batch shrank: decode work is bounded by each request's OWN length,
    # so total decoded tokens is sum(max_new) - B, not B * max(max_new)
    assert stats["decode_steps"] == max(max_new) - 1
    decoded = stats["tok_per_s"] * max(stats["total_s"] - stats["prefill_s"],
                                       1e-9)
    assert round(decoded) == sum(max_new) - len(reqs)
