"""Swapped inference == direct inference (lossless), across engine modes,
plus budget enforcement and multi-DNN scheduling."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.core.cost_model import DelayModel
from repro.core.runtime import SwappedModel, split_units, unit_infos
from repro.core.scheduler import MultiDNNScheduler, ScheduledModel
from repro.core.partition import PartitionPlanner
from repro.models.transformer import Model

from conftest import make_batch

ARCH_SAMPLE = ["qwen2.5-3b", "zamba2-7b", "deepseek-v2-lite-16b", "gemma2-9b"]


def _setup(arch, seed=0):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    shape = ShapeConfig("p", 32, 2, "prefill")
    batch = make_batch(cfg, shape)
    ref, _ = jax.jit(model.prefill)(params, batch)
    return cfg, model, params, batch, ref


@pytest.mark.parametrize("arch", ARCH_SAMPLE)
@pytest.mark.parametrize("mode", ["snet", "copy_in", "dummy_asm"])
def test_swapped_equals_direct(arch, mode):
    cfg, model, params, batch, ref = _setup(arch)
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode=mode)
        sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(), batch=2, seq=32)
        assert sm.plan.n_blocks >= 2
        logits, stats = sm.forward(batch)
        sm.close()
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    assert stats["peak_resident_mb"] > 0


def test_mode_memory_ordering():
    """Ledger: snet < dummy_asm <= copy_in peak memory (ablation Fig. 15).

    Measured SERIALLY (prefetch_depth=1) so the peak is deterministic: the
    mode multiplier times the largest resident block. At m>=2 the observed
    peak races — the ledger charge for block i+1 lands when the loader
    finishes, and a slow loader (copy_in's staging + dispatch copies, on a
    loaded CI box) can charge only after a fast executor already dropped
    block i, deflating the mode that should peak highest."""
    peaks = {}
    for mode, gpu in (("snet", True), ("dummy_asm", True), ("copy_in", True)):
        cfg, model, params, batch, _ = _setup("qwen2.5-3b")
        with tempfile.TemporaryDirectory() as d:
            sm = SwappedModel(model, params, d, mode=mode, gpu_dispatch=gpu,
                              prefetch_depth=1)
            sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(), batch=2, seq=32)
            sm.forward(batch)
            peaks[mode] = sm.engine.stats.peak_resident
            sm.close()
    assert peaks["snet"] < peaks["dummy_asm"] <= peaks["copy_in"]


def test_budget_enforced():
    cfg, model, params, batch, _ = _setup("qwen2.5-3b")
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet", budget=1024)  # 1 KB
        sm.set_plan((len(sm.units) // 2,))
        with pytest.raises(MemoryError):
            sm.forward(batch)
        sm.close()


def test_shared_block_pinned_once():
    """zamba2's shared attention block is stored once and pinned."""
    cfg, model, params, batch, ref = _setup("zamba2-7b")
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet")
        names = [u.name for u in sm.units]
        assert names.count("shared_attn") >= 2          # referenced repeatedly
        assert len(sm.store.skeletons) < len(names)     # stored once
        sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(), batch=2, seq=32)
        logits, _ = sm.forward(batch)
        sm.close()
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_m1_degraded_plan_respected_at_runtime():
    """A budget between the largest layer and the largest adjacent pair
    forces an m=1 plan; the executor must then run serially and stay within
    budget (regression: it used to double-buffer m=1 plans)."""
    import jax.numpy as jnp
    import numpy as np_
    from repro.core.runtime import SwappedSequential
    from repro.models import vision

    name, layers, hw = vision.vgg_sim()
    params = vision.init_convnet(layers, jax.random.key(0))
    sizes = [sum(np_.asarray(x).nbytes for x in jax.tree.leaves(p))
             for p in params]
    largest = max(sizes)
    # pick a budget that fits the largest layer but not largest+neighbor
    budget = int(largest * 1.3)
    import tempfile as tf
    from conftest import make_batch  # noqa: F401  (path setup)
    from repro.core.cost_model import LayerInfo
    from repro.core.partition import PartitionPlanner
    infos = [LayerInfo(f"l{i}", s, max(len(jax.tree.leaves(p)), 1), 1e6)
             for i, (s, p) in enumerate(zip(sizes, params))]
    planner = PartitionPlanner(infos, DelayModel())
    plan, _ = planner.best_partition(budget)
    assert plan.m == 1, "expected degradation to serial residency"

    units = [(f"u{i:02d}", p) for i, p in enumerate(params)]
    x = jax.random.normal(jax.random.key(1), (2, hw, hw, 3))
    with tempfile.TemporaryDirectory() as d:
        sw = SwappedSequential(
            units, lambda i, p, xx: vision.apply_layer(layers[i], p, xx),
            d, mode="snet", budget=budget)
        sw.plan = plan
        out, st = sw.forward(x)      # raises MemoryError if m=2 behavior leaks
        sw.close()
    assert st["peak_resident_mb"] * 1e6 <= budget


def test_multi_dnn_scheduler_adapts():
    dm = DelayModel()
    models = []
    for i, arch in enumerate(["qwen2.5-3b", "gemma2-9b"]):
        cfg = ARCHS[arch].reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(i))
        units = split_units(model, params)
        infos = unit_infos(model, units, 2, 32)
        models.append(ScheduledModel(arch, PartitionPlanner(infos, dm)))
    total = sum(float(np.sum(m.planner.sizes)) for m in models)
    sched = MultiDNNScheduler(models, available=total * 0.5)
    for m in models:
        assert m.plan is not None and m.budget > 0
    floors = sum(m.planner.min_feasible_budget() for m in models)
    dt = sched.adapt(max(total * 0.4, floors * 1.1))  # budget shrinks at runtime
    assert dt < 5.0                          # adaptation is cheap (no re-profiling)
    for m in models:
        assert m.plan.n_blocks >= 2
        assert m.budget >= m.planner.min_feasible_budget() * 0.99

    # a budget below the sum of physical floors is rejected loudly
    with pytest.raises(ValueError, match="below the sum"):
        sched.adapt(floors * 0.5)


def test_lift_to_floors_clamps_donors():
    from repro.core.scheduler import lift_to_floors
    # three-model boundary case: the deficit equals the donors' total
    # headroom, so every donor lands EXACTLY at its floor — one step past
    # this (any sharing rule that takes more than a donor's headroom, e.g.
    # proportional to budget) pushes a donor below floor
    out = lift_to_floors([4.0, 13.0, 13.0], [10.0, 10.0, 10.0], usable=30.0)
    assert out == [10.0, 10.0, 10.0]
    # skewed headroom: lifted model reaches its floor, donors stay >= theirs
    out = lift_to_floors([2.0, 4.5, 23.5], [4.0, 4.0, 4.0], usable=30.0)
    assert abs(sum(out) - 30.0) < 1e-9
    for b, f in zip(out, [4.0, 4.0, 4.0]):
        assert b >= f - 1e-9
    assert out[0] == 4.0
    # infeasible: floors alone exceed usable
    with pytest.raises(ValueError, match="below the sum"):
        lift_to_floors([1.0, 1.0, 1.0], [10.0, 10.0, 10.0], usable=20.0)


def test_three_model_floor_lift_keeps_donors_feasible():
    """Eq. 1 starves a big-layer/low-urgency model below its physical
    floor; the lift must bring it to the floor WITHOUT pushing either
    donor below its own (every model's best_partition stays feasible)."""
    from repro.core.cost_model import LayerInfo
    dm = DelayModel()
    models = []
    # model A: one dominant 9-byte layer (high floor), tiny share appeal
    layers = {"A": [9.0, 1.0], "B": [1.0] * 20, "C": [1.0] * 20}
    urgency = {"A": 0.01, "B": 10.0, "C": 10.0}
    for name, sizes in layers.items():
        infos = [LayerInfo(f"{name}{i}", int(s * 1e6), 1, 1e9)
                 for i, s in enumerate(sizes)]
        models.append(ScheduledModel(name, PartitionPlanner(infos, dm),
                                     urgency=urgency[name]))
    floors = {m.name: m.planner.min_feasible_budget() for m in models}
    sched = MultiDNNScheduler(models, available=40e6)
    for m in sched.models:
        assert m.budget >= floors[m.name] - 1e-6, \
            f"{m.name} below its floor after lift"
        assert m.plan is not None            # partition feasible at budget
    assert sum(m.budget for m in sched.models) <= 40e6 + 1e-6
