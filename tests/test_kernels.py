"""Per-kernel shape/dtype sweeps in interpret mode vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.swap_linear import swap_linear, vmem_bytes


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 512, 128),
                                   (128, 1024, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["none", "silu"])
def test_swap_linear_sweep(M, K, N, dtype, act):
    kq, kw, kb = jax.random.split(jax.random.key(0), 3)
    x = jax.random.normal(kq, (M, K), dtype) * 0.5
    w = jax.random.normal(kw, (K, N), dtype) * (K ** -0.5)
    b = jax.random.normal(kb, (N,), dtype) * 0.1
    got = swap_linear(x, w, b, act=act, block_m=128, block_n=128,
                      block_k=128, interpret=True)
    want = ref.swap_linear_ref(x, w, b, act=act)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_swap_linear_vmem_budget():
    # default tiling must fit a 16 MB v5e VMEM twice over (headroom)
    assert vmem_bytes(256, 256, 512) < 8 * 1024 * 1024


@pytest.mark.parametrize("S,hd", [(256, 64), (512, 128), (256, 80)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None), (True, 128, None), (True, None, 50.0),
    (False, None, None), (True, 64, 30.0)])
def test_flash_attention_sweep(S, hd, dtype, causal, window, softcap):
    BH = 4
    kq, kk, kv = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(kq, (BH, S, hd), dtype) * 0.5
    k = jax.random.normal(kk, (BH, S, hd), dtype) * 0.5
    v = jax.random.normal(kv, (BH, S, hd), dtype) * 0.5
    got = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("S,hd", [(64, 64), (128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(S, hd, dtype):
    from repro.kernels.wkv6 import wkv6
    BH = 4
    keys = jax.random.split(jax.random.key(3), 5)
    r = jax.random.normal(keys[0], (BH, S, hd), dtype) * 0.5
    k = jax.random.normal(keys[1], (BH, S, hd), dtype) * 0.5
    v = jax.random.normal(keys[2], (BH, S, hd), dtype) * 0.5
    w_log = jnp.clip(-jnp.exp(jax.random.normal(keys[3], (BH, S, hd))),
                     -5.0, -1e-4).astype(dtype)
    u = (jax.random.normal(keys[4], (BH, hd)) * 0.1).astype(dtype)
    got = wkv6(r, k, v, w_log, u, interpret=True)
    want = ref.wkv6_ref(r, k, v, w_log, u)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **(_tol(dtype) if dtype != jnp.bfloat16
                                  else dict(rtol=5e-2, atol=5e-2)))


def test_wkv6_matches_model_rwkv():
    """Kernel agrees with the model's chunked WKV (same factorization)."""
    import dataclasses
    from repro.configs import ARCHS
    from repro.distributed.sharding import init_from_defs
    from repro.models import ssm
    cfg = dataclasses.replace(ARCHS["rwkv6-3b"].reduced(), dtype="float32")
    p = init_from_defs(ssm.rwkv6_defs(cfg), jax.random.key(0))
    B, S = 2, 32
    xn = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.5
    r, k, v, g, logw, _ = ssm._rwkv_time_inputs(cfg, p, xn, None)
    nh, hd = ssm.rwkv6_dims(cfg)
    from repro.kernels.wkv6 import wkv6
    def flat(t):
        return t.transpose(0, 2, 1, 3).reshape(B * nh, S, hd)
    u = jnp.broadcast_to(p["u"][None], (B, nh, hd)).reshape(B * nh, hd)
    y_kernel = wkv6(flat(r), flat(k), flat(v), flat(logw), u, interpret=True)
    y_ref = ref.wkv6_ref(flat(r), flat(k), flat(v), flat(logw), u)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_flash_matches_model_attention():
    """The kernel oracle agrees with the model's chunked online attention."""
    from repro.models.attention import online_attention
    B, S, H, hd = 2, 256, 4, 64
    kq, kk, kv = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(kq, (B, S, H, hd)) * 0.5
    k = jax.random.normal(kk, (B, S, H, hd)) * 0.5
    v = jax.random.normal(kv, (B, S, H, hd)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    got = online_attention(q, k, v, pos, None, causal=True, window=None,
                           scale=hd ** -0.5, logit_cap=None, chunk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    want = ref.flash_attention_ref(qf, kf, vf, causal=True)
    want = want.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
