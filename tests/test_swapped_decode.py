"""Weight-streaming decode (paper §10 LLM-on-edge): the swapped decode loop
must generate the same greedy tokens as the fully-resident serving engine."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.cost_model import DelayModel
from repro.core.runtime import SwappedModel
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "rwkv6-3b"])
def test_swapped_decode_matches_engine(arch):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S, NEW = 2, 12, 5
    prompts = rng.integers(0, cfg.vocab_size, (B, S))

    engine = ServingEngine(model, params, max_len=64)
    reqs = [Request(i, list(map(int, prompts[i])), max_new_tokens=NEW)
            for i in range(B)]
    engine.generate(reqs)
    want = np.asarray([r.output for r in reqs])

    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, mode="snet")
        sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(), batch=B, seq=S)
        gen, stats = sm.decode_loop(jnp.asarray(prompts, jnp.int32),
                                    max_new_tokens=NEW, max_len=64)
        sm.close()
    np.testing.assert_array_equal(np.asarray(gen), want)
    assert stats["peak_resident_mb"] > 0
