"""Fused dequant-matmul swap path (ISSUE 3): int4 pack/unpack carrier
layout, swap_linear_q vs its numpy/jnp reference at int8 and int4, the
padded swap_linear grid for odd shapes, QuantizedTensor plumbing, lazy
(quantized-resident) store + ledger accounting, and the planner's
resident-size view.

Documented error contracts exercised here:
  * int8 round trip: |x̂ - x| <= scale_c / 2 = max|x[:, c]| / 254
  * int4 round trip: |x̂ - x| <= scale_c / 2 = max|x[:, c]| / 14
  * swap_linear_q vs swap_linear(dequant(qw)): same fp32 accumulator, scale
    applied once at flush -> allclose at ~1e-5 (fp32) / ~2e-2 (bf16)
  * HBM->VMEM weight stream at equal tiles: >= 2x (int8) / >= 3.5x (int4)
    fewer bytes than the fp32 swap_linear stream
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.core.cost_model import DelayModel
from repro.core.runtime import SwappedModel
from repro.core.swap_engine import SwapEngine
from repro.kernels import ref
from repro.kernels.dequant import (pack_int4, quantize_int4, quantize_int8,
                                   unpack_int4)
from repro.kernels.qtensor import QuantizedTensor, cast_unit_params
from repro.kernels.swap_linear import (swap_linear, vmem_bytes,
                                       weight_stream_bytes)
from repro.kernels.swap_linear_q import swap_linear_q
from repro.models.layers import linear
from repro.models.transformer import Model
from repro.store import build_store

from conftest import make_batch


# ------------------------------------------------------------ int4 packing
def test_pack_int4_carrier_layout_bit_exact():
    """Carrier byte r holds row 2r in the low nibble and row 2r+1 in the
    high nibble, two's complement — asserted bit-by-bit."""
    q = np.array([[-7, 3], [5, -1], [0, 7]], np.int8)      # odd rows: pads 0
    p = pack_int4(q)
    assert p.shape == (2, 2) and p.dtype == np.int8
    u = p.view(np.uint8)
    for r in range(2):
        for c in range(2):
            lo = int(q[2 * r, c]) & 0xF
            hi = (int(q[2 * r + 1, c]) & 0xF) if 2 * r + 1 < q.shape[0] else 0
            assert u[r, c] == ((hi << 4) | lo)


@pytest.mark.parametrize("R", [1, 2, 7, 64])
def test_int4_pack_unpack_roundtrip(R):
    rng = np.random.default_rng(3)
    q = rng.integers(-7, 8, (R, 5)).astype(np.int8)
    np.testing.assert_array_equal(unpack_int4(pack_int4(q), R), q)
    # the traceable unpack agrees with the numpy one
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_int4_ref(jnp.asarray(pack_int4(q)), R)), q)


def test_quantize_int4_error_bound():
    rng = np.random.default_rng(7)
    x = rng.standard_normal((64, 32)).astype(np.float32) * 3.0
    carrier, scales = quantize_int4(x)
    assert carrier.shape == (32, 32)
    x_hat = unpack_int4(carrier, 64).astype(np.float32) * scales[None, :]
    assert np.all(np.abs(x_hat - x) <= scales[None, :] / 2 + 1e-7)
    assert np.all(np.abs(x_hat - x)
                  <= np.max(np.abs(x), axis=0)[None, :] / 14 + 1e-7)


# ------------------------------------------------------------ fused kernel
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("M,K,N", [(64, 256, 128), (50, 130, 70)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swap_linear_q_matches_ref(bits, M, K, N, dtype):
    """Pallas kernel (interpret) vs the dequant-then-matmul oracle AND vs
    swap_linear over the eagerly dequantized weight, within the documented
    accumulation-order tolerance."""
    rng = np.random.default_rng(0)
    quant = quantize_int8 if bits == 8 else quantize_int4
    wf = (rng.standard_normal((K, N)) * K ** -0.5).astype(np.float32)
    qw, s = quant(wf)
    x = jnp.asarray(rng.normal(0, 0.5, (M, K)), dtype)
    b = jnp.asarray(rng.normal(0, 0.1, (N,)), dtype)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)
    got = swap_linear_q(x, jnp.asarray(qw), jnp.asarray(s), b, bits=bits,
                        act="silu", block_m=64, block_n=64, block_k=64,
                        interpret=True)
    want = ref.swap_linear_q_ref(x, jnp.asarray(qw), jnp.asarray(s), b,
                                 act="silu", bits=bits)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)
    vals = unpack_int4(qw, K) if bits == 4 else qw
    wd = jnp.asarray(vals.astype(np.float32) * s[None, :]).astype(dtype)
    want2 = swap_linear(x, wd, b, act="silu", block_m=64, block_n=64,
                        block_k=64, interpret=True)
    tol2 = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want2, np.float32), **tol2)


@pytest.mark.parametrize("M,K,N", [(100, 300, 130), (1, 7, 3), (130, 64, 100)])
def test_swap_linear_pads_odd_shapes(M, K, N):
    """Satellite: the hard divisibility assert is gone — odd shapes pad to
    block multiples and slice back, matching the dense oracle."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 0.5, (M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(0, K ** -0.5, (K, N)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 0.1, (N,)), jnp.float32)
    got = swap_linear(x, w, b, act="gelu", block_m=64, block_n=64,
                      block_k=64, interpret=True)
    want = ref.swap_linear_ref(x, w, b, act="gelu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_vmem_and_stream_bytes_shrink():
    """Acceptance: the fused weight stream moves >= 2x (int8) / >= 3.5x
    (int4) fewer HBM->VMEM bytes than the fp stream at equal tile shapes,
    and the VMEM weight window shrinks accordingly."""
    fp = weight_stream_bytes(256, 1024, 512, w_bits=32)
    assert fp / weight_stream_bytes(256, 1024, 512, w_bits=8) >= 2.0
    assert fp / weight_stream_bytes(256, 1024, 512, w_bits=4) >= 3.5
    # default formula unchanged for the fp path (seed contract)
    assert vmem_bytes(256, 256, 512) == \
        2 * (256 * 512 + 512 * 256 + 256) * 2 + 256 * 256 * 4
    assert vmem_bytes(256, 256, 512, 2, 8) < vmem_bytes(256, 256, 512, 2)
    assert vmem_bytes(256, 256, 512, 2, 4) < vmem_bytes(256, 256, 512, 2, 8)


# ------------------------------------------------------------ QuantizedTensor
def test_quantized_tensor_pytree_and_dequant():
    rng = np.random.default_rng(5)
    wf = rng.standard_normal((40, 16)).astype(np.float32)
    qw, s = quantize_int4(wf)
    qt = QuantizedTensor(jnp.asarray(qw), jnp.asarray(s), (40, 16),
                         "float32", bits=4)
    assert qt.nbytes == qw.nbytes + s.nbytes < qt.logical_nbytes
    # jit-traversable (registered pytree)
    y = jax.jit(lambda t: t.dequant().sum())(qt)
    w_hat = np.asarray(qt.dequant())
    assert w_hat.shape == (40, 16) and w_hat.dtype == np.float32
    assert np.all(np.abs(w_hat - wf)
                  <= np.max(np.abs(wf), axis=0)[None, :] / 14 + 1e-6)
    np.testing.assert_allclose(float(y), w_hat.sum(), rtol=1e-5)


def test_linear_routes_quantized_tensor():
    """layers.linear: QuantizedTensor streams through swap_linear_q; the
    result matches the dequant-then-dense path within fp tolerance, for
    3-D activations too."""
    rng = np.random.default_rng(9)
    wf = (rng.standard_normal((64, 48)) * 8 ** -1).astype(np.float32)
    qw, s = quantize_int8(wf)
    qt = QuantizedTensor(jnp.asarray(qw), jnp.asarray(s), (64, 48),
                         "float32", bits=8)
    x = jnp.asarray(rng.normal(0, 0.5, (2, 10, 64)), jnp.float32)
    got = linear(x, qt, act="silu")
    assert got.shape == (2, 10, 48)
    want = linear(x, qt.dequant(), act="silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_cast_unit_params_keeps_fused_keys_quantized():
    rng = np.random.default_rng(2)
    qw, s = quantize_int8(rng.standard_normal((64, 32)).astype(np.float32))
    qt = lambda: QuantizedTensor(jnp.asarray(qw), jnp.asarray(s), (64, 32),
                                 "float32", bits=8)
    tree = {"ffn": {"wi0": qt(), "wo": qt()},
            "attn": {"w_dkv": qt()},          # not a fused key: dequants
            "ln1": np.ones(32, np.float32)}
    out = cast_unit_params(tree, jnp.bfloat16)
    assert isinstance(out["ffn"]["wi0"], QuantizedTensor)
    assert isinstance(out["ffn"]["wo"], QuantizedTensor)
    assert isinstance(out["attn"]["w_dkv"], jax.Array)
    assert out["attn"]["w_dkv"].dtype == jnp.bfloat16
    assert out["ln1"].dtype == jnp.bfloat16


# ------------------------------------------------------------ lazy store
def _units(seed=0, n=3, shape=(128, 256)):
    rng = np.random.default_rng(seed)
    return [(f"u{i:02d}", {"w": rng.standard_normal(shape).astype(np.float32),
                           "g": rng.standard_normal(shape[0]).astype(np.float32)})
            for i in range(n)]


@pytest.mark.parametrize("bits", [8, 4])
def test_lazy_store_delivers_quantized_resident_units(bits):
    """eager=False: quantized leaves come back as QuantizedTensor, raw
    leaves as arrays; the ledger is charged the quantized payload and
    SwapStats.bytes_resident_quantized reports the still-quantized bytes."""
    units = _units()
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend="quant", bits=bits,
                            eager=False)
        assert store.precision == ("int8" if bits == 8 else "int4")
        eng = SwapEngine(store)
        h = eng.swap_in([n for n, _ in units])
        expect = sum(store.stored_nbytes(n) for n, _ in units)
        assert h.resident_bytes == expect
        assert eng.ledger.resident == expect
        st = eng.stats
        assert 0 < st.bytes_resident_quantized <= st.bytes_swapped
        assert st.bytes_swapped < st.bytes_logical / (2.5 if bits == 8
                                                      else 5.0)
        for p, (_, orig) in zip(h.params, units):
            w = p["w"]
            assert isinstance(w, QuantizedTensor) and w.bits == bits
            assert isinstance(p["g"], jax.Array)       # raw 1-D leaf
            bound = np.max(np.abs(orig["w"]), axis=0)[None, :] \
                / (254.0 if bits == 8 else 14.0)
            assert np.all(np.abs(np.asarray(w.dequant()) - orig["w"])
                          <= bound + 1e-6)
        eng.swap_out(h)
        assert eng.ledger.resident == 0
        eng.close()


def _setup(arch, seed=0):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    batch = make_batch(cfg, ShapeConfig("p", 32, 2, "prefill"))
    return cfg, model, params, batch


def test_int4_swapped_forward_fidelity_and_bytes():
    """End-to-end int4: half the swap bytes of int8 and logits that stay
    directionally faithful (random-init reduced models are the worst case
    for 4-bit weights; pretrained weights do far better)."""
    cfg, model, params, batch = _setup("qwen2.5-3b")
    ref_logits, _ = jax.jit(model.prefill)(params, batch)
    swapped = {}
    for precision in ("int8", "int4"):
        with tempfile.TemporaryDirectory() as d:
            sm = SwappedModel(model, params, d, store_backend="quant",
                              precision=precision)
            assert sm.precision == precision
            sm.partition(budget=8 * 1024 * 1024, dm=DelayModel(),
                         batch=2, seq=32)
            logits, st = sm.forward(batch)
            sm.close()
        swapped[precision] = st["bytes_swapped"]
        assert st["bytes_resident_quantized"] > 0
        assert st["vmem_working_set"] > 0
        a = np.asarray(logits, np.float64).ravel()
        b = np.asarray(ref_logits, np.float64).ravel()[-a.size:]
        cos = a @ b / max(np.linalg.norm(a) * np.linalg.norm(b), 1e-30)
        assert cos > (0.98 if precision == "int8" else 0.8), (precision, cos)
    assert swapped["int4"] * 1.7 < swapped["int8"]


def test_partition_sees_quantized_working_set():
    """The block planner costs quantized-resident units at their payload:
    at the same budget the quant plan's resident peak is a fraction of the
    mmap one. (The planner's n-search may give quant MORE blocks than mmap
    on purpose — the slack budget buys pipeline depth — so the working-set
    claim is asserted on the peak, not the block count.)"""
    cfg, model, params, batch = _setup("qwen2.5-3b")
    budget = 4 * 1024 * 1024
    blocks, peaks = {}, {}
    for backend in ("mmap", "quant"):
        with tempfile.TemporaryDirectory() as d:
            sm = SwappedModel(model, params, d, store_backend=backend)
            sm.partition(budget=budget, dm=DelayModel(), batch=2, seq=32)
            _, st = sm.forward(batch)
            blocks[backend] = sm.plan.n_blocks
            peaks[backend] = st["peak_resident_mb"]
            sm.close()
    assert peaks["quant"] * 1.5 < peaks["mmap"]
    # the deepening is bounded: kappa stops paying after a couple of extra
    # counts at this scale, so quant stays within a small margin of mmap
    assert blocks["quant"] <= blocks["mmap"] + 2


def test_config_swap_precision_default():
    """granite-20b opts into int4 swap units; the runtime resolves the
    config default when no explicit precision is passed."""
    assert ARCHS["granite-20b"].swap_precision == "int4"
    assert ARCHS["qwen2.5-3b"].swap_precision == "int8"
    cfg, model, params, _ = _setup("qwen2.5-3b")
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, store_backend="quant")
        assert sm.precision == "int8"
        assert sm.store.bits == 8
        sm.close()
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d)     # exact store: fp axis
        assert sm.precision == "fp"
        sm.close()
