"""Serving engine: batched generation, cache padding, determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "rwkv6-3b", "zamba2-7b"])
def test_generate_batched(arch):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 16)))
               for _ in range(4)]
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    stats = engine.generate(reqs)
    assert all(len(r.output) == 8 for r in reqs)
    assert stats["decode_steps"] >= 7

    # greedy decoding is deterministic
    reqs2 = [Request(10 + i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    engine.generate(reqs2)
    for a, b in zip(reqs, reqs2):
        assert a.output == b.output


def test_generation_continues_prefill_distribution():
    """The first generated token equals argmax of prefill logits."""
    cfg = dataclasses.replace(ARCHS["qwen2.5-3b"].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    engine = ServingEngine(model, params, max_len=64)
    rng = np.random.default_rng(1)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 12)))
    reqs = [Request(0, prompt, max_new_tokens=4)]
    engine.generate(reqs)
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    logits, _ = jax.jit(model.prefill)(params, batch)
    assert reqs[0].output[0] == int(jnp.argmax(logits[0, -1]))
