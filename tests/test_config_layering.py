"""Layered serving configuration (ISSUE 9 tentpole): defaults -> profile ->
env (``SWAPNET_*``) -> CLI.

Covers the edge cases the layering must hold:
  * deep-merge semantics — nested dicts recurse, scalars AND lists are
    last-wins (a layer that sets ``workload.priorities`` replaces the list
    wholesale);
  * env type coercion — ``"24"`` -> float, ``"true"/"0"`` -> bool,
    ``"1,8"`` -> ``[1.0, 8.0]``, ``"none"`` -> None for Optional fields;
  * unknown-key rejection with a did-you-mean hint (dict keys AND env
    vars), instead of a typo silently falling back to a default;
  * profile-not-found with a did-you-mean hint;
  * full precedence ordering through all four layers;
  * ``validate()`` cross-field invariants;
  * every shipped profile resolves AND validates (the profiles are data —
    nothing type-checks them until they go through the schema).
"""
import dataclasses

import pytest

from repro.config import (ENV_PREFIX, PROFILES, ServeConfig, deep_merge,
                          env_overlay, explain_layers, profile_names,
                          profile_overlay, resolve_config)
from repro.errors import ConfigError


# ------------------------------------------------------------- deep merge
def test_deep_merge_dicts_recurse():
    base = {"runtime": {"budget_mb": 8.0, "store": "mmap"}}
    out = deep_merge(base, {"runtime": {"budget_mb": 24.0}})
    assert out["runtime"] == {"budget_mb": 24.0, "store": "mmap"}
    # inputs are not mutated
    assert base["runtime"]["budget_mb"] == 8.0


def test_deep_merge_lists_replace_wholesale():
    base = {"workload": {"priorities": [1.0, 8.0]}, "models": ["a", "b"]}
    out = deep_merge(base, {"workload": {"priorities": [2.0]},
                            "models": []})
    assert out["workload"]["priorities"] == [2.0]     # not [2.0, 8.0]
    assert out["models"] == []                        # not ["a", "b"]


def test_deep_merge_scalar_replaces_dict_and_vice_versa():
    assert deep_merge({"k": {"a": 1}}, {"k": 2}) == {"k": 2}
    assert deep_merge({"k": 2}, {"k": {"a": 1}}) == {"k": {"a": 1}}


# ------------------------------------------------------------- env layer
def test_env_overlay_coerces_types():
    cfg = resolve_config(env={
        "SWAPNET_RUNTIME_BUDGET_MB": "24",
        "SWAPNET_RUNTIME_EXECUTORS": "2",
        "SWAPNET_RUNTIME_PAGED": "true",
        "SWAPNET_SCHEDULER_PREEMPT": "0",
        "SWAPNET_WORKLOAD_PRIORITIES": "1,8",
        "SWAPNET_ARCH": "qwen2.5-3b",
    })
    assert cfg.runtime.budget_mb == 24.0
    assert cfg.runtime.executors == 2
    assert cfg.runtime.paged is True
    assert cfg.scheduler.preempt is False
    assert cfg.workload.priorities == [1.0, 8.0]
    assert cfg.arch == "qwen2.5-3b"


def test_env_overlay_optional_none_strings():
    ov = env_overlay({"SWAPNET_RUNTIME_PRECISION": "none"})
    cfg = ServeConfig.from_dict(ov)
    assert cfg.runtime.precision is None


def test_env_overlay_models_list():
    cfg = resolve_config(env={
        "SWAPNET_MODELS": "qwen2.5-3b,gemma2-9b",
        "SWAPNET_RUNTIME_BUDGET_MB": "48",
    })
    assert cfg.models == ["qwen2.5-3b", "gemma2-9b"]


def test_env_overlay_ignores_foreign_vars():
    assert env_overlay({"PATH": "/bin", "SWAPNET_PROFILE": "mcu"}) == {}


def test_env_unknown_var_did_you_mean():
    with pytest.raises(ConfigError, match="SWAPNET_RUNTIME_BUDGET_MB"):
        env_overlay({"SWAPNET_RUNTIME_BUDGT_MB": "24"})


def test_env_bad_int_raises():
    with pytest.raises(ConfigError, match="runtime.executors"):
        resolve_config(env={"SWAPNET_RUNTIME_EXECUTORS": "two"})


def test_env_bad_bool_raises():
    with pytest.raises(ConfigError, match="runtime.paged"):
        resolve_config(env={"SWAPNET_RUNTIME_PAGED": "maybe"})


def test_env_profile_variable_selects_profile():
    cfg = resolve_config(env={ENV_PREFIX + "PROFILE": "mcu"})
    assert cfg.profile == "mcu"
    assert cfg.runtime.store == "quant"
    # an explicit profile beats $SWAPNET_PROFILE
    cfg = resolve_config(profile="edge-tpu",
                         env={ENV_PREFIX + "PROFILE": "mcu"})
    assert cfg.profile == "edge-tpu"


# ---------------------------------------------------------- unknown keys
def test_unknown_key_did_you_mean():
    with pytest.raises(ConfigError, match="budget_mb"):
        ServeConfig.from_dict({"runtime": {"budjet_mb": 8}})


def test_unknown_toplevel_key_rejected():
    with pytest.raises(ConfigError, match="unknown config key"):
        ServeConfig.from_dict({"runtme": {}})


def test_profile_not_found_did_you_mean():
    with pytest.raises(ConfigError, match="edge-tpu"):
        profile_overlay("edge_tpu")
    with pytest.raises(ConfigError):
        resolve_config(profile="no-such-profile", env={})


# ------------------------------------------------------------ precedence
def test_precedence_defaults_profile_env_cli():
    # defaults: budget_mb None; profile mcu: 8; env: 16; cli: 32
    assert ServeConfig().runtime.budget_mb is None
    cfg = resolve_config(profile="mcu", env={})
    assert cfg.runtime.budget_mb == 8.0
    cfg = resolve_config(profile="mcu",
                         env={"SWAPNET_RUNTIME_BUDGET_MB": "16"})
    assert cfg.runtime.budget_mb == 16.0
    cfg = resolve_config(profile="mcu",
                         env={"SWAPNET_RUNTIME_BUDGET_MB": "16"},
                         cli={"runtime": {"budget_mb": 32.0}})
    assert cfg.runtime.budget_mb == 32.0
    # a layer only touches what it sets: mcu's store survives the overrides
    assert cfg.runtime.store == "quant"
    assert cfg.profile == "mcu"


def test_explain_layers_order_and_names():
    names = [n for n, _ in explain_layers(
        profile="mcu", env={"SWAPNET_REDUCE": "smoke"},
        cli={"arch": "qwen2.5-3b"})]
    assert names == ["defaults", "profile:mcu", "env", "cli"]


def test_defaults_resolve_hermetically():
    cfg = resolve_config(env={})
    assert cfg == ServeConfig()         # no layers -> pure defaults


# ------------------------------------------------------------ validation
def test_validate_rejects_bad_enums():
    with pytest.raises(ConfigError, match="reduce"):
        resolve_config(env={}, cli={"reduce": "tiny"})
    with pytest.raises(ConfigError, match="store"):
        resolve_config(env={}, cli={"runtime": {"store": "s3"}})
    with pytest.raises(ConfigError, match="precision"):
        resolve_config(env={}, cli={"runtime": {"precision": "int2"}})


def test_validate_rejects_bad_ranges():
    with pytest.raises(ConfigError, match="executors"):
        resolve_config(env={}, cli={"runtime": {"executors": 0}})
    with pytest.raises(ConfigError, match="cache_frac"):
        resolve_config(env={}, cli={"runtime": {"cache_frac": 1.5}})
    with pytest.raises(ConfigError, match="budget_mb"):
        resolve_config(env={}, cli={"runtime": {"budget_mb": -1}})
    with pytest.raises(ConfigError, match="no block budget"):
        resolve_config(env={}, cli={"runtime": {"paged": True,
                                                "cache_frac": 0.5,
                                                "kv_frac": 0.6}})


def test_validate_arch_xor_models():
    with pytest.raises(ConfigError, match="not both"):
        resolve_config(env={}, cli={"arch": "qwen2.5-3b",
                                    "models": ["gemma2-9b"]})


def test_validate_unknown_arch_did_you_mean():
    with pytest.raises(ConfigError, match="qwen2.5-3b"):
        resolve_config(env={}, cli={"arch": "qwen-3b"})


# -------------------------------------------------------------- profiles
def test_every_profile_resolves_and_validates():
    assert set(profile_names()) == set(PROFILES)
    for name in profile_names():
        cfg = resolve_config(profile=name, env={})
        assert cfg.profile == name
        assert cfg.model_names(), name          # complete scenario
        assert cfg.runtime.budget_mb and cfg.runtime.budget_mb > 0, name
        assert PROFILES[name]["description"]


def test_profiles_cover_distinct_device_classes():
    stores = {resolve_config(profile=n, env={}).runtime.store
              for n in profile_names()}
    assert len(stores) >= 2          # not three copies of one deployment
    assert {"mcu", "edge-tpu", "workstation"} <= set(profile_names())


# ------------------------------------------------------------- round trip
def test_to_dict_from_dict_round_trip():
    cfg = resolve_config(profile="workstation", env={})
    again = ServeConfig.from_dict(cfg.to_dict()).validate()
    assert again == cfg


def test_from_dict_partial_sections():
    cfg = ServeConfig.from_dict({"runtime": {"budget_mb": 4}})
    assert cfg.runtime.budget_mb == 4.0
    assert cfg.runtime.store == "mmap"          # untouched defaults
    assert dataclasses.asdict(cfg.workload) \
        == dataclasses.asdict(ServeConfig().workload)
