"""Per-arch smoke tests: reduced variant, one forward/train step on CPU,
asserting output shapes and no NaNs (deliverable (f))."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models.transformer import Model, alloc_cache

from conftest import make_batch

SMOKE = ShapeConfig("smoke_train", seq_len=32, global_batch=2, mode="train")


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, SMOKE)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(not bool(jnp.any(jnp.isnan(g))) for g in flat), f"{arch}: NaN grads"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_shapes(arch):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    shape = ShapeConfig("smoke_prefill", seq_len=32, global_batch=2, mode="prefill")
    batch = make_batch(cfg, shape)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN prefill logits"


@pytest.mark.parametrize("arch", sorted(a for a in ARCHS
                                        if ARCHS[a].supports_decode()))
def test_decode_matches_prefill(arch):
    """Token-by-token decode from a zero cache must reproduce the prefill
    logits — validates KV/MLA caches and the chunked SSM state math."""
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    if cfg.moe is not None:
        # equivalence needs drop-free routing: prefill drops over-capacity
        # tokens, single-token decode never does
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_routed)))
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 16
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    pre_batch = {"tokens": tokens}   # text-only (no vision merge) on purpose
    if cfg.rope_type == "mrope":
        pre_batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
    ref_logits, _ = jax.jit(model.prefill)(params, pre_batch)

    dec_shape = ShapeConfig("d", seq_len=S, global_batch=B, mode="decode")
    cache = alloc_cache(model, dec_shape)
    step = jax.jit(model.decode_step)
    for t in range(S):
        db = {"token": tokens[:, t:t + 1], "pos": jnp.full((B,), t, jnp.int32)}
        if cfg.rope_type == "mrope":
            db["positions"] = jnp.full((B, 1, 3), t, jnp.int32)
        logits, cache = step(params, cache, db)

    assert logits.shape == (B, 1, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(ref_logits[:, 0]),
                               rtol=2e-3, atol=2e-3)
