"""Chunked SSM forms vs naive per-timestep recurrences (the math oracle)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.distributed.sharding import init_from_defs
from repro.models import ssm


def _naive_mamba2(cfg, p, x):
    """Literal per-step recurrence."""
    d_inner, nh, ds = ssm.mamba2_dims(cfg)
    hd = cfg.ssm.head_dim
    B, S, D = x.shape
    z, xs, Bv, Cv, dt, a, _ = ssm._mamba2_inputs(cfg, p, x, None)
    h = jnp.zeros((B, nh, hd, ds))
    ys = []
    for t in range(S):
        h = a[:, t][:, :, None, None] * h + jnp.einsum(
            "bnh,bd,bn->bnhd", xs[:, t].astype(jnp.float32),
            Bv[:, t].astype(jnp.float32), dt[:, t])
        ys.append(jnp.einsum("bnhd,bd->bnh", h, Cv[:, t].astype(jnp.float32)))
    y = jnp.stack(ys, 1) + xs.astype(jnp.float32) * p["D_skip"][:, None]
    from repro.models.layers import rms_norm
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_y"], cfg.norm_eps)
    return y.astype(x.dtype) @ p["wo"], h


def test_mamba2_chunked_matches_naive():
    cfg = dataclasses.replace(ARCHS["zamba2-7b"].reduced(), dtype="float32")
    p = init_from_defs(ssm.mamba2_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model)) * 0.5
    y_naive, h_naive = _naive_mamba2(cfg, p, x)
    y_chunk, (h_chunk, _) = ssm.mamba2_chunked(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_naive),
                               rtol=1e-4, atol=1e-4)


def _naive_rwkv6(cfg, p, xn):
    nh, hd = ssm.rwkv6_dims(cfg)
    B, S, D = xn.shape
    r, k, v, g, logw, _ = ssm._rwkv_time_inputs(cfg, p, xn, None)
    Scur = jnp.zeros((B, nh, hd, hd))
    ys = []
    for t in range(S):
        rq, kq, vq, lw = r[:, t], k[:, t], v[:, t], logw[:, t]
        bonus = jnp.einsum("bnh,bnh->bn", rq, p["u"][None] * kq)
        ys.append(jnp.einsum("bnh,bnhv->bnv", rq, Scur) + bonus[..., None] * vq)
        Scur = jnp.exp(lw)[..., None] * Scur + kq[..., None] * vq[..., None, :]
    y = jnp.stack(ys, 1).reshape(B, S, D)
    from repro.models.layers import layer_norm
    y = layer_norm(y, p["ln_x_w"], p["ln_x_b"], eps=1e-5)
    return (y.astype(xn.dtype) * g) @ p["wo"], Scur


def test_rwkv6_chunked_matches_naive():
    cfg = dataclasses.replace(ARCHS["rwkv6-3b"].reduced(), dtype="float32")
    p = init_from_defs(ssm.rwkv6_defs(cfg), jax.random.key(0))
    xn = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model)) * 0.5
    y_naive, S_naive = _naive_rwkv6(cfg, p, xn)
    y_chunk, (S_chunk, _) = ssm.rwkv6_time_mix_chunked(cfg, p, xn)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_chunk), np.asarray(S_naive),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_decay_is_data_dependent():
    """Finch's contribution: different inputs -> different decay."""
    cfg = dataclasses.replace(ARCHS["rwkv6-3b"].reduced(), dtype="float32")
    p = init_from_defs(ssm.rwkv6_defs(cfg), jax.random.key(0))
    x1 = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    x2 = jax.random.normal(jax.random.key(2), (1, 8, cfg.d_model))
    *_, w1, _ = ssm._rwkv_time_inputs(cfg, p, x1, None)
    *_, w2, _ = ssm._rwkv_time_inputs(cfg, p, x2, None)
    assert not np.allclose(np.asarray(w1), np.asarray(w2))
