"""Continuous-batching decode engine (serving tentpole).

Greedy decode is deterministic, so batching/paging/preemption must be
INVISIBLE in the outputs: every request's tokens must equal what a solo
contiguous-cache run produces, while the step trace shows batch membership
actually changing every iteration (admissions and retirements at step
boundaries, preemption-by-recomputation under page pressure).
"""
import dataclasses
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.cost_model import DelayModel
from repro.core.multi_model import MultiModelRuntime
from repro.core.runtime import SwappedModel
from repro.core.serving_scheduler import ServingScheduler
from repro.core.swap_engine import MemoryLedger
from repro.models.transformer import Model
from repro.serving.batch_engine import BatchDecodeEngine
from repro.serving.engine import Request, ServingEngine
from repro.serving.paged_kv import PagedKVCache

MB = 1024 * 1024


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(ARCHS["qwen2.5-3b"].reduced(), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 8)))
               for _ in range(5)]
    eng = ServingEngine(model, params, max_len=64)

    def solo(prompt, max_new):
        r = Request(0, list(prompt), max_new_tokens=max_new)
        eng.generate([r])
        return list(r.output)
    return cfg, model, params, prompts, solo


def _swapped(model, params, workdir):
    sm = SwappedModel(model, params, workdir, mode="snet")
    sm.partition(budget=8 * MB, dm=DelayModel(), batch=2, seq=16)
    return sm


def test_continuous_batching_exact_with_step_trace(setup):
    cfg, model, params, prompts, solo = setup
    max_new = [2, 6, 3, 5, 4]
    want = [solo(prompts[i], max_new[i]) for i in range(5)]
    with tempfile.TemporaryDirectory() as d:
        sm = _swapped(model, params, d)
        kv = PagedKVCache(cfg, MemoryLedger(1 << 30), page_tokens=4,
                          max_pages=8)
        be = BatchDecodeEngine(sm, kv, max_batch=2)
        reqs = [Request(i, list(prompts[i]), max_new_tokens=max_new[i])
                for i in range(5)]
        for r in reqs:
            be.submit(r)
        be.run_all()
        sm.close()
    assert [list(r.output) for r in reqs] == want

    # ---- the trace is a real continuous-batching log
    tr = be.trace
    assert sorted(r for t in tr for r in t.retired) == [0, 1, 2, 3, 4]
    assert sorted(r for t in tr for r in t.admitted) == [0, 1, 2, 3, 4]
    assert all(len(t.batch) <= 2 for t in tr)
    # each request retires at ITS OWN length: rid 0 (2 tokens) leaves long
    # before rid 1 (6 tokens), and its slot is refilled mid-flight — some
    # step admits a new sequence while another is still decoding
    retire_step = {r: t.step for t in tr for r in t.retired}
    assert retire_step[0] < retire_step[1]
    refills = [t for t in tr if t.admitted and t.batch]
    assert refills, "no admission ever joined a running batch"
    # admissions happened at 3+ distinct step boundaries (5 reqs, 2 slots)
    assert len({t.step for t in tr if t.admitted}) >= 3
    # pages were freed mid-run: pool occupancy is not monotone
    pages = [t.kv_pages for t in tr]
    assert any(b < a for a, b in zip(pages, pages[1:]))
    assert kv.pages_in_use == 0
    st = be.stats()
    assert st["tokens_emitted"] == sum(max_new)
    assert 0 < st["mean_occupancy"] <= 1.0


def test_preemption_by_recomputation_exact(setup):
    """Page pressure evicts the lowest-priority sequence mid-decode; it is
    re-admitted (prompt + emitted output recomputed) and still produces
    exactly the solo outputs."""
    cfg, model, params, prompts, solo = setup
    want_hi = solo(prompts[0], 5)
    want_lo = solo(prompts[1], 4)
    with tempfile.TemporaryDirectory() as d:
        sm = _swapped(model, params, d)
        # prompts are 8 tokens = 2 pages of 4; 5 pages total, so two admitted
        # sequences leave ONE spare page: the first boundary crossing evicts
        kv = PagedKVCache(cfg, MemoryLedger(1 << 30), page_tokens=4,
                          max_pages=5)
        be = BatchDecodeEngine(sm, kv, max_batch=2)
        hi = Request(0, list(prompts[0]), max_new_tokens=5, priority=2.0)
        lo = Request(1, list(prompts[1]), max_new_tokens=4, priority=1.0)
        be.submit(hi)
        be.submit(lo)
        be.run_all()
        sm.close()
    assert list(hi.output) == want_hi
    assert list(lo.output) == want_lo
    assert be.preemptions >= 1
    preempted = [r for t in be.trace for r in t.preempted]
    assert 1 in preempted and 0 not in preempted, \
        "eviction must pick the LOW priority sequence"
    # rid 1 was admitted twice (initial + recompute)
    assert sum(t.admitted.count(1) for t in be.trace) == 2
    # the high-priority sequence was never stalled: it decoded every step
    # from its admission to its retirement
    hi_steps = [t.step for t in be.trace if 0 in t.batch or 0 in t.retired]
    assert hi_steps == list(range(min(hi_steps), max(hi_steps) + 1))


def test_eos_retires_early(setup):
    cfg, model, params, prompts, solo = setup
    # find a generation with a token whose FIRST occurrence is mid-sequence,
    # so stopping on it as EOS genuinely exercises early retirement
    full = eos_at = None
    for p in prompts:
        full = solo(p, 6)
        ks = [k for k in range(1, len(full)) if full[k] not in full[:k]]
        if ks:
            prompt, eos_at = p, ks[0]
            break
    assert eos_at is not None, "all sample generations are constant"
    with tempfile.TemporaryDirectory() as d:
        sm = _swapped(model, params, d)
        kv = PagedKVCache(cfg, MemoryLedger(1 << 30), page_tokens=4,
                          max_pages=8)
        be = BatchDecodeEngine(sm, kv, max_batch=2)
        r = Request(0, list(prompt), max_new_tokens=6, eos=full[eos_at])
        be.submit(r)
        be.run_all()
        sm.close()
    assert list(r.output) == full[:eos_at + 1]


def test_oversized_prompt_raises(setup):
    cfg, model, params, prompts, _ = setup
    with tempfile.TemporaryDirectory() as d:
        sm = _swapped(model, params, d)
        kv = PagedKVCache(cfg, MemoryLedger(1 << 30), page_tokens=4,
                          max_pages=1)       # 4-token capacity, 8-token prompt
        be = BatchDecodeEngine(sm, kv, max_batch=2)
        be.submit(Request(0, list(prompts[0]), max_new_tokens=2))
        with pytest.raises(MemoryError):
            be.run_all()
        sm.close()


def test_scheduler_generate_integration(setup):
    """submit_generate drives decode through the shared-budget runtime: one
    driver's stepping serves other drivers' sequences, completion comes from
    the retire callback, and the KV pool + ledger end clean."""
    cfg, model, params, prompts, solo = setup
    max_new = [3, 5, 4]
    want = [solo(prompts[i], max_new[i]) for i in range(3)]
    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(budget=24 * MB, cache_frac=0.2, kv_frac=0.25,
                               page_tokens=4, max_batch=2)
        rt.add_model("m", model, params, d)
        rt.plan(batch=2, seq=16)
        reqs = [Request(i, list(prompts[i]), max_new_tokens=max_new[i])
                for i in range(3)]
        with ServingScheduler(rt, executors=1) as sched:
            handles = [sched.submit_generate("m", r) for r in reqs]
            for h in handles:
                h.wait(timeout=600)
        assert [list(r.output) for r in reqs] == want
        be = rt.batch_engine("m")
        assert be.kv.pages_in_use == 0
        assert len(sched.completed) == 3
        assert all(h.latency_s > 0 for h in handles)
        rt.close()
