"""HTTP control plane + metrics registry (ISSUE 9 tentpole).

One real serving stack (reduced arch, real ServingScheduler, real
ThreadingHTTPServer on an ephemeral port) behind every test:

  * submit/poll round-trip: HTTP logits == the runtime's own forward;
  * the acceptance invariant — ``/metrics`` numbers match the scheduler's
    internal stats EXACTLY (same values, not approximately);
  * cancel, runtime model arrival (add + replan), breaker reset, replan;
  * error surface: bad JSON, unknown routes/models/rids, generate without
    a KV reserve -> 409.

The MetricsRegistry is additionally covered stand-alone (it must work
with no scheduler attached, and render well-formed Prometheus text).
"""
import dataclasses
import json
import tempfile
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.multi_model import MultiModelRuntime
from repro.core.serving_scheduler import ServingScheduler
from repro.models.transformer import Model
from repro.serving.control_plane import ENDPOINTS, ControlPlane
from repro.serving.engine import Request, pad_prompts
from repro.serving.metrics import MetricsRegistry, render_prometheus


def _call(base, path, body=None, timeout=60.0):
    req = urllib.request.Request(
        base + path,
        data=(json.dumps(body).encode() if body is not None else None),
        headers={"Content-Type": "application/json"},
        method="POST" if body is not None else "GET")
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        raw = resp.read()
        if "text/plain" in resp.headers.get("Content-Type", ""):
            return resp.status, raw.decode()
        return resp.status, json.loads(raw)


def _status_of(err_or_resp):
    return err_or_resp[0] if isinstance(err_or_resp, tuple) \
        else err_or_resp.code


def _expect_error(base, path, status, body=None):
    with pytest.raises(urllib.error.HTTPError) as ei:
        _call(base, path, body)
    assert ei.value.code == status, ei.value.read()
    return json.loads(ei.value.read() or b"{}")


def _tiny(arch="qwen2.5-3b", seed=0):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    model = Model(cfg)
    return cfg, model, model.init(jax.random.key(seed))


@pytest.fixture(scope="module")
def stack():
    """runtime + scheduler + control plane over ONE reduced model, with an
    injected arrival factory so add_model stays cheap."""
    cfg, model, params = _tiny()

    def build_model(arch, reduce, seed):
        _, m, p = _tiny(arch, seed=seed)
        return m, p

    with tempfile.TemporaryDirectory() as d:
        rt = MultiModelRuntime(budget=int(40e6), cache_frac=0.2)
        rt.add_model("qwen2.5-3b", model, params, d)
        rt.plan(batch=2, seq=16)
        sched = ServingScheduler(rt, preempt=True)
        cp = ControlPlane(rt, sched, host="127.0.0.1", port=0,
                          plan_shape=(2, 16), reduce="smoke", workdir=d,
                          build_model=build_model)
        with cp:
            yield cfg, rt, sched, cp, cp.url
        sched.shutdown()
        rt.close()


# ---------------------------------------------------------------- liveness
def test_healthz(stack):
    _, _, _, _, base = stack
    status, health = _call(base, "/healthz")
    assert status == 200
    assert health["status"] == "ok"
    assert health["models"]["qwen2.5-3b"] is True


def test_models_listing(stack):
    _, rt, _, _, base = stack
    _, out = _call(base, "/v1/models")
    info = out["models"]["qwen2.5-3b"]
    assert info["up"] is True
    assert info["n_blocks"] == rt.models["qwen2.5-3b"].plan.n_blocks
    assert info["store"] == "mmap"


# ----------------------------------------------------------- submit / poll
def test_submit_poll_matches_in_process_forward(stack):
    cfg, rt, _, _, base = stack
    rng = np.random.default_rng(3)
    rows = [[int(t) for t in rng.integers(0, cfg.vocab_size, 16)]
            for _ in range(2)]
    _, sub = _call(base, "/v1/submit", {"model": "qwen2.5-3b",
                                        "tokens": rows})
    assert sub["batch_shape"] == [2, 16]
    out = _poll_done(base, sub["rid"])
    assert out["latency_s"] > 0
    got = np.asarray(out["logits"] if "logits" in out else [])
    _, full = _call(base, f"/v1/requests/{sub['rid']}?logits=1")
    got = np.asarray(full["logits"])

    reqs = [Request(i, r) for i, r in enumerate(rows)]
    ref, _ = rt.forward("qwen2.5-3b", pad_prompts(cfg, reqs))
    np.testing.assert_allclose(got, np.asarray(ref, np.float64),
                               rtol=1e-5, atol=1e-5)


def test_submit_seeded_random_workload(stack):
    _, _, _, _, base = stack
    _, sub = _call(base, "/v1/submit", {"model": "qwen2.5-3b",
                                        "requests": 3, "prompt_len": 8,
                                        "seed": 11, "priority": 4.0})
    out = _poll_done(base, sub["rid"])
    assert out["logits_shape"][0] == 3
    assert out["priority"] == 4.0


def _poll_done(base, rid, deadline_s=120.0):
    import time
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline_s:
        _, out = _call(base, f"/v1/requests/{rid}")
        if out["status"] != "pending":
            assert out["status"] == "done", out
            return out
        time.sleep(0.02)
    raise AssertionError(f"rid {rid} still pending after {deadline_s}s")


def test_cancel_or_complete(stack):
    _, _, _, _, base = stack
    _, sub = _call(base, "/v1/submit", {"model": "qwen2.5-3b",
                                        "requests": 1, "prompt_len": 8})
    _, res = _call(base, f"/v1/requests/{sub['rid']}/cancel", {})
    _, out = _call(base, f"/v1/requests/{sub['rid']}")
    if res["cancelled"]:
        assert out["status"] == "cancelled"
        assert out["error"]["type"] == "RequestCancelled"
    else:       # executor won the race; the request must complete cleanly
        _poll_done(base, sub["rid"])


# ------------------------------------------------------- metrics exactness
def test_metrics_match_scheduler_internals_exactly(stack):
    """The acceptance criterion: a /metrics scrape agrees EXACTLY with the
    scheduler's own latency_by_class / counters at snapshot time."""
    _, rt, sched, cp, base = stack
    # quiesce: everything submitted so far completed (tests above waited)
    by_class = sched.latency_by_class()
    quant = cp.metrics.latency_quantiles()
    _, text = _call(base, "/metrics")

    got_count = _prom_samples(text, "swapnet_requests_completed_total")
    for prio, lats in by_class.items():
        assert got_count[(("priority", f"{prio:g}"),)] == float(len(lats))
    got_lat = _prom_samples(text, "swapnet_request_latency_seconds")
    for prio, q in quant.items():
        key = ("priority", f"{prio:g}")
        assert got_lat[(key, ("quantile", "0.5"))] \
            == pytest.approx(q["p50_s"], rel=0, abs=0)
        assert got_lat[(key, ("quantile", "0.99"))] \
            == pytest.approx(q["p99_s"], rel=0, abs=0)
        # and the quantiles ARE np.percentile over the raw latencies
        assert q["p50_s"] == float(np.percentile(by_class[prio], 50))

    got = _prom_samples(text, "swapnet_cache_hit_rate")
    assert got[()] == float(rt.cache.hit_rate())
    got = _prom_samples(text, "swapnet_ledger_peak_bytes")
    assert got[()] == float(rt.ledger.peak)
    got = _prom_samples(text, "swapnet_preemptions_total")
    assert got[()] == float(sched.preemptions)
    got = _prom_samples(text, "swapnet_model_up")
    assert got[(("model", "qwen2.5-3b"),)] == 1.0


def _prom_samples(text, family):
    """{ tuple(sorted(label pairs)) : value } for one metric family."""
    out = {}
    for line in text.splitlines():
        if not line.startswith(family) or line.startswith("#"):
            continue
        rest = line[len(family):]
        if rest[:1] not in ("{", " "):
            continue        # a longer family name sharing the prefix
        labels = ()
        if rest.startswith("{"):
            inner, _, rest = rest[1:].partition("}")
            labels = tuple(sorted(
                tuple(p.split("=", 1)) for p in inner.split(",") if p))
            labels = tuple((k, v.strip('"')) for k, v in labels)
        out[labels] = float(rest.strip())
    return out


def test_metrics_content_type_and_families(stack):
    _, _, _, _, base = stack
    req = urllib.request.Request(base + "/metrics")
    with urllib.request.urlopen(req, timeout=30) as resp:
        assert "text/plain" in resp.headers["Content-Type"]
        text = resp.read().decode()
    assert "# TYPE swapnet_ledger_occupancy gauge" in text
    assert "# HELP swapnet_cache_hit_rate" in text
    assert "swapnet_http_requests_total" in text


# --------------------------------------------------------- runtime arrival
def test_add_model_then_serve_it(stack):
    _, rt, sched, _, base = stack
    _, added = _call(base, "/v1/models",
                     {"arch": "qwen2.5-3b", "name": "tenant-b"})
    assert added["added"] == "tenant-b"
    assert "tenant-b" in added["models"]
    assert rt.models["tenant-b"].plan is not None    # replanned
    _, sub = _call(base, "/v1/submit", {"model": "tenant-b",
                                        "requests": 2, "prompt_len": 16})
    _poll_done(base, sub["rid"])
    # duplicate arrival is a conflict
    _expect_error(base, "/v1/models", 409,
                  {"arch": "qwen2.5-3b", "name": "tenant-b"})


def test_replan_budgets_over_http(stack):
    _, rt, _, _, base = stack
    _, out = _call(base, "/v1/replan",
                   {"urgencies": {name: 1.0 for name in rt.models}})
    assert set(out["budgets_mb"]) == set(rt.models)
    assert all(v > 0 for v in out["budgets_mb"].values())


def test_reset_model(stack):
    _, _, _, _, base = stack
    _, out = _call(base, "/v1/models/qwen2.5-3b/reset", {})
    assert out == {"reset": "qwen2.5-3b", "up": True}
    _expect_error(base, "/v1/models/nope/reset", 404, {})


# ------------------------------------------------------------ error paths
def test_error_surface(stack):
    _, _, _, _, base = stack
    _expect_error(base, "/v1/submit", 400, {})                # no model
    _expect_error(base, "/v1/submit", 404, {"model": "ghost"})
    _expect_error(base, "/v1/submit", 400,
                  {"model": "qwen2.5-3b", "tokens": [[999999]]})
    _expect_error(base, "/v1/requests/424242", 404)
    _expect_error(base, "/no/such/route", 404)
    # generate needs a KV reserve; this runtime has kv_frac=0 -> 409
    _expect_error(base, "/v1/generate", 409,
                  {"model": "qwen2.5-3b", "prompt": [1, 2, 3]})


def test_bad_json_body_is_400(stack):
    _, _, _, _, base = stack
    req = urllib.request.Request(base + "/v1/submit", data=b"{nope",
                                 headers={"Content-Type": "application/json"},
                                 method="POST")
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=30)
    assert ei.value.code == 400


def test_endpoints_contract_is_complete(stack):
    """Every route the handler dispatches is declared in ENDPOINTS (the
    docs-drift checker verifies docs against this same tuple)."""
    paths = {p for _, p in ENDPOINTS}
    for must in ("/healthz", "/metrics", "/v1/submit", "/v1/generate",
                 "/v1/models", "/v1/replan", "/v1/shutdown",
                 "/v1/requests/<rid>", "/v1/requests/<rid>/cancel",
                 "/v1/models/<name>/reset"):
        assert must in paths, must


# ------------------------------------------------- registry, stand-alone
def test_metrics_registry_without_scheduler():
    reg = MetricsRegistry()             # nothing attached: no samples
    assert reg.collect() == []
    assert reg.latency_quantiles() == {}
    reg.count_http("/healthz")
    reg.count_http("/healthz")
    text = reg.render_prometheus()
    assert 'swapnet_http_requests_total{endpoint="/healthz"} 2' in text


def test_render_prometheus_groups_families():
    text = render_prometheus([
        ("swapnet_queue_depth", {}, 3.0),
        ("swapnet_model_up", {"model": "a"}, 1.0),
        ("swapnet_model_up", {"model": "b"}, 0.0),
    ])
    lines = text.splitlines()
    assert lines.count("# TYPE swapnet_model_up gauge") == 1
    assert 'swapnet_model_up{model="a"} 1' in lines
    assert 'swapnet_model_up{model="b"} 0' in lines
