"""Per-stage swap timeline + the fused-path overlap regression tests.

PR 6's tentpole: every swap-in is logged as (stage, start, end) spans —
"read" / "unpack" / "dispatch" on the loader thread, "wait" / "exec" on the
executor — so a serialization point is attributable to the stage that
caused it. The regression these tests pin down: on the fused (quantized-
resident) path at prefetch depth m >= 2, the HOST READ of block i+1's
carrier bytes must genuinely overlap block i's compute, and the pipelined
pass must beat the serial (m=1) one.

Timing-sensitive assertions retry a few times before failing: on a noisy
shared CPU a single pass can schedule pathologically, but the overlap must
show up in SOME attempt if the pipeline works at all.
"""
import tempfile
import time

import jax
import numpy as np
import pytest

from benchmarks.bench_overhead import _evict_page_cache
from repro.core.cost_model import DelayModel, LayerInfo
from repro.core.partition import PartitionPlanner
from repro.core.runtime import SwappedSequential
from repro.core.swap_engine import BlockCache, MemoryLedger, SwapStats
from repro.models import vision
from repro.store import build_store

RETRIES = 3


# ------------------------------------------------------------ span algebra
def test_overlap_seconds_algebra():
    st = SwapStats()
    st.timeline = [("read", 0.0, 1.0), ("exec", 0.5, 2.0),
                   ("read", 3.0, 4.0), ("exec", 3.5, 3.75)]
    assert st.stage_seconds("read") == pytest.approx(2.0)
    assert st.overlap_seconds("read", "exec") == pytest.approx(0.75)
    assert st.overlap_seconds("read", "wait") == 0.0
    assert st.stage_spans("exec") == [(0.5, 2.0), (3.5, 3.75)]


def test_overlap_seconds_merges_overlapping_spans():
    st = SwapStats()
    # two loader spans that themselves overlap must not double-count
    st.timeline = [("read", 0.0, 2.0), ("read", 1.0, 3.0),
                   ("exec", 0.0, 3.0)]
    assert st.overlap_seconds("read", "exec") == pytest.approx(3.0)


# ------------------------------------------------------------ store stages
@pytest.mark.parametrize("backend,opts", [
    ("mmap", {}),
    ("rawio", {}),
    ("quant", {"bits": 8, "eager": True}),
    ("quant", {"bits": 4, "eager": False}),
    ("directio", {}),
])
def test_read_unit_emits_well_formed_stages(backend, opts):
    rng = np.random.default_rng(0)
    units = [("u0", {"w": rng.standard_normal((64, 128)).astype(np.float32),
                     "b": rng.standard_normal(128).astype(np.float32)})]
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend=backend, **opts)
        r = store.read_unit("u0")
    assert [s for s, _, _ in r.stages] == ["read", "unpack", "dispatch"]
    times = [t for _, s, e in r.stages for t in (s, e)]
    assert times == sorted(times)           # contiguous, monotone
    # the recorded io/asm split must agree with the spans
    assert r.io_s == pytest.approx(r.stages[0][2] - r.stages[0][1], abs=1e-9)


# ------------------------------------------------------------ pipeline
def _fc_stack(n=10, dim=512, seed=0):
    layers = [vision.Layer("fc", dim, dim) for _ in range(n)]
    params = vision.init_convnet(layers, jax.random.key(seed))
    return layers, params


def _run_fused(layers, params, workdir, m, batch=64, unit_delay_s=0.0):
    units = [(f"fc{i:02d}", p) for i, p in enumerate(params)]
    dim = layers[0].cin
    total = sum(np.asarray(x).nbytes
                for p in params for x in jax.tree.leaves(p))
    infos = [LayerInfo(f"fc{i:02d}",
                       sum(np.asarray(x).nbytes for x in jax.tree.leaves(p)),
                       len(jax.tree.leaves(p)), 2.0 * batch * dim * dim)
             for i, p in enumerate(params)]
    ledger = MemoryLedger(int(total))
    sw = SwappedSequential(
        units, lambda i, p, xx: vision.apply_layer(layers[i], p, xx),
        workdir, prefetch_depth=m, ledger=ledger,
        cache=BlockCache(0, ledger),
        store_backend="quant", precision="int4", fused=True)
    # plan with the store's own measured channel cost (the bench does the
    # same): mmap-profiled alpha under-costs fused swap-ins and the search
    # then under-pipelines exactly the path this file regression-tests
    sw.partition_with(infos, int(total * 0.5),
                      DelayModel().calibrated(sw.store))
    x = jax.random.normal(jax.random.key(1), (batch, dim))
    sw.forward(x)                           # warm: jit compiles
    if unit_delay_s:
        # inject deterministic per-unit storage latency (a sleep releases
        # the GIL exactly like a real I/O wait): the pipeline property —
        # hide swap-in waits behind compute — becomes assertable without
        # depending on the host disk, whose virtualized page cache makes
        # "cold" reads memcpy-fast and leaves nothing for depth m to hide
        orig = sw.store.read_unit
        sw.store.read_unit = lambda name: (time.sleep(unit_delay_s),
                                           orig(name))[1]
    # evict the unit files' page-cache pages so the timed pass also pays
    # whatever real storage I/O the host will give us (bench_overhead
    # measures cold the same way)
    _evict_page_cache(sw.store)
    sw.engine.stats.__init__()
    _, st = sw.forward(x)
    stats = sw.engine.stats
    sw.close()
    return st, stats


def test_fused_timeline_has_loader_and_executor_events():
    layers, params = _fc_stack()
    with tempfile.TemporaryDirectory() as d:
        _, stats = _run_fused(layers, params, d, m=2)
    stages = {ev[0] for ev in stats.timeline}
    assert {"read", "unpack", "dispatch", "wait", "exec"} <= stages
    # one read span per swapped-in unit, one exec span per block
    assert len(stats.stage_spans("read")) == len(layers)
    assert len(stats.stage_spans("exec")) > 1


def test_fused_host_read_overlaps_compute():
    """THE tentpole regression: at m=2 the host read of block i+1's carrier
    bytes runs inside block i's exec span — the old fused path deferred the
    read to page faults inside the device put and showed ~zero overlap."""
    layers, params = _fc_stack()
    for attempt in range(RETRIES):
        with tempfile.TemporaryDirectory() as d:
            _, stats = _run_fused(layers, params, d, m=2)
        hidden = stats.overlap_seconds("read", "exec")
        if hidden > 0.0:
            return
    pytest.fail(f"no read/exec overlap in {RETRIES} fused m=2 passes "
                f"(timeline: {sorted({e[0] for e in stats.timeline})})")


def test_fused_m2_latency_beats_m1():
    """Pipelining must pay on the fused path: with per-unit storage latency
    the depth-2 pass hides swap-in waits behind compute and beats the
    serial (m=1) pass by roughly the hidden compute time. The latency is
    INJECTED (5 ms per unit, a GIL-releasing sleep — exactly the shape of
    a real storage wait) so the assertion exercises the pipeline property
    this repo controls, not the benchmark host's disk: on a virtualized
    single-core runner, "cold" reads land in the hypervisor's page cache
    and degenerate to pure CPU memcpy, which a depth-m pipeline cannot
    hide — and the serial pass legitimately ties. min-of-3 per arm sheds
    scheduler noise on top."""
    layers, params = _fc_stack(dim=1024)

    def best(m):
        lat = []
        for _ in range(RETRIES):
            with tempfile.TemporaryDirectory() as d:
                st, _ = _run_fused(layers, params, d, m=m,
                                   unit_delay_s=0.005)
            lat.append(st["latency_s"])
        return min(lat)

    m1, m2 = best(1), best(2)
    assert m2 < m1, f"fused m2 ({m2*1e3:.1f} ms) not below m1 ({m1*1e3:.1f} ms)"


# ------------------------------------------------------------ planner search
def test_planner_deepens_pipeline_when_budget_is_slack():
    """With the whole model admitted by the budget (the fused-path regime),
    the paper's first-feasible rule returns n == m and leaves the cold first
    block — half the model — unhidable. The n-search must instead trade the
    exposed first block against kappa and pick a deeper plan."""
    infos = [LayerInfo(f"l{i}", int(1e8), 1, 6e9) for i in range(8)]
    dm = DelayModel(alpha=1.2e-9, beta=0.0, gamma=2e-11, eta=0.0)
    planner = PartitionPlanner(infos, dm, m=2)
    plan, _ = planner.best_partition(budget=int(1e10))   # admits everything
    assert plan.n_blocks > 2                 # paper's rule would stop at 2
    assert plan.m == 2


def test_planner_kappa_bounds_block_count():
    """A large per-block fixed cost must stop the n-search: with kappa
    dominating, finer plans only add overhead."""
    infos = [LayerInfo(f"l{i}", int(1e8), 1, 6e9) for i in range(8)]
    dm = DelayModel(alpha=1.2e-9, beta=0.0, gamma=2e-11, eta=0.0, kappa=0.5)
    planner = PartitionPlanner(infos, dm, m=2)
    plan, _ = planner.best_partition(budget=int(1e10))
    assert plan.n_blocks == 2
