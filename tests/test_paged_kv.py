"""Paged KV cache + paged attention kernel (serving tentpole).

The kernel property: attention gathered through an ARBITRARY page table must
match contiguous flash attention on the same context within fp tolerance —
paging is a memory layout, not a math change. The cache property: pages are
charged to the shared MemoryLedger and the ledger NEVER exceeds its budget,
no matter how concurrent admits/retires interleave.
"""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.swap_engine import MemoryLedger
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention
from repro.serving.paged_kv import (PagedBatchView, PagedKVCache,
                                    page_bytes_for)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


def _random_paged(rng_key, B, H, KV, hd, T, max_pages, dtype,
                  seq_lens):
    """Random q + page pools + a SHUFFLED page table covering seq_lens."""
    kq, kk, kv = jax.random.split(rng_key, 3)
    q = jax.random.normal(kq, (B, H, hd), dtype) * 0.5
    k_pages = jax.random.normal(kk, (max_pages + 1, T, KV, hd), dtype) * 0.5
    v_pages = jax.random.normal(kv, (max_pages + 1, T, KV, hd), dtype) * 0.5
    k_pages = k_pages.at[0].set(0)        # zero sentinel
    v_pages = v_pages.at[0].set(0)
    NP = max(-(-int(s) // T) for s in seq_lens)
    rng = np.random.default_rng(0)
    ids = rng.permutation(np.arange(1, max_pages + 1))
    pt = np.zeros((B, NP), np.int32)
    used = 0
    for b, s in enumerate(seq_lens):
        n = -(-int(s) // T)
        pt[b, :n] = ids[used:used + n]
        used += n
    assert used <= max_pages
    return q, k_pages, v_pages, jnp.asarray(pt), jnp.asarray(
        np.asarray(seq_lens, np.int32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [
    (None, None), (7, None), (None, 30.0), (5, 30.0)])
def test_paged_kernel_vs_ref(dtype, window, softcap):
    B, H, KV, hd, T = 3, 8, 2, 64, 8
    seq_lens = [5, 23, 16]
    q, kp, vp, pt, sl = _random_paged(jax.random.key(0), B, H, KV, hd, T,
                                      16, dtype, seq_lens)
    got = paged_attention(q, kp, vp, pt, sl, window=window, softcap=softcap,
                          interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, pt, sl, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("seq_len", [1, 8, 17, 40])
@pytest.mark.parametrize("window", [None, 6])
def test_paged_kernel_matches_contiguous_flash(seq_len, window):
    """The property the serving path stands on: scattering a context across
    shuffled pages changes NOTHING vs contiguous flash attention."""
    H, KV, hd, T = 4, 2, 64, 8
    G = H // KV
    q, kp, vp, pt, sl = _random_paged(jax.random.key(1), 1, H, KV, hd, T,
                                      8, jnp.float32, [seq_len])
    got = np.asarray(paged_attention(q, kp, vp, pt, sl, window=window,
                                     interpret=True))[0]          # [H, hd]
    # contiguous reference: gather the pages back into [S, KV, hd], expand
    # KV heads to H, run causal flash over the real context, take the last
    # row (the broadcast q rows cannot influence it under causal masking)
    S = int(sl[0])
    ctx_k = np.asarray(kp)[np.asarray(pt)[0]].reshape(-1, KV, hd)[:S]
    ctx_v = np.asarray(vp)[np.asarray(pt)[0]].reshape(-1, KV, hd)[:S]
    for h in range(H):
        qh = jnp.broadcast_to(q[0, h][None, None, :], (1, S, hd))
        kh = jnp.asarray(ctx_k[:, h // G][None])
        vh = jnp.asarray(ctx_v[:, h // G][None])
        want = ref.flash_attention_ref(qh, kh, vh, causal=True,
                                       window=window)[0, -1]
        np.testing.assert_allclose(got[h], np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- cache
def _cfg():
    return dataclasses.replace(ARCHS["qwen2.5-3b"].reduced(),
                               dtype="float32")


def test_page_accounting_delta_semantics():
    cfg = _cfg()
    pb = page_bytes_for(cfg, 4)
    assert pb == 2 * cfg.n_layers * 4 * cfg.n_kv_heads \
        * cfg.resolved_head_dim * 4
    led = MemoryLedger(budget=10 * pb)
    kv = PagedKVCache(cfg, led, page_tokens=4, max_pages=16)
    assert kv.alloc("a", 6)                 # 2 pages
    assert led.resident == 2 * pb
    assert kv.extend("a", 1)                # 7 tokens: still 2 pages
    assert led.resident == 2 * pb
    assert kv.extend("a", 2)                # 9 tokens: 3rd page, delta-charge
    assert led.resident == 3 * pb
    assert kv.alloc("b", 20)                # 5 pages
    assert led.resident == 8 * pb
    assert not kv.alloc("c", 12)            # 3 pages > 2 left in budget
    assert led.resident == 8 * pb           # rejection left no residue
    kv.free("a")
    assert led.resident == 5 * pb
    assert kv.alloc("c", 12)
    kv.free("b"), kv.free("c")
    assert led.resident == 0 and kv.pages_in_use == 0
    assert len(kv._free) == 16


def test_pool_exhaustion_independent_of_ledger():
    cfg = _cfg()
    led = MemoryLedger(budget=None)         # unlimited ledger
    kv = PagedKVCache(cfg, led, page_tokens=4, max_pages=3)
    assert kv.alloc("a", 12)                # all 3 pages
    assert not kv.alloc("b", 1)             # pool, not ledger, says no
    assert not kv.extend("a", 1)
    kv.free("a")
    assert kv.alloc("b", 1)


def test_write_page_table_roundtrip_and_sentinel():
    cfg = _cfg()
    kv = PagedKVCache(cfg, MemoryLedger(None), page_tokens=4, max_pages=8)
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(0)
    kv.alloc("a", 6)
    k = rng.standard_normal((6, KV, hd)).astype(np.float32)
    v = rng.standard_normal((6, KV, hd)).astype(np.float32)
    kv.write("a", 0, 0, k, v)               # spans a page boundary
    pt, sl = kv.page_table(["a"])
    assert sl.tolist() == [6] and pt.shape == (1, 2)
    gathered = kv.k_pools[0][pt[0]].reshape(-1, KV, hd)[:6]
    np.testing.assert_array_equal(gathered, k)
    # sentinel page 0 is never handed out and never written
    assert 0 not in pt[0]
    assert not kv.k_pools[0][0].any()
    # a second, longer sequence pads the FIRST one's table row with 0s
    kv.alloc("b", 16)
    pt2, _ = kv.page_table(["a", "b"])
    assert pt2.shape == (2, 4)
    assert (pt2[0, 2:] == 0).all()


def test_rejects_non_uniform_attention():
    mla = dataclasses.replace(ARCHS["deepseek-v2-lite-16b"].reduced(),
                              dtype="float32")
    with pytest.raises(ValueError):
        PagedKVCache(mla, MemoryLedger(None))
    ssm = dataclasses.replace(ARCHS["rwkv6-3b"].reduced(), dtype="float32")
    with pytest.raises(ValueError):
        PagedKVCache(ssm, MemoryLedger(None))


def test_for_budget_sizing():
    cfg = _cfg()
    pb = page_bytes_for(cfg, 8)
    kv = PagedKVCache.for_budget(cfg, MemoryLedger(None), 10 * pb + 5,
                                 page_tokens=8)
    assert kv.max_pages == 10


def test_ledger_never_exceeds_budget_concurrent():
    """Adversarial: admit/extend/retire hammered from several threads while
    a weight-block tenant charges the same ledger. The ledger's peak must
    stay under budget and the final state must be clean."""
    cfg = _cfg()
    pb = page_bytes_for(cfg, 4)
    budget = 12 * pb
    led = MemoryLedger(budget=budget)
    led.add("weights", 4 * pb)              # a co-resident weight block
    kv = PagedKVCache(cfg, led, page_tokens=4, max_pages=64)
    stop = threading.Event()
    errors = []

    def worker(tid):
        rng = np.random.default_rng(tid)
        try:
            for it in range(60):
                sid = (tid, it)
                if not kv.alloc(sid, int(rng.integers(1, 12))):
                    continue
                for _ in range(int(rng.integers(0, 6))):
                    if not kv.extend(sid, 1):
                        break
                kv.free(sid)
        except BaseException as e:          # noqa: BLE001
            errors.append(e)
            stop.set()

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert led.peak <= budget
    assert kv.pages_in_use == 0
    assert led.resident == 4 * pb           # only the weight block remains
    assert sorted(kv._free) == list(range(1, 65))


def test_batch_view_write_position():
    """PagedBatchView writes each sequence's new K/V at seq_len-1 and
    attends over exactly the live context."""
    cfg = _cfg()
    kv = PagedKVCache(cfg, MemoryLedger(None), page_tokens=4, max_pages=8)
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    H = cfg.n_heads
    rng = np.random.default_rng(3)
    kv.alloc("a", 5)
    k0 = rng.standard_normal((5, KV, hd)).astype(np.float32)
    v0 = rng.standard_normal((5, KV, hd)).astype(np.float32)
    kv.write("a", 0, 0, k0, v0)
    assert kv.extend("a", 1)
    view = PagedBatchView(kv, ["a"])
    q = jnp.asarray(rng.standard_normal((1, H, hd)).astype(np.float32))
    kn = rng.standard_normal((1, KV, hd)).astype(np.float32)
    vn = rng.standard_normal((1, KV, hd)).astype(np.float32)
    out = view.attend(0, q, jnp.asarray(kn), jnp.asarray(vn))
    # the new row landed at position 5
    pt, sl = kv.page_table(["a"])
    assert sl.tolist() == [6]
    np.testing.assert_array_equal(
        kv.k_pools[0][pt[0]].reshape(-1, KV, hd)[5], kn[0])
    # and the output equals the oracle over the 6-token context
    want = ref.paged_attention_ref(
        q, jnp.asarray(kv.k_pools[0]), jnp.asarray(kv.v_pools[0]),
        jnp.asarray(pt), jnp.asarray(sl))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
