"""DirectIOStore: O_DIRECT swap-in — alignment, arena reuse, bit identity.

The backend's correctness surface is narrow but sharp: O_DIRECT silently
returns EINVAL (or short reads) when any of buffer address / file offset /
byte count is unaligned, and a pooled read buffer that rotates too early
corrupts a unit already handed to the device. These tests pin all of it
down — including the buffered-pread fallback path, which must be
byte-for-byte the same store, just slower.
"""
import os
import tempfile

import numpy as np
import pytest

from repro.store import DirectIOStore, build_store
from repro.store.directio_store import ALIGNMENT, AlignedArena, _align_up


def _units(seed=0, n=4, shape=(64, 128)):
    rng = np.random.default_rng(seed)
    return [(f"u{i:02d}", {"w": rng.standard_normal(shape).astype(np.float32),
                           "g": rng.standard_normal(shape[0]).astype(np.float32)})
            for i in range(n)]


# ------------------------------------------------------------ aligned arena
def test_arena_buffers_are_aligned():
    arena = AlignedArena(depth=3)
    for nbytes in (1, ALIGNMENT - 1, ALIGNMENT, 3 * ALIGNMENT + 17):
        buf = arena.take(nbytes)
        assert buf.ctypes.data % ALIGNMENT == 0
        assert buf.nbytes == _align_up(max(nbytes, 1))


def test_arena_reuses_buffers_in_steady_state():
    arena = AlignedArena(depth=2)
    for _ in range(10):
        arena.take(2 * ALIGNMENT)
    # depth buffers allocated once, then reused round-robin
    assert arena.allocations == 2


def test_arena_rotation_preserves_previous_reads():
    """A buffer must survive ``depth - 1`` subsequent takes untouched —
    the window in which its device put is still draining."""
    arena = AlignedArena(depth=3)
    a = arena.take(ALIGNMENT)
    a[:] = 1
    b = arena.take(ALIGNMENT)
    b[:] = 2
    c = arena.take(ALIGNMENT)
    c[:] = 3
    assert a[0] == 1 and b[0] == 2      # still intact two takes later
    d = arena.take(ALIGNMENT)           # wraps: aliases a
    d[:] = 4
    assert a[0] == 4


def test_arena_grows_for_larger_units():
    arena = AlignedArena(depth=2)
    small = arena.take(ALIGNMENT).nbytes
    big = arena.take(8 * ALIGNMENT).nbytes
    assert big > small
    assert arena.take(8 * ALIGNMENT).nbytes >= small  # slot 0 regrown or fresh


# ------------------------------------------------------------ store reads
def test_directio_bit_identical_to_source():
    units = _units()
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend="directio")
        assert store.direct_io is not None      # probe ran at open()
        for name, params in units:
            r = store.read_unit(name)
            for k in params:
                np.testing.assert_array_equal(np.asarray(r.params[k]),
                                              params[k])
            # aligned I/O, logical residency
            assert r.io_bytes == _align_up(store.nbytes(name))
            assert r.io_bytes % ALIGNMENT == 0
            assert r.ledger_bytes == store.nbytes(name)
            assert len(r.stages) == 3
            assert [s for s, _, _ in r.stages] == ["read", "unpack",
                                                   "dispatch"]


def test_directio_files_padded_to_alignment():
    units = _units(n=2)
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend="directio")
        for name, _ in units:
            size = os.path.getsize(store._path(name))
            assert size % ALIGNMENT == 0
            assert size == store.stored_nbytes(name)


def test_directio_matches_mmap_backend():
    """Same units through directio and mmap must produce identical trees:
    the backend changes the I/O path, never the bytes."""
    units = _units(seed=3)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        dio = build_store(units, d1, backend="directio")
        mm = build_store(units, d2, backend="mmap")
        for name, _ in units:
            a = dio.read_unit(name).params
            b = mm.read_unit(name).params
            for k in a:
                np.testing.assert_array_equal(np.asarray(a[k]),
                                              np.asarray(b[k]))


@pytest.mark.parametrize("queue_depth", [1, 4])
def test_directio_queue_depths_agree(queue_depth):
    """queue_depth>1 splits a unit into concurrent aligned extents; the
    reassembled bytes must equal the single-pread read."""
    # one unit big enough to actually split (>= queue_depth aligned chunks)
    rng = np.random.default_rng(7)
    units = [("big", {"w": rng.standard_normal((256, 512))
                      .astype(np.float32)})]
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend="directio",
                            queue_depth=queue_depth)
        r = store.read_unit("big")
        np.testing.assert_array_equal(np.asarray(r.params["w"]),
                                      units[0][1]["w"])


def test_directio_buffered_fallback_bit_identical():
    """Filesystems without O_DIRECT fall back to buffered preads into the
    same arena — forced here, the read must stay bit-identical."""
    units = _units(seed=5)
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend="directio")
        store.direct_io = False                 # force the fallback path
        for name, params in units:
            r = store.read_unit(name)
            for k in params:
                np.testing.assert_array_equal(np.asarray(r.params[k]),
                                              params[k])


def test_directio_steady_state_allocations_bounded():
    """Repeat swap-ins must not allocate per read (the arena is the point)."""
    units = _units(n=2)
    with tempfile.TemporaryDirectory() as d:
        store = build_store(units, d, backend="directio", arena_depth=2)
        for _ in range(3):                      # warm the two arena slots
            for name, _ in units:
                store.read_unit(name)
        allocs = store.arena.allocations
        for _ in range(5):
            for name, _ in units:
                store.read_unit(name)
        assert store.arena.allocations == allocs
