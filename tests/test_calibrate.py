"""Calibration pass + precision policy (ISSUE 10): plan determinism,
fidelity-target monotonicity, fp bit-identity through a mixed store, the
exact stored-bytes model, the solver's greedy ladder, artifact versioning,
and the runtime surface (mixed guards, SwapStats.bytes_by_precision).
"""
import json
import os
import sys
import tempfile

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.calibrate import (PRECISION_LADDER, PrecisionPlan,
                             SensitivityProfile, assign_precisions,
                             calibrate_model, calibration_batch,
                             quantize_roundtrip, unit_precision_bytes)
from repro.calibrate.profiler import shape_signature
from repro.configs import get_arch
from repro.launch.train import scale_config
from repro.models.transformer import Model
from repro.store.quantized_store import (QuantizedStore, quantizable,
                                         unit_stored_nbytes)

RANK = {p: i for i, p in enumerate(PRECISION_LADDER)}  # int4 < int8 < fp


def _profile(units=None, fidelity_target=None) -> SensitivityProfile:
    """Synthetic 4-unit profile: one int4-robust, two mid, one fragile."""
    units = units or {
        "u0": dict(bytes_fp=4000, bytes_int8=1000, bytes_int4=500,
                   err_int8=0.0, err_int4=0.001),
        "u1": dict(bytes_fp=4000, bytes_int8=1000, bytes_int4=500,
                   err_int8=0.004, err_int4=0.05),
        "u2": dict(bytes_fp=4000, bytes_int8=1000, bytes_int4=500,
                   err_int8=0.004, err_int4=0.06),
        "u3": dict(bytes_fp=4000, bytes_int8=1000, bytes_int4=500,
                   err_int8=0.02, err_int4=0.30),
    }
    return SensitivityProfile(arch="synthetic", method="output", seed=0,
                              signature="s" * 16, units=units)


def _small_model(seed=0):
    mcfg = scale_config(get_arch("qwen2.5-3b"), "smoke")
    model = Model(mcfg)
    return model, model.init(jax.random.key(seed))


# ------------------------------------------------------------------- policy
def test_policy_greedy_ladder():
    """Robust units stay int4, fragile ones climb; predicted error is the
    RSS of the chosen levels and stays under the headroomed target."""
    prof = _profile()
    plan = assign_precisions(prof, fidelity=0.02)
    assert plan.assignments["u0"] == "int4"       # free int4
    assert plan.assignments["u3"] != "int4"       # 0.30 alone busts 0.02
    rss = sum(prof.units[u][f"err_{p}"] ** 2 if p != "fp" else 0.0
              for u, p in plan.assignments.items()) ** 0.5
    assert rss == pytest.approx(plan.predicted_err)
    assert plan.predicted_err <= 0.02 * 0.7 + 1e-12
    assert plan.stored_bytes == sum(
        prof.units[u][f"bytes_{p}"] for u, p in plan.assignments.items())


def test_policy_fidelity_monotonicity():
    """Tightening the target never DEMOTES any unit: the greedy upgrade
    trajectory is target-independent, a tighter target only walks it
    further. (The satellite's determinism contract, policy half.)"""
    prof = _profile()
    targets = [0.5, 0.2, 0.1, 0.05, 0.02, 0.01, 0.005, 0.001]
    prev = None
    for t in targets:
        plan = assign_precisions(prof, fidelity=t)
        if prev is not None:
            for u in plan.assignments:
                assert RANK[plan.assignments[u]] >= RANK[prev[u]], \
                    f"{u} demoted at fidelity {t}"
        prev = plan.assignments


def test_policy_infinite_target_is_all_int4():
    plan = assign_precisions(_profile(), fidelity=float("inf"))
    assert set(plan.assignments.values()) == {"int4"}


def test_policy_unquantizable_unit_forced_fp():
    """A unit with nothing quantizable (bytes_int4 >= bytes_fp) must be
    assigned fp — quantizing it buys no bytes, only risk."""
    units = {
        "raw": dict(bytes_fp=256, bytes_int8=256, bytes_int4=256,
                    err_int8=0.0, err_int4=0.0),
        "w": dict(bytes_fp=4000, bytes_int8=1000, bytes_int4=500,
                  err_int8=0.001, err_int4=0.01),
    }
    plan = assign_precisions(_profile(units), fidelity=1.0)
    assert plan.assignments["raw"] == "fp"
    assert plan.assignments["w"] == "int4"


def test_plan_json_roundtrip_and_version_gate(tmp_path):
    plan = assign_precisions(_profile(), fidelity=0.02)
    p = tmp_path / "plan.json"
    plan.save(str(p))
    back = PrecisionPlan.load(str(p))
    assert back.to_json() == plan.to_json()
    assert back.bits_map() == plan.bits_map()
    doctored = json.loads(plan.to_json())
    doctored["version"] = 99
    with pytest.raises(ValueError, match="version"):
        PrecisionPlan.from_json(json.dumps(doctored))


def test_profile_json_roundtrip_and_version_gate():
    prof = _profile()
    back = SensitivityProfile.from_json(prof.to_json())
    assert back.to_json() == prof.to_json()
    doctored = json.loads(prof.to_json())
    doctored["version"] = 99
    with pytest.raises(ValueError, match="version"):
        SensitivityProfile.from_json(json.dumps(doctored))


# ----------------------------------------------------- stored-bytes model
def test_unit_stored_nbytes_matches_store_exactly():
    """The policy packs against unit_stored_nbytes — it must equal the
    ACTUAL on-disk unit size for every precision, or the plan's byte
    arithmetic drifts from what the planner/ledger will see."""
    rng = np.random.default_rng(0)
    params = {"w": rng.standard_normal((96, 64)).astype(np.float32),
              "b": rng.standard_normal((64,)).astype(np.float32)}
    for bits in (0, 8, 4):
        with tempfile.TemporaryDirectory() as d:
            store = QuantizedStore.build(
                [("u", params)], d,
                plan={"u": bits} if bits else {"u": 0})
            actual = os.path.getsize(store._path("u"))
            assert unit_stored_nbytes(params, bits, 1024) == actual



def test_quantize_roundtrip_matches_store_numerics():
    """Host round-trip == what reading the quant store materializes, so
    measured sensitivity is the realized sensitivity."""
    rng = np.random.default_rng(1)
    params = {"w": rng.standard_normal((64, 48)).astype(np.float32)}
    for bits in (8, 4):
        with tempfile.TemporaryDirectory() as d:
            store = QuantizedStore.build([("u", params)], d, bits=bits)
            got = np.asarray(store.read_unit("u").params["w"])

        np.testing.assert_array_equal(got, quantize_roundtrip(params["w"],
                                                              bits))


# --------------------------------------------------------- model-level pass
def test_calibrate_model_deterministic_byte_identical():
    """Same arch + seed + batch => byte-identical PrecisionPlan AND
    SensitivityProfile artifacts (the satellite's determinism contract)."""
    model, params = _small_model()
    batch = calibration_batch(model.cfg, seed=0)
    prof1, plan1 = calibrate_model(model, params, fidelity=2e-2, batch=batch)
    prof2, plan2 = calibrate_model(model, params, fidelity=2e-2, batch=batch)
    assert prof1.to_json() == prof2.to_json()
    assert plan1.to_json() == plan2.to_json()


def test_calibrate_model_signature_pins_geometry():
    model, params = _small_model()
    prof, _ = calibrate_model(model, params, fidelity=1e-1, method="weight")
    seen, named = set(), []
    from repro.core.runtime import SwappedModel
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, store_backend="mmap")
        for u in sm.units:
            if u.name not in seen:
                seen.add(u.name)
                named.append((u.name, u.params))
        sm.close()
    assert prof.signature == shape_signature(named)


def test_mixed_store_fp_units_bit_identical():
    """Units the plan assigns fp must round-trip BIT-IDENTICALLY through a
    mixed store — fp assignment is a no-quantization promise, not a 'less
    lossy' one. Quantized units must NOT be bit-identical (they really
    were quantized)."""
    rng = np.random.default_rng(2)
    units = [(f"u{i}", {"w": rng.standard_normal((96, 64))
                        .astype(np.float32)}) for i in range(3)]
    plan = {"u0": 0, "u1": 8, "u2": 4}
    with tempfile.TemporaryDirectory() as d:
        store = QuantizedStore.build(units, d, plan=plan)
        got = {n: np.asarray(store.read_unit(n).params["w"])
               for n, _ in units}

    ref = dict(units)
    np.testing.assert_array_equal(got["u0"], ref["u0"]["w"])
    assert not np.array_equal(got["u1"], ref["u1"]["w"])
    assert not np.array_equal(got["u2"], ref["u2"]["w"])
    np.testing.assert_array_equal(got["u1"],
                                  quantize_roundtrip(ref["u1"]["w"], 8))
    np.testing.assert_array_equal(got["u2"],
                                  quantize_roundtrip(ref["u2"]["w"], 4))


def test_mixed_store_precision_byte_split():
    """UnitRead.precision_bytes buckets the stored segments by the bits
    that produced them and sums to the full stored size."""
    rng = np.random.default_rng(3)
    units = [("a", {"w": rng.standard_normal((96, 64)).astype(np.float32)}),
             ("b", {"w": rng.standard_normal((96, 64)).astype(np.float32)})]
    with tempfile.TemporaryDirectory() as d:
        store = QuantizedStore.build(units, d, plan={"a": 4, "b": 0})
        ra, rb = store.read_unit("a"), store.read_unit("b")
        assert set(ra.precision_bytes) == {"int4"}
        assert set(rb.precision_bytes) == {"fp"}
        assert sum(ra.precision_bytes.values()) == \
            os.path.getsize(store._path("a"))



def test_unplanned_unit_stored_raw():
    """A unit the plan omits is stored RAW (bits=0): calibration that
    never saw a unit must not silently quantize it."""
    rng = np.random.default_rng(4)
    w = rng.standard_normal((96, 64)).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        store = QuantizedStore.build([("u", {"w": w})], d, plan={})
        got = np.asarray(store.read_unit("u").params["w"])

    np.testing.assert_array_equal(got, w)


# ----------------------------------------------------------- runtime surface
def test_swapped_model_mixed_requires_plan():
    from repro.core.runtime import SwappedModel
    model, params = _small_model()
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="plan"):
            SwappedModel(model, params, d, store_backend="quant",
                         precision="mixed")


def test_swapped_model_mixed_end_to_end_stats():
    """calibrate -> mixed store -> forward: per-precision byte split shows
    up in stats and bytes_swapped lands at/below the int8-uniform point."""
    from repro.core.runtime import SwappedModel
    model, params = _small_model()
    if not model.cfg.quant_eligible:
        pytest.skip("smoke arch not quant-eligible")
    _, plan = calibrate_model(model, params, fidelity=5e-2)
    with tempfile.TemporaryDirectory() as d:
        sm = SwappedModel(model, params, d, store_backend="quant",
                          precision="mixed", store_options={"plan": plan})
        sm.set_plan(tuple(range(1, len(sm.units))))
        batch = calibration_batch(model.cfg, seed=0)
        _, st = sm.forward(batch)
        sm.close()
    bp = st["bytes_by_precision"]
    assert bp and sum(bp.values()) == st["bytes_swapped"]
    hist = plan.histogram()
    for prec, n in hist.items():
        if n and prec != "fp":
            assert bp.get(prec, 0) > 0


def test_config_mixed_validation():
    from repro.config import ServeConfig
    from repro.errors import ConfigError
    cfg = ServeConfig.from_dict({
        "arch": "qwen2.5-3b",
        "runtime": {"store": "quant", "precision": "mixed",
                    "fidelity": 1e-2}})
    cfg.validate()
    with pytest.raises(ConfigError, match="fidelity"):
        ServeConfig.from_dict({
            "arch": "qwen2.5-3b",
            "runtime": {"store": "quant",
                        "precision": "mixed"}}).validate()
    with pytest.raises(ConfigError, match="quant"):
        ServeConfig.from_dict({
            "arch": "qwen2.5-3b",
            "runtime": {"store": "mmap", "precision": "mixed",
                        "fidelity": 1e-2}}).validate()


def test_quantizable_predicate():
    assert quantizable(np.zeros((64, 64), np.float32), 1024)
    assert not quantizable(np.zeros((64,), np.float32), 1024)    # 1-D
    assert not quantizable(np.zeros((8, 8), np.float32), 1024)   # too small
    assert not quantizable(np.zeros((64, 64), np.int32), 1024)   # not float
    b = unit_precision_bytes({"w": np.zeros((64, 64), np.float32)}, 1024)
    assert b["int4"] < b["int8"] < b["fp"]
