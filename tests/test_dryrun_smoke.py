"""Dry-run smoke: the launch path works in a subprocess (512 host devices).
One real combination end-to-end; skip rules honored. Marked slow-ish but
bounded (decode lowering compiles in seconds)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args, timeout=540):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("JAX_PLATFORMS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=timeout)


def test_dryrun_decode_compiles(tmp_path):
    r = _run(["--arch", "qwen2.5-3b", "--shape", "decode_32k",
              "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    f = tmp_path / "qwen2.5-3b__decode_32k__16x16.json"
    data = json.loads(f.read_text())
    assert data["status"] == "ok"
    assert data["cost_analysis"]["flops"] > 0
    assert data["memory_analysis"]["temp_size_in_bytes"] > 0
    assert sum(v["count"] for v in data["collectives"].values()) > 0


def test_dryrun_respects_skip_rules():
    """Skip rules (DESIGN.md §5): encoder-only has no decode; pure
    full-attention archs have no long_500k; SWA/SSM/hybrid do."""
    from repro.configs import ARCHS, SHAPES, applicable
    assert not applicable(ARCHS["hubert-xlarge"], SHAPES["decode_32k"])
    assert not applicable(ARCHS["granite-20b"], SHAPES["long_500k"])
    assert applicable(ARCHS["h2o-danube-3-4b"], SHAPES["long_500k"])
    assert applicable(ARCHS["zamba2-7b"], SHAPES["long_500k"])


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %all-reduce.1 = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[2,1024]{1,0} all-gather(%y), dimensions={1}
  ROOT %r = (f32[4]{0}, f32[4]{0}) all-to-all(%a, %b)
  %notacoll = f32[8]{0} add(%c, %d)
"""
    got = parse_collectives(hlo)
    assert got["all-reduce"]["count"] == 1
    assert got["all-reduce"]["bytes"] == 16 * 128 * 4
    assert got["all-gather"]["bytes"] == 2 * 1024 * 2
    assert got["all-to-all"]["count"] == 1
    assert got["all-to-all"]["bytes"] == 32
