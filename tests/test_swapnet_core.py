"""SwapNet core: unit + property tests (hypothesis) for the system invariants."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # hypothesis is a dev extra: property tests skip, unit tests still run
    # (one missing dep must not fail collection of the whole module).
    def settings(**_kw):
        return lambda f: f

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    class _StrategyStub:
        def composite(self, f):
            return lambda *a, **k: None

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core.budget import ModelDemand, allocate_budgets
from repro.core.cost_model import DelayModel, LayerInfo
from repro.core.partition import (BlockPlan, PartitionPlanner,
                                  create_blocks, get_layers,
                                  n_blocks_for_budget, simulate_pipeline)
from repro.core.skeleton import assemble, assemble_dummy, assemble_np, flatten_params


# ------------------------------------------------------------------ skeleton
@st.composite
def param_trees(draw):
    n = draw(st.integers(1, 6))
    tree = {}
    for i in range(n):
        shape = tuple(draw(st.lists(st.integers(1, 7), min_size=1, max_size=3)))
        dt = draw(st.sampled_from(["float32", "bfloat16", "int32"]))
        seed = draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        if dt == "int32":
            arr = rng.integers(-100, 100, shape).astype(np.int32)
        else:
            arr = rng.normal(0, 1, shape).astype(jnp.dtype(dt).type)
        tree[f"p{i}"] = arr if i % 2 == 0 else {"nested": arr}
    return tree


@settings(max_examples=30, deadline=None)
@given(param_trees())
def test_skeleton_roundtrip(tree):
    """flatten -> assemble (all three modes) reproduces the tree exactly."""
    buf, skel = flatten_params(tree)
    assert skel.depth == len(jax.tree.leaves(tree))
    for rebuilt in (assemble_np(skel, buf), assemble_dummy(skel, buf),
                    jax.jit(lambda b: assemble(skel, b))(jnp.asarray(buf))):
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(rebuilt)):
            np.testing.assert_array_equal(np.asarray(a, np.float32) if a.dtype
                                          == jnp.bfloat16 else np.asarray(a),
                                          np.asarray(b, np.float32) if b.dtype
                                          == jnp.bfloat16 else np.asarray(b))


def test_skeleton_is_small():
    tree = {"w": np.zeros((1000, 1000), np.float32)}
    buf, skel = flatten_params(tree)
    assert skel.meta_bytes() < 1024          # paper: skeletons are KBs
    assert buf.nbytes >= 4_000_000


# ------------------------------------------------------------------ partition
@st.composite
def layer_sets(draw):
    L = draw(st.integers(3, 40))
    sizes = [draw(st.integers(1_000, 5_000_000)) for _ in range(L)]
    return [LayerInfo(f"l{i}", s, draw(st.integers(1, 12)),
                      draw(st.integers(10_000, 10**9)))
            for i, s in enumerate(sizes)]


@settings(max_examples=25, deadline=None)
@given(layer_sets(), st.floats(0.2, 0.8))
def test_partition_invariants(infos, frac):
    dm = DelayModel()
    planner = PartitionPlanner(infos, dm)
    total = float(np.sum(planner.sizes))
    budget = max(total * frac, 2 * float(np.max(planner.sizes)) / 0.95 + 1)
    plan, table = planner.best_partition(budget)
    # blocks cover every layer exactly once, in order
    blocks = plan.blocks()
    assert blocks[0][0] == 0 and blocks[-1][1] == len(infos)
    for (a, b), (c, d) in zip(blocks, blocks[1:]):
        assert b == c and a < b
    # Eq. 3: any two adjacent blocks (m=2 resident) fit the budget
    s, d, f = create_blocks(plan, planner.sizes, planner.depths, planner.flops)
    if len(s) > 1:
        assert max(s[i] + s[i + 1] for i in range(len(s) - 1)) \
            <= budget * 0.95 + 1e-6
    # conservation
    assert abs(float(np.sum(s)) - total) < 1e-6
    # latency bounds: >= pure execution, <= fully serial
    t = simulate_pipeline(s, d, f, dm)
    t_ex = sum(dm.t_ex(x) for x in f)
    t_serial = sum(dm.t_in(s[i], d[i]) + dm.t_ex(f[i]) + dm.t_out(d[i])
                   for i in range(len(s)))
    assert t >= t_ex - 1e-9
    assert t <= t_serial + 1e-6


def test_n_blocks_rule():
    # paper: n = ceil(m*s/b)
    assert n_blocks_for_budget(100, 50, m=2) == 4
    assert n_blocks_for_budget(100, 210, m=2) == 2   # floor at m


def test_pipeline_overlap_beats_serial():
    """Double buffering must hide swap-in latency behind execution."""
    # kappa=0: this test checks the pipeline algebra with exact 1s/2s stages
    dm = DelayModel(alpha=1e-9, beta=0, gamma=1e-10, eta=0, kappa=0)
    s = np.array([1e9, 1e9, 1e9, 1e9])      # 1s swap-in each
    d = np.zeros(4)
    f = np.array([2e10] * 4)                 # 2s execution each
    t = simulate_pipeline(s, d, f, dm, m=2)
    # serial would be 4*(1+2)=12s; pipelined: 1 + 4*2 = 9s
    assert t == pytest.approx(9.0, rel=1e-6)


# ------------------------------------------------------------------ budget
@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.floats(1e6, 1e9), st.floats(0.01, 10.0),
                          st.floats(0.1, 5.0)), min_size=1, max_size=8),
       st.floats(1e6, 2e9))
def test_budget_allocation_eq1(items, available):
    demands = [ModelDemand(f"m{i}", mem, lat, u)
               for i, (mem, lat, u) in enumerate(items)]
    out = allocate_budgets(demands, available)
    total = sum(d.memory for d in demands)
    if total <= available:
        assert out == [d.memory for d in demands]
    else:
        assert sum(out) == pytest.approx(available, rel=1e-6)
        assert all(a > 0 for a in out)


def test_budget_ps_calibration():
    """Higher PS (urgent, slow, small) models get proportionally more than
    their pure size share (the paper's 1/n reserved calibration)."""
    a = ModelDemand("fast_big", 1e9, latency=0.1, urgency=1.0)
    b = ModelDemand("slow_small", 1e8, latency=1.0, urgency=1.0)
    out = allocate_budgets([a, b], 5e8)
    share_b = out[1] / 5e8
    assert share_b > (1e8 / 1.1e9) * 0.5    # strictly above pure-size share


# ------------------------------------------------------------------ cost model
def test_delay_model_fit_recovers_coefficients():
    true = DelayModel(alpha=2e-9, beta=5e-5, gamma=3e-11, eta=1e-5)
    rng = np.random.default_rng(0)
    s_in = [(s, d, true.t_in(s, d) * rng.normal(1, 0.01))
            for s, d in zip(rng.uniform(1e6, 1e8, 40), rng.integers(1, 50, 40))]
    s_ex = [(f, true.t_ex(f) * rng.normal(1, 0.01))
            for f in rng.uniform(1e8, 1e11, 40)]
    s_out = [(d, true.t_out(d) * rng.normal(1, 0.01))
             for d in rng.integers(1, 50, 40)]
    fit = DelayModel.fit(s_in, s_ex, s_out)
    assert fit.alpha == pytest.approx(true.alpha, rel=0.05)
    assert fit.beta == pytest.approx(true.beta, rel=0.05)
    assert fit.gamma == pytest.approx(true.gamma, rel=0.05)
    assert fit.eta == pytest.approx(true.eta, rel=0.05)
    assert fit.kappa == pytest.approx(true.kappa, rel=0.25)  # intercept: noisier
    assert fit.r2_in(s_in) > 0.99
