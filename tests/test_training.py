"""Training substrate: optimizer, schedule, loss-decrease integration,
checkpoint roundtrip."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.pipeline import SyntheticLM
from repro.models.transformer import Model
from repro.training import checkpoint
from repro.training.optimizer import OptConfig, adamw_init, adamw_update, lr_at
from repro.training.train_loop import TrainState, make_train_step


def test_lr_schedule():
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    assert float(lr_at(jnp.asarray(0), cfg)) == 0.0
    assert float(lr_at(jnp.asarray(10), cfg)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr_at(jnp.asarray(100), cfg)) == pytest.approx(1e-4, rel=1e-2)


def test_adamw_moves_against_gradient():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.ones((4, 4))}
    mu, nu = adamw_init(params)
    p, mu, nu, m = adamw_update(params, grads, mu, nu,
                                jnp.asarray(200, jnp.int32),
                                OptConfig(warmup_steps=0))
    assert float(jnp.mean(p["w"])) < 1.0
    assert float(m["grad_norm"]) == pytest.approx(4.0, rel=1e-5)


def test_train_loss_decreases():
    """Integration: a few dozen steps on the learnable synthetic stream must
    cut the loss substantially (the affine pattern is easy)."""
    cfg = ARCHS["qwen2.5-3b"].reduced()
    model = Model(cfg)
    state = TrainState(model.init(jax.random.key(0)))
    opt = OptConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    ds = SyntheticLM(cfg, seq_len=64, batch=8, seed=0)
    losses = []
    for i, batch in zip(range(60), ds):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])
    assert not any(np.isnan(l) for l in losses)


def test_checkpoint_roundtrip():
    cfg = ARCHS["gemma2-9b"].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(3))
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, params)
        like = jax.tree.map(lambda a: jnp.zeros_like(a), params)
        back = checkpoint.restore(d, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_rejected():
    params = {"w": jnp.ones((4, 4))}
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, params)
        with pytest.raises(AssertionError):
            checkpoint.restore(d, {"w": jnp.ones((5, 4))})
