"""Back-compat golden snapshot (ISSUE 9 satellite): every pre-profile
``repro.launch.serve`` invocation must resolve to the SAME effective
config — and route to the same serving path — after the layered-config
refactor as before it.

``tests/golden/serve_configs.json`` freezes, for a matrix of real legacy
flag combinations, the fully-resolved ``ServeConfig`` dict plus the
dispatch mode. The resolution here is hermetic (``env={}``), so a
developer's ``SWAPNET_*`` variables can't leak into the assertion.

Regenerate (ONLY after an intentional semantic change, with the diff
reviewed):

    PYTHONPATH=src:tests python -c \
        "import test_serve_backcompat as t; t.regenerate()"
"""
import json
import os

import pytest

from repro.config import resolve_config
from repro.launch.serve import build_parser, cli_overrides, dispatch_mode

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "serve_configs.json")

# the legacy invocation matrix: one entry per pre-refactor serving path /
# flag interaction worth freezing
LEGACY_ARGVS = [
    ["--arch", "qwen2.5-3b"],
    ["--arch", "qwen2.5-3b", "--reduce", "100m", "--requests", "4",
     "--new-tokens", "8", "--max-len", "64"],
    ["--arch", "gemma2-9b", "--reduce", "smoke", "--prompt-len", "16"],
    ["--arch", "qwen2.5-3b", "--budget-mb", "64"],
    ["--arch", "qwen2.5-3b", "--budget-mb", "16", "--store", "quant",
     "--precision", "int4", "--prefetch-depth", "1"],
    ["--arch", "qwen2.5-3b", "--budget-mb", "24", "--store", "directio"],
    ["--multi", "qwen2.5-3b,gemma2-9b", "--budget-mb", "48",
     "--rounds", "3"],
    ["--multi", "qwen2.5-3b,gemma2-9b", "--budget-mb", "48",
     "--executors", "2", "--priorities", "1,8", "--rebalance"],
    ["--multi", "qwen2.5-3b,gemma2-9b", "--budget-mb", "48",
     "--executors", "2", "--cache-frac", "0.2", "--store", "rawio"],
    ["--arch", "qwen2.5-3b", "--budget-mb", "24", "--paged",
     "--kv-frac", "0.3", "--page-tokens", "16", "--max-batch", "8"],
    ["--arch", "qwen2.5-3b", "--budget-mb", "24", "--paged",
     "--cache-frac", "0.1", "--new-tokens", "4"],
]


def _resolve(argv):
    args = build_parser().parse_args(argv)
    cfg = resolve_config(profile=args.profile, env={},
                         cli=cli_overrides(args))
    return cfg, {"argv": argv, "resolved": cfg.to_dict(),
                 "mode": dispatch_mode(cfg)}


def regenerate():
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    cases = [_resolve(argv)[1] for argv in LEGACY_ARGVS]
    with open(GOLDEN, "w") as f:
        json.dump(cases, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(cases)} cases to {GOLDEN}")


def _golden():
    if not os.path.exists(GOLDEN):     # keep the module importable for
        return []                      # regenerate(); the matrix test fails
    with open(GOLDEN) as f:
        return json.load(f)


def test_golden_covers_the_matrix():
    golden = _golden()
    assert [c["argv"] for c in golden] == LEGACY_ARGVS, \
        "golden file out of sync with LEGACY_ARGVS — regenerate() and " \
        "review the diff"


@pytest.mark.parametrize("case", _golden(),
                         ids=[" ".join(c["argv"]) for c in _golden()])
def test_legacy_invocation_resolves_identically(case):
    cfg, got = _resolve(case["argv"])
    assert got["resolved"] == case["resolved"], \
        f"effective config drifted for {' '.join(case['argv'])}"
    assert got["mode"] == case["mode"]
    assert cfg.profile is None          # legacy flags never imply a profile


# ------------------------------------------------- routing edges (no golden)
def test_multi_without_budget_still_errors():
    cfg, _ = None, None
    args = build_parser().parse_args(["--multi", "a,b"])
    # arch validation happens on resolve; use real names
    args = build_parser().parse_args(["--multi", "qwen2.5-3b,gemma2-9b"])
    cfg = resolve_config(env={}, cli=cli_overrides(args))
    with pytest.raises(SystemExit, match="budget"):
        dispatch_mode(cfg)


def test_paged_without_budget_still_errors():
    args = build_parser().parse_args(["--arch", "qwen2.5-3b", "--paged"])
    cfg = resolve_config(env={}, cli=cli_overrides(args))
    with pytest.raises(SystemExit, match="budget"):
        dispatch_mode(cfg)


def test_bare_invocation_still_demands_a_target():
    cfg = resolve_config(env={}, cli=cli_overrides(
        build_parser().parse_args([])))
    with pytest.raises(SystemExit, match="--arch"):
        dispatch_mode(cfg)


def test_cli_arch_overrides_profile_models():
    """--arch on top of a multi-model profile serves THAT model only (the
    flags clear each other so CLI choices cleanly override profiles)."""
    args = build_parser().parse_args(["--profile", "edge-tpu",
                                      "--arch", "qwen2.5-3b"])
    cfg = resolve_config(profile=args.profile, env={},
                         cli=cli_overrides(args))
    assert cfg.arch == "qwen2.5-3b" and cfg.models == []
    args = build_parser().parse_args(["--profile", "mcu",
                                      "--multi", "qwen2.5-3b,gemma2-9b"])
    cfg = resolve_config(profile=args.profile, env={},
                         cli=cli_overrides(args))
    assert cfg.arch is None
    assert cfg.models == ["qwen2.5-3b", "gemma2-9b"]


def test_http_flag_routes_to_http_mode():
    args = build_parser().parse_args(["--profile", "edge-tpu", "--http"])
    cfg = resolve_config(profile=args.profile, env={},
                         cli=cli_overrides(args))
    assert dispatch_mode(cfg) == "http"
