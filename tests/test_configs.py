"""Config registry invariants: assignments, citations, shapes, reductions."""
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, applicable, get_arch, get_shape

ASSIGNED = {
    "granite-20b": dict(n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
                        d_ff=24576, vocab_size=49152),
    "rwkv6-3b": dict(n_layers=32, d_model=2560, d_ff=8960, vocab_size=65536),
    "qwen2-vl-72b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                         d_ff=29568, vocab_size=152064),
    "qwen2.5-3b": dict(n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
                       d_ff=11008, vocab_size=151936),
    "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
                      d_ff=14336, vocab_size=32000),
    "hubert-xlarge": dict(n_layers=48, d_model=1280, n_heads=16,
                          n_kv_heads=16, d_ff=5120, vocab_size=504),
    "h2o-danube-3-4b": dict(n_layers=24, d_model=3840, n_heads=32,
                            n_kv_heads=8, d_ff=10240, vocab_size=32000),
    "gemma2-9b": dict(n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
                      d_ff=14336, vocab_size=256000),
    "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                 d_ff=1408, vocab_size=102400),
    "llama4-scout-17b-a16e": dict(n_layers=48, d_model=5120, n_heads=40,
                                  n_kv_heads=8, d_ff=8192, vocab_size=202048),
}


def test_all_assigned_archs_present():
    assert set(ASSIGNED) == set(ARCHS)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_exact_assigned_numbers(arch):
    cfg = get_arch(arch)
    for k, v in ASSIGNED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert cfg.source, f"{arch} missing citation"


def test_family_traits():
    assert get_arch("rwkv6-3b").ssm.kind == "rwkv6"
    assert get_arch("zamba2-7b").ssm.kind == "mamba2"
    assert get_arch("zamba2-7b").ssm.d_state == 64
    assert get_arch("zamba2-7b").hybrid_attn_every == 6
    assert get_arch("deepseek-v2-lite-16b").mla.kv_lora_rank == 512
    m = get_arch("deepseek-v2-lite-16b").moe
    assert (m.n_routed, m.top_k, m.n_shared) == (64, 6, 2)
    m = get_arch("llama4-scout-17b-a16e").moe
    assert (m.n_routed, m.top_k, m.n_shared) == (16, 1, 1)
    assert get_arch("gemma2-9b").attn_logit_softcap == 50.0
    assert get_arch("gemma2-9b").layer_pattern == "alt_local_global"
    assert get_arch("h2o-danube-3-4b").sliding_window == 4096
    assert get_arch("qwen2-vl-72b").rope_type == "mrope"
    assert get_arch("qwen2.5-3b").attn_bias
    assert get_arch("hubert-xlarge").is_encoder


def test_shapes_exact():
    assert (SHAPES["train_4k"].seq_len, SHAPES["train_4k"].global_batch) == (4096, 256)
    assert (SHAPES["prefill_32k"].seq_len, SHAPES["prefill_32k"].global_batch) == (32768, 32)
    assert (SHAPES["decode_32k"].seq_len, SHAPES["decode_32k"].global_batch) == (32768, 128)
    assert (SHAPES["long_500k"].seq_len, SHAPES["long_500k"].global_batch) == (524288, 1)


def test_reduced_limits():
    for cfg in ARCHS.values():
        r = cfg.reduced()
        assert r.n_layers <= 4 and r.d_model <= 512
        if r.moe:
            assert r.moe.n_routed <= 4
        # reduced keeps the family
        assert r.family == cfg.family


def test_combination_counts():
    # 34 after llama4 gained iRoPE chunked attention (long_500k now runs);
    # 6 principled skips remain (DESIGN.md §5)
    runs = sum(applicable(ARCHS[a], SHAPES[s]) for a in ARCHS for s in SHAPES)
    assert runs == 34 and len(ARCHS) * len(SHAPES) == 40
