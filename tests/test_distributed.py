"""Sharding rules: divisibility downgrade, spec filtering, 1-device mesh jit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.distributed.sharding import (batch_spec, filter_spec, pspec,
                                        stack_specs)
from repro.launch.mesh import make_smoke_mesh
from repro.models.transformer import Model


def test_pspec_divisibility_downgrade():
    # 48 heads * 128 = 6144 divides 16 -> sharded
    assert pspec((6144, 6144), ("residual", "tp")) == P("data", "model")
    # dim 1 (granite kv) cannot shard over 16 -> replicated, explicitly
    assert pspec((6144, 100), ("residual", "tp")) == P("data", None)
    assert pspec((7,), ("tp",)) == P(None)


def test_filter_spec_drops_absent_axes():
    mesh = make_smoke_mesh()        # axes (data, model)
    assert filter_spec(P(("pod", "data"), "model"), mesh) == P(("data",), "model")
    assert filter_spec(P("pod"), mesh) == P(None)


def test_stack_specs_prepends():
    s = stack_specs({"w": P("data", "model")}, 1)
    assert s["w"] == P(None, "data", "model")


def test_param_specs_cover_params():
    """Every param leaf has a spec leaf with matching tree structure and rank."""
    for arch in ("granite-20b", "zamba2-7b", "deepseek-v2-lite-16b"):
        cfg = ARCHS[arch].reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        specs = model.param_specs()
        jax.tree.map(lambda a, s: None, params, specs,
                     is_leaf=lambda x: isinstance(x, P))
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for a, s in zip(flat_p, flat_s):
            assert len(s) <= a.ndim, (a.shape, s)


def test_param_struct_matches_init():
    cfg = ARCHS["gemma2-9b"].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    struct = model.param_struct()
    sp = jax.tree.map(lambda a: (a.shape, str(a.dtype)), params)
    ss = jax.tree.map(lambda a: (a.shape, str(a.dtype)), struct)
    assert sp == ss


def test_jit_with_shardings_smoke_mesh():
    """The production sharding path works end-to-end on a 1-device mesh."""
    from jax.sharding import NamedSharding
    mesh = make_smoke_mesh()
    cfg = ARCHS["qwen2.5-3b"].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    shard = jax.tree.map(
        lambda s: NamedSharding(mesh, filter_spec(s, mesh)),
        model.param_specs(), is_leaf=lambda x: isinstance(x, P))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    fn = jax.jit(model.prefill, in_shardings=(shard, None))
    logits, _ = fn(params, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
